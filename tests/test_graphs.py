"""Graph generator invariants (clean CSR contract) + suite stats.

Randomized csr_from_edges property tests live in ``test_properties.py``
behind ``pytest.importorskip("hypothesis")``.
"""
import numpy as np
import pytest

from repro.core.csr import next_pow2
from repro.graphs import (
    SUITE,
    build_graph,
    erdos_renyi,
    grid2d,
    grid3d,
    honeycomb,
    power_law,
    rmat,
    road,
    small_world,
    stencil27,
)
from repro.graphs.rmat import RMAT_ER, RMAT_G

GENS = {
    "er": lambda: erdos_renyi(500, 6.0, seed=0),
    "rmat_er": lambda: rmat(512, 8.0, RMAT_ER, seed=1),
    "rmat_g": lambda: rmat(512, 8.0, RMAT_G, seed=2),
    "grid2d": lambda: grid2d(10, 12),
    "grid3d": lambda: grid3d(5, 6, 7),
    "stencil27": lambda: stencil27(5, 5, 5),
    "honeycomb": lambda: honeycomb(8, 10),
    "road": lambda: road(300, seed=3),
    "small_world": lambda: small_world(300, 6, seed=4),
    "power_law": lambda: power_law(400, 5.0, seed=5),
}


@pytest.mark.parametrize("name", list(GENS))
def test_clean_csr(name):
    g = GENS[name]()
    src, dst = g.edges()
    assert (src != dst).all()                        # no self loops
    # symmetric: every (u,v) has (v,u)
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((v, u) in fwd for u, v in fwd)
    # sorted, deduped adjacency
    for v in range(min(g.n, 50)):
        nb = g.neighbors(v)
        assert (np.diff(nb) > 0).all() if nb.size > 1 else True


def test_grid_degrees():
    g = grid2d(10, 10)
    assert g.max_degree == 4
    g3 = grid3d(4, 4, 4)
    assert g3.max_degree == 6
    h = honeycomb(10, 12)
    assert h.max_degree == 3


def test_stencil27_degree():
    g = stencil27(5, 5, 5)
    assert g.max_degree == 26


def test_rmat_skew():
    er = rmat(2048, 8.0, RMAT_ER, seed=7)
    gg = rmat(2048, 8.0, RMAT_G, seed=7)
    assert gg.degree_std > er.degree_std * 1.5   # rmat-g is skewed (Table 1)


def test_padded_adjacency():
    g = erdos_renyi(100, 5.0, seed=9)
    adj = g.padded_adjacency()
    assert adj.shape == (100, g.max_degree)
    for v in range(20):
        nb = g.neighbors(v)
        assert (adj[v, : nb.size] == nb).all()
        assert (adj[v, nb.size:] == g.n).all()


def test_degree_buckets_partition():
    g = power_law(500, 6.0, seed=11)
    buckets = g.degree_buckets([4, 16])
    all_ids = np.sort(np.concatenate(buckets))
    assert (all_ids == np.arange(g.n)).all()


def test_suite_builds_small():
    for name in ("rmat-er", "G3_circuit", "ASIC_320ks"):
        g = build_graph(name, scale=0.05)
        assert g.n > 100 and g.m > 100


def test_suite_covers_table1():
    assert len(SUITE) == 13   # every Table-1 graph has a stand-in


def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 5, 1024, 1025)] == [
        1, 1, 2, 4, 8, 1024, 2048]
