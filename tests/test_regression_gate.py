"""Unit tests for the CI bench regression gate (benchmarks/check_regression.py).

The gate is pure stdlib, so these tests run in milliseconds and prove the
acceptance property directly: a document with an injected color regression,
an invalid coloring, or an errored algorithm makes the checker FAIL (exit
1), while the clean document passes.
"""
import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import (  # noqa: E402
    MIN_WORK_RATIO,
    check,
    main,
    make_baseline,
)

DOC = {
    "schema": 4,
    "scale": 0.01,
    "engine": "ragged",
    "algorithms": {
        "fused": {
            "rmat-g": {"colors": 5, "valid": True, "seconds": 0.01},
            "G3_circuit": {"colors": 2, "valid": True, "seconds": 0.02},
        },
    },
    "bipartite": {"banded_b2": {"groups": 5, "optimal": 5, "valid": True}},
    "dynamic": {
        "rmat-g": {"colors": 6, "valid": True, "work_ratio": 16.4},
    },
}
BASELINE = make_baseline([DOC])


def test_clean_document_passes():
    fails, _ = check(DOC, BASELINE)
    assert fails == []


def test_injected_color_regression_fails():
    doc = copy.deepcopy(DOC)
    doc["algorithms"]["fused"]["rmat-g"]["colors"] = 6  # baseline: 5
    fails, _ = check(doc, BASELINE)
    assert any("colors regressed 5 -> 6" in f for f in fails)


def test_invalid_coloring_fails():
    doc = copy.deepcopy(DOC)
    doc["algorithms"]["fused"]["G3_circuit"]["valid"] = False
    fails, _ = check(doc, BASELINE)
    assert any("INVALID" in f for f in fails)


def test_errored_algorithm_fails():
    doc = copy.deepcopy(DOC)
    doc["algorithms"]["fused"]["rmat-g"] = {"error": "ValueError: boom"}
    fails, _ = check(doc, BASELINE)
    assert any("errored" in f for f in fails)


def test_bipartite_group_regression_fails():
    doc = copy.deepcopy(DOC)
    doc["bipartite"]["banded_b2"]["groups"] = 7
    fails, _ = check(doc, BASELINE)
    assert any("groups regressed 5 -> 7" in f for f in fails)


def test_dynamic_work_ratio_floor():
    doc = copy.deepcopy(DOC)
    doc["dynamic"]["rmat-g"]["work_ratio"] = 1.2  # n-proportional again
    fails, _ = check(doc, BASELINE)
    assert any("work_ratio" in f and "floor" in f for f in fails)
    assert BASELINE["dynamic"]["rmat-g"]["min_work_ratio"] == MIN_WORK_RATIO


def test_scale_mismatch_skips_color_comparison_not_validity():
    doc = copy.deepcopy(DOC)
    doc["scale"] = 0.02  # weekly small-scale run
    doc["algorithms"]["fused"]["rmat-g"]["colors"] = 9  # more colors is FINE
    fails, notes = check(doc, BASELINE)
    assert fails == []
    assert any("not compared" in m for m in notes)
    doc["algorithms"]["fused"]["rmat-g"]["valid"] = False  # but this never is
    fails, _ = check(doc, BASELINE)
    assert any("INVALID" in f for f in fails)


def test_new_algorithm_is_a_note_not_a_failure():
    doc = copy.deepcopy(DOC)
    doc["algorithms"]["shiny_new"] = {
        "rmat-g": {"colors": 3, "valid": True}}
    fails, notes = check(doc, BASELINE)
    assert fails == []
    assert any("not in baseline" in m for m in notes)


def _schema5_doc():
    doc = copy.deepcopy(DOC)
    doc["schema"] = 5
    doc["backend"] = "pallas"
    doc["algorithms"]["fused"]["rmat-g"]["backend"] = "pallas"
    doc["algorithms"]["fused"]["rmat-g"]["roofline"] = {
        "bytes_per_cell": 12,
        "bytes_total": 1200,
        "classes": [
            {"width": 8, "cells": 50, "bytes": 600,
             "achieved_bytes_per_s": 6e7},
            {"width": 32, "cells": 50, "bytes": 600,
             "achieved_bytes_per_s": 6e7},
        ],
        "achieved_bytes_per_s": 1.2e8,
        "seconds": 1e-5,
    }
    return doc


def test_schema5_clean_document_passes():
    fails, _ = check(_schema5_doc(), BASELINE)
    assert fails == []


def test_schema5_missing_backend_fails():
    doc = _schema5_doc()
    del doc["backend"]
    fails, _ = check(doc, BASELINE)
    assert any("missing its 'backend' field" in f for f in fails)


def test_roofline_byte_sum_mismatch_fails():
    doc = _schema5_doc()
    doc["algorithms"]["fused"]["rmat-g"]["roofline"]["bytes_total"] = 601
    fails, _ = check(doc, BASELINE)
    assert any("class bytes sum 1200 != bytes_total 601" in f for f in fails)


def test_roofline_nonpositive_bytes_fail():
    doc = _schema5_doc()
    rl = doc["algorithms"]["fused"]["rmat-g"]["roofline"]
    rl["bytes_total"] = 0
    fails, _ = check(doc, BASELINE)
    assert any("bytes_total 0 <= 0" in f for f in fails)
    doc = _schema5_doc()
    rl = doc["algorithms"]["fused"]["rmat-g"]["roofline"]
    rl["classes"][1]["bytes"] = 0
    rl["bytes_total"] = 600
    fails, _ = check(doc, BASELINE)
    assert any("class with bytes <= 0" in f for f in fails)


def test_roofline_nonpositive_rate_fails():
    doc = _schema5_doc()
    doc["algorithms"]["fused"]["rmat-g"]["roofline"][
        "achieved_bytes_per_s"] = 0.0
    fails, _ = check(doc, BASELINE)
    assert any("achieved_bytes_per_s 0.0 <= 0" in f for f in fails)


def _trace_section():
    # rows satisfy retired + conflicts == live (boot, two live steps, tail)
    return {"supersteps": 4, "tail_step": 3, "series_from": 0,
            "live": [8, 8, 5, 2], "retired": [0, 3, 3, 2],
            "conflicts": [8, 5, 2, 0], "max_color": [1, 2, 3, 3],
            "cells": [0, 64, 40, 16]}


def _schema6_doc():
    doc = copy.deepcopy(DOC)
    doc["schema"] = 6
    doc["backend"] = "jax"
    for rec in doc["algorithms"]["fused"].values():
        rec["trace"] = _trace_section()
    doc["dynamic"]["rmat-g"]["rounds_detail"] = [
        {"round": 0, "frontier": 40, "work": 200, "supersteps": 3,
         "tail_step": 2, "cache_hit": False},
        {"round": 1, "frontier": 38, "work": 190, "supersteps": 3,
         "tail_step": 2, "cache_hit": True},
    ]
    doc["dynamic"]["rmat-g"]["jit"] = {"hits": 1, "misses": 1}
    return doc


SCHEMA6_BASELINE = make_baseline([_schema6_doc()])


def test_schema6_clean_document_passes():
    fails, _ = check(_schema6_doc(), SCHEMA6_BASELINE)
    assert fails == []


def test_schema6_missing_trace_on_traced_algorithm_fails():
    doc = _schema6_doc()
    del doc["algorithms"]["fused"]["rmat-g"]["trace"]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("missing its 'trace' section" in f for f in fails)
    # untraced algorithms are exempt: topology-family records carry none
    doc["algorithms"]["serial"] = {
        "rmat-g": {"colors": 5, "valid": True}}
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert not any("serial" in f for f in fails)


def test_schema6_trace_integrity_failures():
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["live"] = [8, 8]  # len 2
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("series lengths differ" in f for f in fails)
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["retired"][1] = -3
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("negative entry" in f for f in fails)
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["conflicts"][2] = 7
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("retired + conflicts == live" in f for f in fails)
    doc = _schema6_doc()
    del doc["algorithms"]["fused"]["rmat-g"]["trace"]["tail_step"]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("trace section missing" in f for f in fails)


def test_schema6_superstep_count_regression_fails():
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["supersteps"] = 9
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("supersteps regressed 4 -> 9" in f for f in fails)


def test_schema6_earlier_tail_trigger_fails():
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["tail_step"] = 1
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("serial tail triggers at step 1" in f for f in fails)
    # tail firing where the baseline never tailed is also a regression
    base = copy.deepcopy(SCHEMA6_BASELINE)
    base["algorithms"]["fused"]["rmat-g"]["tail_step"] = -1
    fails, _ = check(_schema6_doc(), base)
    assert any("serial tail triggers" in f for f in fails)
    # and LATER (or never) is fine
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["trace"]["tail_step"] = -1
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert fails == []


def test_schema6_dynamic_jit_and_rounds_gates():
    doc = _schema6_doc()
    del doc["dynamic"]["rmat-g"]["rounds_detail"]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("missing its \nrounds_detail/jit sections".replace("\n", "")
               in f for f in fails)
    doc = _schema6_doc()
    doc["dynamic"]["rmat-g"]["jit"]["misses"] = 5  # baseline cap: 1
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("jit misses 5 exceed the" in f for f in fails)


def test_schema6_baseline_roundtrip():
    base = make_baseline([_schema6_doc()])
    rec = base["algorithms"]["fused"]["rmat-g"]
    assert rec["supersteps"] == 4 and rec["tail_step"] == 3
    assert base["dynamic"]["rmat-g"]["max_jit_misses"] == 1
    # legacy documents produce baselines without the schema-6 fields,
    # and checking a schema-6 doc against them stays green (no caps)
    legacy = make_baseline([DOC])
    assert "supersteps" not in legacy["algorithms"]["fused"]["rmat-g"]
    fails, _ = check(_schema6_doc(), legacy)
    assert fails == []


def test_unexpected_degradations_fail():
    """§17 acceptance: an injected degradation entry flips the gate."""
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["degradations"] = [
        {"stage": "ladder", "rung": "budget_extension",
         "outcome": "resolved"}]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("unexpected degradations ['ladder']" in f for f in fails)
    # dynamic and bipartite records are gated identically
    doc = _schema6_doc()
    doc["dynamic"]["rmat-g"]["degradations"] = [
        {"stage": "ingest_repair", "action": "symmetrized", "count": 2}]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("unexpected degradations ['ingest_repair']" in f
               for f in fails)
    doc = _schema6_doc()
    doc["bipartite"]["banded_b2"]["degradations"] = [{"stage": "ladder"}]
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert any("banded_b2: unexpected degradations" in f for f in fails)


def test_allowed_degradations_whitelist():
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["degradations"] = [
        {"stage": "ingest_repair", "action": "deduplicated", "count": 1}]
    base = copy.deepcopy(SCHEMA6_BASELINE)
    base["algorithms"]["fused"]["rmat-g"]["allowed_degradations"] = [
        "ingest_repair"]
    fails, _ = check(doc, base)
    assert fails == []
    # the whitelist is per-stage: a ladder escalation still fails
    doc["algorithms"]["fused"]["rmat-g"]["degradations"].append(
        {"stage": "ladder", "rung": "serial_oracle", "outcome": "resolved"})
    fails, _ = check(doc, base)
    assert any("unexpected degradations ['ladder']" in f for f in fails)
    # empty list is the healthy case, never a failure
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["degradations"] = []
    fails, _ = check(doc, SCHEMA6_BASELINE)
    assert fails == []


def test_write_baseline_accepts_current_degradations():
    doc = _schema6_doc()
    doc["algorithms"]["fused"]["rmat-g"]["degradations"] = [
        {"stage": "ingest_repair", "action": "sorted_rows", "count": 3}]
    base = make_baseline([doc])
    assert base["algorithms"]["fused"]["rmat-g"][
        "allowed_degradations"] == ["ingest_repair"]
    fails, _ = check(doc, base)
    assert fails == []


def _schema9_doc():
    # the real serving document (benchmarks/serve.py) carries ONLY the
    # serve section — no algorithms/dynamic records ride along
    doc = {"schema": 9, "scale": 0.01, "backend": "jax"}
    doc["serve"] = {
        "steady": {"p50_ms": 3.0, "p99_ms": 8.0, "rejection_rate": 0.0,
                   "jit_misses_after_warmup": 0, "submitted": 240,
                   "completed": 240, "rejected": 0, "queue_peak": 4},
        "overload": {"submitted": 96, "completed": 32, "rejected": 64,
                     "queue_peak": 32, "queue_limit": 32},
    }
    return doc


SCHEMA9_BASELINE = make_baseline([_schema9_doc()])


def test_schema9_clean_serve_document_passes():
    fails, _ = check(_schema9_doc(), SCHEMA9_BASELINE)
    assert fails == []
    assert SCHEMA9_BASELINE["serve"]["max_jit_misses_after_warmup"] == 0


def test_schema9_tail_latency_blowup_fails():
    doc = _schema9_doc()
    doc["serve"]["steady"]["p99_ms"] = 9.5  # > 3 x 3.0
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("tail latency blowup" in f for f in fails)
    doc["serve"]["steady"]["p50_ms"] = 0
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("p50_ms 0 <= 0" in f for f in fails)


def test_schema9_steady_rejections_fail():
    doc = _schema9_doc()
    doc["serve"]["steady"]["rejection_rate"] = 0.1
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("sheds load" in f for f in fails)


def test_schema9_jit_miss_after_warmup_fails():
    doc = _schema9_doc()
    doc["serve"]["steady"]["jit_misses_after_warmup"] = 1
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("left the \njit cache".replace("\n", "") in f for f in fails)


def test_schema9_lost_requests_fail():
    doc = _schema9_doc()
    doc["serve"]["steady"]["completed"] = 239  # 239 + 0 != 240
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("requests were lost" in f for f in fails)


def test_schema9_overload_must_reject_and_stay_bounded():
    doc = _schema9_doc()
    doc["serve"]["overload"]["rejected"] = 0
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("backpressure is not engaging" in f for f in fails)
    doc = _schema9_doc()
    doc["serve"]["overload"]["queue_peak"] = 40  # past limit 32
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("bound is not enforced" in f for f in fails)
    doc = _schema9_doc()
    del doc["serve"]["overload"]
    fails, _ = check(doc, SCHEMA9_BASELINE)
    assert any("missing its 'overload' section" in f for f in fails)


def test_schema9_baseline_can_widen_the_caps():
    doc = _schema9_doc()
    doc["serve"]["steady"]["p99_ms"] = 11.0
    doc["serve"]["steady"]["rejection_rate"] = 0.05
    base = copy.deepcopy(SCHEMA9_BASELINE)
    base["serve"]["max_p99_over_p50"] = 4.0
    base["serve"]["max_steady_rejection_rate"] = 0.1
    fails, _ = check(doc, base)
    assert fails == []
    # a non-serve document never trips the serve gates
    fails, _ = check(DOC, SCHEMA9_BASELINE)
    assert fails == []


def test_main_exit_codes_and_baseline_roundtrip(tmp_path):
    doc_path = tmp_path / "bench.json"
    base_path = tmp_path / "baseline.json"
    doc_path.write_text(json.dumps(DOC))
    # --write-baseline then check against it: clean pass
    assert main(["--write-baseline", str(doc_path), "-o", str(base_path)]) == 0
    assert main([str(doc_path), "--baseline", str(base_path)]) == 0
    # injected regression flips the exit code (the CI acceptance property)
    bad = copy.deepcopy(DOC)
    bad["algorithms"]["fused"]["rmat-g"]["colors"] = 99
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(bad))
    assert main([str(bad_path), "--baseline", str(base_path)]) == 1
    # one bad document fails the whole invocation even among good ones
    assert main([str(doc_path), str(bad_path),
                 "--baseline", str(base_path)]) == 1
    # no documents: usage error
    assert main(["--baseline", str(base_path)]) == 2


def test_checked_in_baseline_matches_repo_layout():
    """The committed baseline parses and covers the CI artifact surface."""
    here = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baseline_tiny.json")
    with open(here) as f:
        base = json.load(f)
    assert base["scale"] == 0.01  # CI tiny preset pins the JSON scale
    assert "fused" in base["algorithms"]
    assert "dynamic" in base["algorithms"]
    assert base["dynamic"], "dynamic churn records missing"
    for rec in base["dynamic"].values():
        assert rec["min_work_ratio"] >= MIN_WORK_RATIO
        assert rec["max_jit_misses"] >= 1  # schema 6: jit-stability cap
    # schema-6 convergence-schedule caps on the traced algorithms
    for alg in ("data_driven", "fused", "distance2", "dynamic"):
        for rec in base["algorithms"][alg].values():
            assert rec["supersteps"] > 0
            assert rec["tail_step"] >= -1
    # schema-9 serving gates (§19): the zero-miss cap is the contract
    assert base["serve"]["max_jit_misses_after_warmup"] == 0
    assert base["serve"]["max_p99_over_p50"] <= 3.0
    assert base["serve"]["max_steady_rejection_rate"] <= 0.02
