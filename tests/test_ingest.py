"""§17 ingest front door: defect detection, repair, capacity budgets.

The capacity boundary tests pin the exact bit budgets of the two packed
fast paths — 2^15 − 1 / 2^15 / 2^16 — because both failure modes are
silent without the guards: an id at 2^15 flips the halo word's sign bit,
a degree at 2^15 walks the packed gather's color field into the degree
field.
"""
import numpy as np
import pytest

from repro.api import color
from repro.core import CSRGraph, csr_from_edges, is_valid_coloring
from repro.core.coloring import run_ragged_engine
from repro.core.distributed import _build_step
from repro.ingest import (
    INDEX_MAX,
    PACKED_GATHER_MAX_DEG,
    PACKED_HALO_MAX_N,
    IngestError,
    check_halo_words,
    pack_halo_words,
    packed_gather_ok,
    packed_halo_ok,
    sanitize_csr,
    unpack_halo_words,
)


def _dirty(offsets, cols):
    return np.asarray(offsets, np.int64), np.asarray(cols, np.int32)


# --------------------------------------------------------------------------
# detection + strict policy
# --------------------------------------------------------------------------

def test_clean_graph_passes_unchanged():
    rng = np.random.default_rng(0)
    g = csr_from_edges(40, rng.integers(0, 40, 200), rng.integers(0, 40, 200))
    out, report = sanitize_csr(g, policy="strict")
    assert out is g  # identity: no copy on the clean fast path
    assert report.ok
    assert report.degradations() == ()
    assert "clean" in report.summary()


def test_empty_graph_is_clean():
    out, report = sanitize_csr(*_dirty([0], []), policy="strict")
    assert report.ok and out.n == 0 and out.m == 0


@pytest.mark.parametrize("offsets,cols,issue", [
    ([0, 1, 1, 1], [1], "asymmetric"),
    ([0, 2, 3], [0, 1, 0], "self_loop"),
    ([0, 2, 3], [1, 1, 0], "duplicate_edge"),
    ([0, 2, 3], [-1, 1, 0], "col_negative"),
    ([0, 2, 3], [1, 5, 0], "col_out_of_range"),
    ([0, 2, 4, 6], [1, 2, 2, 0, 0, 1], "row_unsorted"),
    ([0, 2, 1, 3], [1, 2, 0], "indptr_nonmonotone"),
    ([1, 2, 3], [1, 0], "indptr_first_nonzero"),
    ([0, 1, 5], [1, 0], "indptr_last_mismatch"),
])
def test_each_defect_detected_and_strict_raises(offsets, cols, issue):
    with pytest.raises(IngestError) as ei:
        sanitize_csr(*_dirty(offsets, cols), policy="strict")
    assert issue in ei.value.report.issues, ei.value.report.issues
    assert issue in ei.value.report.summary() or not ei.value.report.ok


def test_strict_report_is_structured():
    with pytest.raises(IngestError) as ei:
        sanitize_csr(*_dirty([0, 2, 3], [0, 1, 0]), policy="strict")
    rep = ei.value.report
    assert rep.policy == "strict" and rep.n == 2 and rep.m == 3
    assert rep.repairs == ()  # strict never repairs


def test_bad_shapes_and_dtypes_always_raise():
    with pytest.raises(IngestError):
        sanitize_csr(np.zeros((2, 2), np.int64), np.zeros(0, np.int32))
    with pytest.raises(IngestError):
        sanitize_csr(np.array([0.0, 1.0]), np.array([0.5]))


# --------------------------------------------------------------------------
# repair policy
# --------------------------------------------------------------------------

def test_repair_symmetrizes():
    g, rep = sanitize_csr(*_dirty([0, 1, 1, 1], [1]), policy="repair")
    assert ("symmetrized", 1) in rep.repairs
    assert g.n == 3 and g.m == 2  # 0-1 both directions
    assert list(g.neighbors(1)) == [0]


def test_repair_strips_loops_dedups_sorts():
    g, rep = sanitize_csr(
        *_dirty([0, 3, 5, 6], [1, 1, 0, 0, 0, 1]), policy="repair")
    actions = dict(rep.repairs)
    assert "stripped_self_loops" in actions
    assert "deduplicated" in actions
    for v in range(g.n):
        nb = g.neighbors(v)
        assert (np.diff(nb) > 0).all()  # sorted, no dups
        assert v not in nb              # no self loops


def test_repair_drops_bad_indices_keeps_rest():
    g, rep = sanitize_csr(
        *_dirty([0, 3, 4], [-1, 1, 9, 0]), policy="repair")
    assert ("dropped_out_of_range", 2) in rep.repairs
    assert g.n == 2 and g.m == 2  # surviving 0-1 edge, symmetric


def test_repair_rebuilds_broken_indptr():
    g, rep = sanitize_csr(*_dirty([0, 2, 1, 3], [1, 2, 0]), policy="repair")
    assert any(a == "rebuilt_indptr" for a, _ in rep.repairs)
    assert (np.diff(g.row_offsets) >= 0).all()
    out, rep2 = sanitize_csr(g, policy="strict")  # repaired output is clean
    assert rep2.ok


def test_repair_output_always_revalidates():
    rng = np.random.default_rng(5)
    for _ in range(10):
        n = int(rng.integers(2, 12))
        m = int(rng.integers(0, 20))
        counts = rng.multinomial(m, np.ones(n) / n)
        offsets = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        cols = rng.integers(-2, n + 2, m)
        g, _ = sanitize_csr(offsets, cols.astype(np.int32), policy="repair")
        _, rep = sanitize_csr(g, policy="strict")
        assert rep.ok


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown policy"):
        sanitize_csr(*_dirty([0], []), policy="lenient")


def test_index_capacity_guard_on_vertex_growth():
    # materializing 2^31 offsets is not viable in CI; the int32 index-space
    # ceiling is exercised where it can actually be crossed — vertex growth
    from repro.dynamic.delta import DeltaCSR

    assert INDEX_MAX == 2**31 - 1
    d = DeltaCSR.from_edges(2, np.array([0]), np.array([1]))
    with pytest.raises(ValueError, match="int32"):
        d.add_vertices(INDEX_MAX)


# --------------------------------------------------------------------------
# packed-word capacity boundaries: 2^15 − 1 / 2^15 / 2^16 exactly
# --------------------------------------------------------------------------

def test_packed_halo_boundary():
    assert packed_halo_ok(PACKED_HALO_MAX_N - 1)        # 2^15 - 1: last good
    assert not packed_halo_ok(PACKED_HALO_MAX_N)        # 2^15: sign-bit flip
    assert not packed_halo_ok(2**16)                    # far side
    assert not packed_halo_ok(-1)


def test_packed_gather_boundary():
    assert packed_gather_ok(PACKED_GATHER_MAX_DEG - 1)  # 2^15 - 2: last good
    assert not packed_gather_ok(PACKED_GATHER_MAX_DEG)  # 2^15 - 1: deg + 1
    assert not packed_gather_ok(2**15)
    assert not packed_gather_ok(2**16)
    assert not packed_gather_ok(-1)
    # color bound is checked with the same margin
    assert packed_gather_ok(4, color_bound=PACKED_GATHER_MAX_DEG - 1)
    assert not packed_gather_ok(4, color_bound=PACKED_GATHER_MAX_DEG)
    assert not packed_gather_ok(4, color_bound=2**16)


def test_halo_word_roundtrip_at_capacity():
    ids = np.array([0, 1, PACKED_HALO_MAX_N - 1], np.int64)
    colors = np.array([0, 7, PACKED_HALO_MAX_N - 1], np.int64)
    back_ids, back_colors = unpack_halo_words(pack_halo_words(ids, colors))
    np.testing.assert_array_equal(back_ids, ids)
    np.testing.assert_array_equal(back_colors, colors)


def test_halo_word_corrupts_past_capacity():
    # the reason the guard exists: id = 2^15 flips the int32 sign bit
    words = pack_halo_words(np.array([2**15]), np.array([1]))
    assert words[0] < 0
    bad = check_halo_words(words, n=2**15 + 10)
    assert bad.size == 1


def test_ragged_engine_refuses_packed_overflow():
    with pytest.raises(ValueError, match="pack_degrees"):
        run_ragged_engine(
            n=4, provider=None, deg_ext=None, classes=[], tile_widths=[],
            acc_widths=[], tail_width=PACKED_GATHER_MAX_DEG,
            max_iters=4, pack_degrees=True)


def test_sharded_step_refuses_packed_halo_overflow():
    with pytest.raises(ValueError, match="halo"):
        _build_step(
            None, provider_kind="csr", n=PACKED_HALO_MAX_N, n_loc=8,
            tile_widths=(4,), heuristic="degree", kind="bitset",
            pack_degrees=False, pack_halo=True)


def test_engine_falls_back_unpacked_above_budget(monkeypatch):
    """Force the capacity predicate to answer False: the dispatch must pick
    the unpacked path and still produce a valid (identical) coloring."""
    import repro.core.coloring as C

    g = csr_from_edges(30, np.arange(29, dtype=np.int64),
                       np.arange(1, 30, dtype=np.int64))
    ref = color(g, "data_driven", engine="ragged")
    monkeypatch.setattr(C, "_packed_gather_ok", lambda d, c=None: False)
    out = color(g, "data_driven", engine="ragged")
    np.testing.assert_array_equal(ref.colors, out.colors)
    assert is_valid_coloring(g, out.colors)


# --------------------------------------------------------------------------
# api wiring
# --------------------------------------------------------------------------

def test_color_validate_input_strict_and_repair():
    bad = CSRGraph(np.array([0, 1, 1, 1], np.int64), np.array([1], np.int32))
    with pytest.raises(IngestError):
        color(bad, validate_input="strict")
    r = color(bad, validate_input="repair")
    assert any(d["stage"] == "ingest_repair" for d in r.degradations)
    assert r.converged


def test_color_validate_input_rejects_non_csr():
    with pytest.raises(TypeError, match="CSRGraph"):
        color(object(), validate_input="strict")


def test_batch_and_partition_validate_input():
    from repro.core.batch import GraphBatch
    from repro.core.csr import PartitionedCSR

    bad = CSRGraph(np.array([0, 1, 1, 1], np.int64), np.array([1], np.int32))
    with pytest.raises(IngestError):
        GraphBatch.from_graphs([bad], validate_input="strict")
    batch = GraphBatch.from_graphs([bad], validate_input="repair")
    assert batch.B == 1
    with pytest.raises(IngestError):
        PartitionedCSR.from_graph(bad, 2, validate_input="strict")
    part = PartitionedCSR.from_graph(bad, 2, validate_input="repair")
    assert part.n == 3


def test_delta_csr_validate_input():
    from repro.dynamic.delta import DeltaCSR

    bad = CSRGraph(np.array([0, 1, 1, 1], np.int64), np.array([1], np.int32))
    d = DeltaCSR(bad, validate_input="repair")
    assert d.ingest_report is not None and d.ingest_report.repairs
    _, rep = sanitize_csr(d.graph(), policy="strict")
    assert rep.ok
    with pytest.raises(IngestError):
        DeltaCSR(bad, validate_input="strict")
