"""Per-architecture smoke tests + serving-path consistency (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model

ARCHS = list_archs()
RNG = jax.random.PRNGKey(7)


def make_batch(cfg, B=2, S=16, with_labels=True):
    if cfg.family == "encoder":
        b = {"frames": jnp.ones((B, S, cfg.d_frontend), jnp.float32)}
        if with_labels:
            b["labels"] = jnp.zeros((B, S), jnp.int32)
        return b
    s_text = S - (cfg.n_patches if cfg.family == "vlm" else 0)
    b = {"tokens": jax.random.randint(RNG, (B, s_text), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_frontend), jnp.float32)
    if with_labels:
        b["labels"] = b["tokens"]
    return b


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step; shapes + finiteness."""
    from repro.training import AdamWConfig, init_train_state, make_train_step

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    batch = make_batch(cfg)
    state = init_train_state(model, RNG)
    logits = model.forward(state["params"], batch)
    s_text = 16 - (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_text, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()

    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dimensions(arch):
    """Full configs carry the exact published dimensions (never allocated)."""
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-236b": (60, 5120, 128, 102400),
        "mixtral-8x22b": (56, 6144, 48, 32768),
        "qwen3-32b": (64, 5120, 64, 151936),
        "qwen3-4b": (36, 2560, 32, 151936),
        "granite-3-8b": (40, 4096, 32, 49155),
        "starcoder2-15b": (40, 6144, 48, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 256000),
        "internvl2-26b": (48, 6144, 48, 92553),
        "hubert-xlarge": (48, 1280, 16, 504),
        "rwkv6-1.6b": (24, 2048, 32, 65536),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.vocab) == expected
    # params land in the right ballpark (within 2x of the nameplate count)
    nameplate = {
        "deepseek-v2-236b": 236e9, "mixtral-8x22b": 141e9, "qwen3-32b": 32e9,
        "qwen3-4b": 4e9, "granite-3-8b": 8e9, "starcoder2-15b": 15e9,
        "recurrentgemma-2b": 2.7e9, "internvl2-26b": 20e9,
        "hubert-xlarge": 1e9, "rwkv6-1.6b": 1.6e9,
    }[arch]
    total, active = cfg.params_estimate()
    assert 0.4 * nameplate < total < 2.5 * nameplate, total
    assert active <= total


DECODE_ARCHS = [a for a in ARCHS if get_config(a).family != "encoder"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch):
    """Logits from prefill+decode match the full forward pass exactly."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, T = 2, 12, 20
    batch = make_batch(cfg, B, S, with_labels=False)
    tokens = batch["tokens"]
    full = np.asarray(model.forward(params, {**batch, "labels": tokens}))

    k = tokens.shape[1] - 4
    pre = dict(batch)
    pre["tokens"] = tokens[:, :k]
    caches, lg = model.prefill(params, pre, T)
    errs = [np.abs(np.asarray(lg) - full[:, k - 1]).max()]
    dec = jax.jit(model.decode_step)
    off = cfg.n_patches if cfg.family == "vlm" else 0
    for t in range(k, tokens.shape[1]):
        caches, lg = dec(params, caches, tokens[:, t:t + 1], jnp.int32(t + off))
        errs.append(np.abs(np.asarray(lg) - full[:, t]).max())
    assert max(errs) < 2e-3, max(errs)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "recurrentgemma-2b"])
def test_windowed_decode_beyond_window(arch):
    """Ring-buffer caches stay correct once pos exceeds the window."""
    cfg = get_config(arch).reduced()   # window = 8
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 14
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    full = np.asarray(model.forward(params, {"tokens": tokens, "labels": tokens}))
    caches, lg = model.prefill(params, {"tokens": tokens[:, :4]}, 4 + S)
    dec = jax.jit(model.decode_step)
    errs = []
    for t in range(4, S):
        caches, lg = dec(params, caches, tokens[:, t:t + 1], jnp.int32(t))
        errs.append(np.abs(np.asarray(lg) - full[:, t]).max())
    assert max(errs) < 2e-3, max(errs)


def test_moe_aux_losses_present():
    cfg = get_config("mixtral-8x22b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    loss, metrics = model.loss(params, make_batch(cfg))
    assert float(metrics["lb_loss"]) > 0.0


def test_label_masking():
    cfg = get_config("qwen3-4b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    masked = dict(batch)
    masked["labels"] = batch["labels"].at[:, ::2].set(-1)
    l_full, m_full = model.loss(params, batch)
    l_mask, m_mask = model.loss(params, masked)
    assert int(m_mask["tokens"]) < int(m_full["tokens"])
    assert np.isfinite(float(l_mask))


def test_input_specs_no_allocation():
    for arch in ARCHS:
        cfg = get_config(arch)   # FULL config — specs must not allocate
        model = build_model(cfg)
        specs = model.input_specs(4, 128, "train")
        assert all(isinstance(s, jax.ShapeDtypeStruct) for s in specs.values())
        if cfg.family != "encoder":
            d = model.input_specs(4, 128, "decode")
            assert isinstance(d["token"], jax.ShapeDtypeStruct)
            for leaf in jax.tree.leaves(d["caches"]):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "qwen3-4b", "mixtral-8x22b",
                                  "recurrentgemma-2b", "deepseek-v2-236b"])
def test_bf16_numerics_smoke(arch):
    """Full configs run bf16; reduced smoke must exercise the same dtypes
    (a bf16/f32 scan-carry mismatch in rwkv6 escaped the f32 smoke tests)."""
    import dataclasses

    cfg = dataclasses.replace(get_config(arch).reduced(),
                              param_dtype="bfloat16", act_dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(RNG)
    loss, _ = model.loss(params, make_batch(cfg))
    assert np.isfinite(float(loss))
    if cfg.family != "encoder":
        caches, lg = model.prefill(params, make_batch(cfg, with_labels=False), 20)
        caches, lg = model.decode_step(
            params, caches, jnp.zeros((2, 1), jnp.int32),
            jnp.int32(16))
        assert np.isfinite(np.asarray(lg, dtype=np.float32)).all()
