"""FirstFit variants + conflict heuristics: deterministic unit tests.

The hypothesis property tests (randomized oracle sweeps) live in
``test_properties.py`` behind ``pytest.importorskip("hypothesis")`` so this
module's coverage survives environments without hypothesis installed.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.firstfit import (
    FF_FUNCS,
    ffs_u32,
    firstfit_bitset,
    firstfit_scan,
    firstfit_sort,
)
from repro.core.heuristics import conflict_lose_flags
from repro.kernels.firstfit.ref import firstfit_ref


def _oracle_row(row):
    present = set(int(c) for c in row if c > 0)
    c = 1
    while c in present:
        c += 1
    return c


def test_firstfit_variants_match_oracle_fixed_seeds():
    for w, W, seed in [(7, 5, 0), (30, 40, 1), (1, 1, 2), (16, 33, 3)]:
        rng = np.random.default_rng(seed)
        nc = rng.integers(0, W + 3, size=(w, W)).astype(np.int32)
        want = np.array([_oracle_row(r) for r in nc], dtype=np.int32)
        for name, fn in FF_FUNCS.items():
            got = np.asarray(fn(jnp.asarray(nc)))
            np.testing.assert_array_equal(got, want, err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(firstfit_ref(jnp.asarray(nc))), want)


def test_firstfit_greedy_bound_edge():
    # W neighbors with colors exactly 1..W -> answer W+1 (bound is tight)
    W = 37
    nc = jnp.asarray(np.arange(1, W + 1)[None, :].astype(np.int32))
    for fn in (firstfit_scan, firstfit_sort, firstfit_bitset):
        assert int(fn(nc)[0]) == W + 1


def test_firstfit_ignores_uncolored_and_huge():
    nc = jnp.asarray(np.array([[0, 0, 999, 2]], dtype=np.int32))
    for fn in FF_FUNCS.values():
        assert int(fn(nc)[0]) == 1


def test_ffs_u32():
    vals = np.array([1, 2, 3, 8, 0x80000000, 0, 0xFFFFFFFF], dtype=np.uint32)
    got = np.asarray(ffs_u32(jnp.asarray(vals)))
    want = []
    for v in vals:
        vi = int(v)
        want.append(32 if vi == 0 else (vi & -vi).bit_length() - 1)
    np.testing.assert_array_equal(got, np.array(want))


def test_conflict_exactly_one_loser_fixed_seed():
    """For every monochromatic edge, exactly one endpoint loses (both rules)."""
    rng = np.random.default_rng(1234)
    n = 10
    deg = rng.integers(0, 7, size=n + 1).astype(np.int32)
    deg[n] = 0
    colors = rng.integers(0, 3, size=n + 1).astype(np.int32)
    colors[n] = 0
    for heuristic in ("id", "degree"):
        for u in range(n):
            for v in range(n):
                if u == v or colors[u] == 0 or colors[u] != colors[v]:
                    continue
                lu = conflict_lose_flags(
                    jnp.asarray([u]), jnp.asarray([[v]]),
                    jnp.asarray([colors[u]]), jnp.asarray([[colors[v]]]),
                    jnp.asarray([deg[u]]), jnp.asarray([[deg[v]]]), heuristic)
                lv = conflict_lose_flags(
                    jnp.asarray([v]), jnp.asarray([[u]]),
                    jnp.asarray([colors[v]]), jnp.asarray([[colors[u]]]),
                    jnp.asarray([deg[v]]), jnp.asarray([[deg[u]]]), heuristic)
                assert bool(lu[0]) != bool(lv[0]), (heuristic, u, v)


def test_conflict_none_when_uncolored_or_different():
    lose = conflict_lose_flags(
        jnp.asarray([3]), jnp.asarray([[5, 7]]),
        jnp.asarray([0]), jnp.asarray([[0, 2]]),
        jnp.asarray([4]), jnp.asarray([[4, 4]]), "degree")
    assert not bool(lose[0])
