"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, shape sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conflict.ops import conflict_tpu
from repro.kernels.conflict.ref import conflict_ref
from repro.kernels.d2.ops import d2_firstfit_bitset_tpu
from repro.kernels.d2.ref import d2_firstfit_ref
from repro.kernels.firstfit.ops import firstfit_bitset_tpu
from repro.kernels.firstfit.ref import firstfit_ref

SHAPES = [(7, 3), (8, 8), (64, 16), (100, 33), (256, 64), (33, 130), (512, 5)]


@pytest.mark.parametrize("w,W", SHAPES)
@pytest.mark.parametrize("dtype", [np.int32, np.int16])
def test_firstfit_kernel_matches_ref(w, W, dtype):
    rng = np.random.default_rng(w * 1000 + W)
    nc = rng.integers(0, W + 3, size=(w, W)).astype(dtype)
    got = np.asarray(firstfit_bitset_tpu(jnp.asarray(nc)))
    want = np.asarray(firstfit_ref(jnp.asarray(nc.astype(np.int32))))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_n", [8, 16, 128])
def test_firstfit_kernel_block_sizes(block_n):
    """Thread-coarsening knob: result independent of block size."""
    rng = np.random.default_rng(0)
    nc = rng.integers(0, 20, size=(200, 17)).astype(np.int32)
    got = np.asarray(firstfit_bitset_tpu(jnp.asarray(nc), block_n=block_n))
    want = np.asarray(firstfit_ref(jnp.asarray(nc)))
    np.testing.assert_array_equal(got, want)


def test_firstfit_kernel_empty():
    out = firstfit_bitset_tpu(jnp.zeros((0, 4), jnp.int32))
    assert out.shape == (0,)


D2_SHAPES = [(7, 3, 9), (8, 8, 64), (64, 16, 48), (100, 5, 33), (33, 2, 130)]


@pytest.mark.parametrize("w,W1,W2", D2_SHAPES)
def test_d2_firstfit_kernel_matches_ref(w, W1, W2):
    rng = np.random.default_rng(w * 100 + W1 + W2)
    nc1 = rng.integers(0, W1 + W2 + 3, size=(w, W1)).astype(np.int32)
    nc2 = rng.integers(0, W1 + W2 + 3, size=(w, W2)).astype(np.int32)
    got = np.asarray(d2_firstfit_bitset_tpu(jnp.asarray(nc1), jnp.asarray(nc2)))
    want = np.asarray(d2_firstfit_ref(jnp.asarray(nc1), jnp.asarray(nc2)))
    np.testing.assert_array_equal(got, want)


def test_d2_firstfit_kernel_union_semantics():
    """A color forbidden by either tile is skipped; the union drives FFS."""
    nc1 = jnp.asarray([[1, 0], [0, 0], [3, 0]], jnp.int32)
    nc2 = jnp.asarray([[2, 3, 0], [0, 0, 0], [1, 2, 4]], jnp.int32)
    got = np.asarray(d2_firstfit_bitset_tpu(nc1, nc2))
    np.testing.assert_array_equal(got, [4, 1, 5])


@pytest.mark.parametrize("block_n", [8, 16, 128])
def test_d2_firstfit_kernel_block_sizes(block_n):
    rng = np.random.default_rng(7)
    nc1 = rng.integers(0, 40, size=(200, 9)).astype(np.int32)
    nc2 = rng.integers(0, 40, size=(200, 29)).astype(np.int32)
    got = np.asarray(
        d2_firstfit_bitset_tpu(jnp.asarray(nc1), jnp.asarray(nc2), block_n=block_n)
    )
    want = np.asarray(d2_firstfit_ref(jnp.asarray(nc1), jnp.asarray(nc2)))
    np.testing.assert_array_equal(got, want)


def test_d2_firstfit_kernel_empty():
    out = d2_firstfit_bitset_tpu(jnp.zeros((0, 4), jnp.int32),
                                 jnp.zeros((0, 16), jnp.int32))
    assert out.shape == (0,)


@pytest.mark.parametrize("w,W", SHAPES[:5])
@pytest.mark.parametrize("heuristic", ["id", "degree"])
def test_conflict_kernel_matches_ref(w, W, heuristic):
    rng = np.random.default_rng(w + W)
    ids = rng.permutation(w + 3)[:w].astype(np.int32)
    nid = rng.integers(0, w + 3, size=(w, W)).astype(np.int32)
    my_c = rng.integers(0, 6, size=(w,)).astype(np.int32)
    nc = rng.integers(0, 6, size=(w, W)).astype(np.int32)
    my_d = rng.integers(0, 9, size=(w,)).astype(np.int32)
    nd = rng.integers(0, 9, size=(w, W)).astype(np.int32)
    args = tuple(map(jnp.asarray, (ids, nid, my_c, nc, my_d, nd)))
    got = np.asarray(conflict_tpu(*args, heuristic))
    want = np.asarray(conflict_ref(*args, heuristic=heuristic))
    np.testing.assert_array_equal(got, want)
