"""§19 ColorOptions: normalization, bit-identity with kwargs, deprecation.

The contract under test: every entry point accepts options two ways —
a frozen ``ColorOptions`` or the equivalent loose kwargs — and BOTH
normalize into the same object before any engine runs, so results are
bit-identical across spellings.  The legacy ``use_kernel=`` knob warns
and translates to ``backend=`` for one release.
"""
import dataclasses
import pickle

import numpy as np
import pytest

import repro
from repro import ColorOptions
from repro.core import csr_from_edges
from repro.options import UNSET


def _graph(n=80, m=400, seed=0):
    rng = np.random.default_rng(seed)
    return csr_from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))


# --------------------------------------------------------------------------
# the object itself
# --------------------------------------------------------------------------

def test_frozen_hashable_and_picklable():
    o = ColorOptions(algorithm="fused", heuristic="id",
                     extra={"mode": "forward"})
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.heuristic = "degree"
    assert hash(o) == hash(ColorOptions(algorithm="fused", heuristic="id",
                                        extra={"mode": "forward"}))
    back = pickle.loads(pickle.dumps(o))
    assert back == o
    assert back.tail_serial is UNSET          # sentinel survives pickling


def test_normalize_kwargs_win_and_unknown_go_to_extra():
    base = ColorOptions(algorithm="fused", heuristic="degree")
    o = ColorOptions.normalize(base, heuristic="id", tiling=(4, 64))
    assert o.heuristic == "id"                # kwargs over options
    assert o.algorithm == "fused"             # untouched field preserved
    assert o.extra_dict() == {"tiling": (4, 64)}
    assert ColorOptions.normalize(base) is base   # no kwargs: no copy


def test_unset_fields_are_omitted_from_engine_kwargs():
    assert ColorOptions().engine_kwargs() == {}
    kw = ColorOptions(heuristic="id", max_iters=7).engine_kwargs()
    assert kw == {"heuristic": "id", "max_iters": 7}
    assert ColorOptions(tail_serial=None).engine_kwargs() == {
        "tail_serial": None}                  # None is meaningful here


def test_session_kwargs_refuses_foreign_fields():
    with pytest.raises(ValueError, match="engine"):
        ColorOptions(engine="sharded").session_kwargs()
    with pytest.raises(ValueError, match="algorithm"):
        ColorOptions(algorithm="fused").session_kwargs()
    assert ColorOptions(algorithm="dynamic").session_kwargs() == {}
    assert (ColorOptions(ensure_valid=True).session_kwargs()
            == {"on_fail": "ladder"})


def test_merged_and_describe():
    o = ColorOptions(algorithm="fused").merged(heuristic="id")
    assert (o.algorithm, o.heuristic) == ("fused", "id")
    assert "heuristic='id'" in o.describe()


# --------------------------------------------------------------------------
# bit-identity: options object path == loose kwargs path
# --------------------------------------------------------------------------

_MATRIX = [
    dict(algorithm="fused"),
    dict(algorithm="fused", heuristic="id"),
    dict(algorithm="fused", backend="jax", tail_serial=None),
    dict(algorithm="data_driven", heuristic="degree"),
    dict(algorithm="topology"),
    dict(algorithm="distance2"),
]


@pytest.mark.parametrize("knobs", _MATRIX,
                         ids=lambda k: ",".join(f"{a}={v}"
                                                for a, v in k.items()))
def test_color_options_path_bit_identical_to_kwargs(knobs):
    g = _graph()
    via_kwargs = repro.color(g, **knobs)
    via_options = repro.color(g, options=ColorOptions(**knobs))
    positional = repro.color(g, ColorOptions(**knobs))
    np.testing.assert_array_equal(via_kwargs.colors, via_options.colors)
    np.testing.assert_array_equal(via_kwargs.colors, positional.colors)
    assert via_kwargs.num_colors == via_options.num_colors


@pytest.mark.parametrize("engine", [None, "sharded"])
def test_color_batch_options_path_bit_identical(engine):
    graphs = [_graph(seed=s) for s in range(3)]
    knobs = {"heuristic": "id"}
    if engine is not None:
        knobs["engine"] = engine
    via_kwargs = repro.color_batch(graphs, "fused", **knobs)
    via_options = repro.color_batch(
        graphs, options=ColorOptions(algorithm="fused", **knobs))
    for a, b in zip(via_kwargs, via_options):
        np.testing.assert_array_equal(a.colors, b.colors)


def test_open_session_options_path_bit_identical():
    g = _graph()
    a = repro.open_session(g, heuristic="id")
    b = repro.open_session(g, options=ColorOptions(heuristic="id"))
    rng_a, rng_b = np.random.default_rng(5), np.random.default_rng(5)
    for s, rng in ((a, rng_a), (b, rng_b)):
        s.apply_delta(add_edges=(rng.integers(0, g.n, 20),
                                 rng.integers(0, g.n, 20)))
        s.recolor()
    np.testing.assert_array_equal(a.colors, b.colors)


def test_color_batch_refuses_foreign_extra_by_name():
    with pytest.raises(ValueError, match="tiling"):
        repro.color_batch([_graph()], "fused", tiling=(4, 32))


def test_positional_options_conflicts_with_options_kw():
    o = ColorOptions(algorithm="fused")
    with pytest.raises(TypeError):
        repro.color(_graph(), o, options=o)


# --------------------------------------------------------------------------
# use_kernel deprecation shim
# --------------------------------------------------------------------------

def test_use_kernel_true_warns_and_maps_to_pallas():
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        o = ColorOptions.normalize(None, use_kernel=True)
    assert o.backend == "pallas"


def test_use_kernel_false_warns_and_leaves_backend_unset():
    with pytest.warns(DeprecationWarning):
        o = ColorOptions.normalize(None, use_kernel=False)
    assert o.backend is None


def test_use_kernel_conflicts_with_jax_backend():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="contradicts"):
            ColorOptions.normalize(None, use_kernel=True, backend="jax")


def test_use_kernel_through_color_entry_point():
    g = _graph()
    with pytest.warns(DeprecationWarning):
        r = repro.color(g, "fused", use_kernel=False)
    np.testing.assert_array_equal(r.colors,
                                  repro.color(g, "fused").colors)


def test_no_in_repo_callers_pass_use_kernel():
    """The migration is complete: no in-repo code calls a PUBLIC entry
    point with the deprecated ``use_kernel=`` knob.  Shim-coverage tests
    are whitelisted; internal helpers below the ``resolve_backend``
    boundary (``ragged_superstep`` & co.) keep a ``use_kernel`` parameter
    carrying the already-resolved kernel mode — that is not the knob."""
    import ast
    import pathlib

    public = {"color", "color_batch", "open_session", "color_data_driven",
              "color_distance2", "color_bipartite"}
    root = pathlib.Path(__file__).resolve().parent.parent
    allowed = {root / "tests" / "test_options.py",
               root / "tests" / "test_differential.py",
               root / "tests" / "test_sharded.py"}
    offenders = []
    for sub in ("src", "examples", "benchmarks", "tests"):
        for path in (root / sub).rglob("*.py"):
            if path in allowed:
                continue
            for node in ast.walk(ast.parse(path.read_text())):
                if not isinstance(node, ast.Call):
                    continue
                name = (node.func.id if isinstance(node.func, ast.Name)
                        else node.func.attr
                        if isinstance(node.func, ast.Attribute) else None)
                if (name in public
                        and any(k.arg == "use_kernel"
                                for k in node.keywords)):
                    offenders.append(
                        f"{path.relative_to(root)}:{node.lineno}")
    assert not offenders, offenders
