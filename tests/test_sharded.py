"""Sharded engine (§13): partition-plan invariants + single-device parity.

Everything here runs in the ordinary single-device pytest process: the
partition plan is pure host numpy, and ``color_distributed`` exercises the
full shard_map machinery even on a one-device mesh — where its contract is
the strongest in the tree: bit-identical to ``color_data_driven
(mode="fused")`` INCLUDING the work/padded-work accounting.  The
8-simulated-device behaviour lives in ``tests/test_distributed.py``.
"""
import numpy as np
import pytest

import repro
from repro.core import (
    ColoringResult,
    PartitionedCSR,
    color_data_driven,
    color_distributed,
    is_valid_coloring,
)
from repro.d2.bipartite import BipartiteGraph
from repro.graphs import erdos_renyi, grid2d, power_law, road

GRAPHS = {
    "er": lambda: erdos_renyi(700, 8.0, seed=0),
    "grid": lambda: grid2d(18, 22),
    "powerlaw": lambda: power_law(600, 6.0, seed=1),
    "road": lambda: road(650, seed=2),
}


def _bipartite(seed=0, shape=(70, 110), p=0.06):
    rng = np.random.default_rng(seed)
    return BipartiteGraph.from_dense(rng.random(shape) < p)


# --------------------------------------------------------------------------
# partition-plan invariants (satellite: halo send-list property test)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("ndev", [2, 3, 8])
def test_plan_partitions_each_range(gname, ndev):
    g = GRAPHS[gname]()
    plan = PartitionedCSR.from_graph(g, ndev)
    assert plan.starts[0] == 0 and plan.starts[-1] == g.n
    assert (np.diff(plan.starts) >= 0).all()
    for d in range(plan.ndev):
        ids = np.arange(plan.starts[d], plan.starts[d + 1])
        # interior/boundary is a PARTITION of the shard's range
        both = np.union1d(plan.interior[d], plan.boundary[d])
        assert np.array_equal(both, ids), (gname, ndev, d)
        assert np.intersect1d(plan.interior[d], plan.boundary[d]).size == 0


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("ndev", [2, 8])
def test_plan_halo_send_lists_cover_cross_edges(gname, ndev):
    """Every cross-partition edge endpoint sits in exactly ONE send list."""
    g = GRAPHS[gname]()
    plan = PartitionedCSR.from_graph(g, ndev)
    owner = plan.owners()
    src, dst = g.edges()
    cross = owner[src] != owner[dst]
    # membership count per vertex across all send (=boundary) lists
    in_sends = np.zeros(g.n, dtype=np.int64)
    for b in plan.boundary:
        np.add.at(in_sends, b, 1)
    # each cross endpoint appears in exactly one send list (its owner's) ...
    endpoints = np.unique(np.concatenate([src[cross], dst[cross]]))
    assert (in_sends[endpoints] == 1).all(), (gname, ndev)
    for d, b in enumerate(plan.boundary):
        assert (owner[b] == d).all()
    # ... and a vertex with NO cross edge is in no send list
    quiet = np.setdiff1d(np.arange(g.n), endpoints)
    assert (in_sends[quiet] == 0).all()
    # recv sets are exactly the remote endpoints each device reads
    for d in range(plan.ndev):
        expect = np.unique(dst[(owner[src] == d) & cross])
        assert np.array_equal(np.sort(plan.recv[d]), expect), (gname, ndev, d)


@pytest.mark.parametrize("ndev", [2, 5])
def test_plan_two_hop_boundary_covers_square_cross_edges(ndev):
    """two_hop plans mark every vertex whose G²-neighborhood crosses."""
    g = GRAPHS["er"]()
    plan = PartitionedCSR.from_graph(g, ndev, boundary_mode="two_hop")
    owner = plan.owners()
    g2 = g.square()
    src, dst = g2.edges()
    cross = owner[src] != owner[dst]
    in_sends = np.zeros(g.n, dtype=np.int64)
    for b in plan.boundary:
        np.add.at(in_sends, b, 1)
    assert (in_sends[np.unique(src[cross])] == 1).all()


@pytest.mark.parametrize("ndev", [2, 4])
def test_plan_bipartite_boundary_covers_conflicts(ndev):
    bg = _bipartite()
    plan = PartitionedCSR.from_bipartite(bg, ndev)
    owner = plan.owners()
    cg = bg.column_conflict_graph()
    src, dst = cg.edges()
    cross = owner[src] != owner[dst]
    in_sends = np.zeros(bg.n_cols, dtype=np.int64)
    for b in plan.boundary:
        np.add.at(in_sends, b, 1)
    assert (in_sends[np.unique(src[cross])] == 1).all()
    for d in range(plan.ndev):
        ids = np.arange(plan.starts[d], plan.starts[d + 1])
        both = np.union1d(plan.interior[d], plan.boundary[d])
        assert np.array_equal(both, ids)


def test_plan_degree_balance():
    """Ranges balance degree+1 weight, not raw vertex counts."""
    g = GRAPHS["powerlaw"]()
    ndev = 4
    plan = PartitionedCSR.from_graph(g, ndev)
    w = g.degrees.astype(np.int64) + 1
    loads = [int(w[plan.starts[d]:plan.starts[d + 1]].sum())
             for d in range(ndev)]
    mean = sum(loads) / ndev
    # contiguity caps the achievable balance; 2x mean is the sanity band
    assert max(loads) <= 2 * mean + int(w.max())


# --------------------------------------------------------------------------
# single-device parity: sharded ≡ fused ragged, bit-for-bit + accounting
# (satellite: padded_work gather-cell regression vs the ragged engine)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_sharded_one_device_equals_fused_ragged(gname):
    g = GRAPHS[gname]()
    r_sh = color_distributed(g)
    r_f = color_data_driven(g, mode="fused")
    assert is_valid_coloring(g, r_sh.colors)
    assert (r_sh.colors == r_f.colors).all()
    assert r_sh.iterations == r_f.iterations
    # the pre-§13 engine reported padded_work = iters * n_pad (lanes, not
    # gather cells); the rewrite must match the ragged engine's accounting
    assert r_sh.work_items == r_f.work_items
    assert r_sh.padded_work == r_f.padded_work
    assert r_sh.converged
    assert r_sh.algorithm.startswith("sharded_sgr_")


def test_sharded_padded_work_counts_gather_cells():
    """Regression: padded_work is lanes × tile width, not lanes alone."""
    g = GRAPHS["er"]()
    r = color_distributed(g, tail_serial=None, tiling=None)
    spec_steps = r.iterations - 1  # bootstrap is materialized, never dispatched
    dmax = g.max_degree
    assert r.padded_work == spec_steps * g.n * dmax
    assert r.padded_work != r.iterations * g.n  # the old buggy formula


def test_sharded_result_reports_halo_field():
    g = GRAPHS["grid"]()
    r = color_distributed(g)
    assert isinstance(r, ColoringResult)
    assert r.halo_bytes_per_step >= 0
    # one device: both all-gather operands are the device's own — the halo
    # field still reports the (trivial) exchanged buffer, bounded well
    # under the old 2 full color arrays per step
    assert r.halo_bytes_per_step < 8 * g.n
    # single-device engines report 0
    assert color_data_driven(g).halo_bytes_per_step == 0


# --------------------------------------------------------------------------
# api plumbing + error paths (satellite: registry/engine error-path tests)
# --------------------------------------------------------------------------

def test_api_engine_sharded_reachable_and_falls_back():
    g = GRAPHS["er"]()
    r = repro.color(g, "data_driven", engine="sharded")
    base = color_data_driven(g)
    assert (r.colors == base.colors).all()  # 1 device: ragged fallback


def test_api_engine_sharded_unknown_heuristic_matches_ragged_error():
    g = GRAPHS["grid"]()
    with pytest.raises(ValueError) as exc_ragged:
        repro.color(g, "data_driven", engine="ragged", heuristic="nope")
    with pytest.raises(ValueError) as exc_sharded:
        repro.color(g, "data_driven", engine="sharded", heuristic="nope")
    # the sharded entry point raises the SAME message as the ragged path
    with pytest.raises(ValueError) as exc_direct:
        color_distributed(g, heuristic="nope")
    assert str(exc_sharded.value) == str(exc_ragged.value)
    assert str(exc_direct.value) == str(exc_ragged.value)
    assert "unknown heuristic" in str(exc_direct.value)


def test_unknown_engine_lists_sharded():
    with pytest.raises(ValueError, match="sharded"):
        color_data_driven(GRAPHS["grid"](), engine="nope")


def test_sharded_rejects_unsupported_schedule_opts():
    """Options the sharded schedule cannot honor raise on ANY device count
    (silently dropping them would make colors depend on the mesh size)."""
    from repro.d2 import color_distance2

    g = GRAPHS["grid"]()
    with pytest.raises(ValueError, match="coarsen"):
        color_data_driven(g, engine="sharded", coarsen_lanes=32)
    with pytest.raises(ValueError, match="coarsen"):
        color_data_driven(g, engine="sharded", coarsen_ff=2)
    with pytest.raises(ValueError, match="use_kernel"):
        color_data_driven(g, engine="sharded", use_kernel=True)
    with pytest.raises(ValueError, match="coarsen"):
        color_distance2(g, engine="sharded", coarsen=2)
    with pytest.raises(ValueError, match="use_kernel"):
        color_distance2(g, engine="sharded", use_kernel=True)
    with pytest.raises(ValueError, match="devices"):
        repro.color_batch([g], algorithm="fused", devices=[object()])


def test_d2_and_bipartite_engine_validation():
    from repro.d2 import color_bipartite, color_distance2

    g = GRAPHS["grid"]()
    with pytest.raises(ValueError, match="unknown engine"):
        color_distance2(g, engine="nope")
    with pytest.raises(ValueError, match="unknown engine"):
        color_bipartite(_bipartite(), engine="nope")
    # sharded on one device falls back to the ragged engine, bit-identical
    r = color_distance2(g, engine="sharded")
    base = color_distance2(g)
    assert (r.colors == base.colors).all()


def test_color_batch_engine_validation():
    graphs = [GRAPHS["er"](), GRAPHS["grid"]()]
    with pytest.raises(ValueError, match="unknown batch engine"):
        repro.color_batch(graphs, algorithm="fused", engine="nope")
    base = repro.color_batch(graphs, algorithm="fused")
    sh = repro.color_batch(graphs, algorithm="fused", engine="sharded")
    for rb, rs in zip(base, sh):
        assert (rb.colors == rs.colors).all()  # 1 device: same batched path


# --------------------------------------------------------------------------
# TwoHopRows over a PartitionedCSR shard (host-checkable slicing identity)
# --------------------------------------------------------------------------

def test_twohop_rows_shard_offset_matches_full():
    import jax.numpy as jnp

    from repro.d2.coloring import TwoHopRows

    g = GRAPHS["grid"]()
    plan = PartitionedCSR.from_graph(g, 3, boundary_mode="two_hop")
    adj_np = g.padded_adjacency()
    full = TwoHopRows(jnp.asarray(adj_np), jnp.asarray(adj_np))
    sliced = plan.stack_rows(adj_np, fill=g.n)
    for d in range(plan.ndev):
        s, e = int(plan.starts[d]), int(plan.starts[d + 1])
        if e == s:
            continue
        shard = TwoHopRows(jnp.asarray(sliced[d]), jnp.asarray(adj_np),
                           start=s, n_colored=g.n)
        ids = jnp.asarray(
            np.concatenate([np.arange(s, e, dtype=np.int32)[:8], [g.n]]))
        assert (np.asarray(shard.rows(ids)) == np.asarray(full.rows(ids))).all()
