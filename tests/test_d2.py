"""Distance-2 & bipartite partial coloring engine (repro.d2, DESIGN.md §11)."""
import numpy as np
import pytest

import repro
from repro import api
from repro.core import ColoringResult, csr_from_edges, is_valid_coloring
from repro.core.batch import GraphBatch
from repro.d2 import (
    BipartiteGraph,
    color_bipartite,
    color_distance2,
    compress_jacobian_pattern,
    greedy_serial_bipartite,
    greedy_serial_d2,
    validate_bipartite,
    validate_d2,
)
from repro.graphs import (
    build_suite,
    erdos_renyi,
    grid2d,
    jacobian_band,
    jacobian_tall_skinny,
    power_law,
    road,
)

FIXTURES = {
    "er": lambda: erdos_renyi(300, 6.0, seed=0),
    "grid": lambda: grid2d(12, 15),
    "powerlaw": lambda: power_law(300, 5.0, seed=1),
    "road": lambda: road(250, seed=2),
}


# --------------------------------------------------------------------------
# host-side two-hop machinery (core/csr.py)
# --------------------------------------------------------------------------

def _brute_square_lists(g):
    out = []
    for v in range(g.n):
        s = set()
        for u in g.neighbors(v):
            s.add(int(u))
            s.update(int(w) for w in g.neighbors(u))
        s.discard(v)
        out.append(sorted(s))
    return out


@pytest.mark.parametrize("gname", list(FIXTURES))
def test_square_matches_bruteforce(gname):
    g = FIXTURES[gname]()
    g2 = g.square()
    assert [g2.neighbors(v).tolist() for v in range(g.n)] == _brute_square_lists(g)
    assert g.two_hop_degree_bound() >= g2.max_degree


def test_square_edge_cases():
    empty = csr_from_edges(0, np.zeros(0, int), np.zeros(0, int))
    assert empty.square().n == 0
    edgeless = csr_from_edges(5, np.zeros(0, int), np.zeros(0, int))
    assert edgeless.square().m == 0
    assert edgeless.two_hop_degree_bound() == 0


def test_padded_adjacency_rejects_silent_truncation():
    g = FIXTURES["er"]()
    with pytest.raises(ValueError, match="allow_truncate"):
        g.padded_adjacency(g.max_degree - 1)
    adj = g.padded_adjacency(g.max_degree - 1, allow_truncate=True)
    assert adj.shape == (g.n, g.max_degree - 1)
    # full-width and wider calls are unaffected
    assert g.padded_adjacency().shape[1] == g.max_degree
    assert g.padded_adjacency(g.max_degree + 4).shape[1] == g.max_degree + 4


# --------------------------------------------------------------------------
# validate_d2 (independent of engine and oracle)
# --------------------------------------------------------------------------

def test_validate_d2_semantics():
    # path 0-1-2: [1,2,1] is a proper distance-1 coloring but NOT distance-2
    g = csr_from_edges(3, np.array([0, 1]), np.array([1, 2]))
    assert is_valid_coloring(g, np.array([1, 2, 1]))
    assert not validate_d2(g, np.array([1, 2, 1]))
    assert validate_d2(g, np.array([1, 2, 3]))
    assert not validate_d2(g, np.array([1, 0, 2]))  # uncolored vertex


# --------------------------------------------------------------------------
# the distance-2 engine
# --------------------------------------------------------------------------

def test_distance2_registered():
    assert "distance2" in api.algorithms()
    assert "bipartite" in api.algorithms()


@pytest.mark.parametrize("gname", list(FIXTURES))
def test_distance2_valid_and_near_oracle(gname):
    g = FIXTURES[gname]()
    r = api.color(g, algorithm="distance2")
    assert isinstance(r, ColoringResult)
    assert r.converged
    assert validate_d2(g, r.colors)
    oracle = greedy_serial_d2(g)
    assert validate_d2(g, oracle)
    assert r.num_colors <= int(oracle.max()) + 1


def test_distance2_full_suite_quality():
    """Acceptance: every suite graph, valid D2 and <= serial oracle + 1."""
    for name, g in build_suite(0.005).items():
        r = color_distance2(g, mode="fused")
        assert r.converged, name
        assert validate_d2(g, r.colors), name
        oracle = greedy_serial_d2(g)
        assert r.num_colors <= int(oracle.max()) + 1, (
            name, r.num_colors, int(oracle.max()))


def test_distance2_strategies_bit_identical():
    for gname in ("er", "grid", "road"):
        g = FIXTURES[gname]()
        pre = color_distance2(g, strategy="precomputed")
        fly = color_distance2(g, strategy="onthefly")
        assert (pre.colors == fly.colors).all(), gname
        assert pre.iterations == fly.iterations, gname


def test_distance2_modes_agree():
    g = FIXTURES["powerlaw"]()
    we = color_distance2(g, mode="workefficient")
    fu = color_distance2(g, mode="fused")
    assert (we.colors == fu.colors).all()
    assert validate_d2(g, fu.colors)


def test_distance2_budget_forces_onthefly():
    g = FIXTURES["grid"]()
    auto = color_distance2(g, memory_budget=1)  # everything blows 1 byte
    pre = color_distance2(g, strategy="precomputed")
    assert (auto.colors == pre.colors).all()
    assert validate_d2(g, auto.colors)


def test_distance2_onthefly_coarsened():
    g = FIXTURES["er"]()
    base = color_distance2(g, strategy="onthefly")
    coarse = color_distance2(g, strategy="onthefly", coarsen=4)
    assert validate_d2(g, coarse.colors)
    # coarsening changes speculation order, not validity
    assert coarse.converged and base.converged


def test_distance2_kernel_matches_reference_path():
    g = erdos_renyi(150, 4.0, seed=5)
    rk = color_distance2(g, strategy="onthefly", backend="pallas")
    rn = color_distance2(g, strategy="onthefly", backend="jax")
    assert (rk.colors == rn.colors).all()
    assert validate_d2(g, rk.colors)


def test_distance2_empty_and_edgeless():
    empty = csr_from_edges(0, np.zeros(0, int), np.zeros(0, int))
    assert color_distance2(empty).colors.shape == (0,)
    edgeless = csr_from_edges(4, np.zeros(0, int), np.zeros(0, int))
    r = color_distance2(edgeless)
    assert (r.colors == 1).all() and r.converged


# --------------------------------------------------------------------------
# batched D2 (core/batch.py d2 path)
# --------------------------------------------------------------------------

def test_batched_d2_bit_identical_to_fused():
    graphs = [FIXTURES[k]() for k in FIXTURES]
    results = repro.color_batch(graphs, algorithm="distance2")
    assert len(results) == len(graphs)
    for g, rb in zip(graphs, results):
        assert rb.algorithm == "batched_fused_sgr_d2"
        assert validate_d2(g, rb.colors)
        single = color_distance2(g, mode="fused", strategy="precomputed")
        assert (rb.colors == single.colors).all()
        assert rb.iterations == single.iterations


def test_batched_d2_packing_uses_square_and_original_degrees():
    graphs = [FIXTURES["er"](), FIXTURES["grid"]()]
    batch = GraphBatch.from_graphs(graphs, distance2=True)
    n_max = max(g.n for g in graphs)
    for b, g in enumerate(graphs):
        g2 = g.square()
        adj = np.asarray(batch.adj[b])
        nb = g2.neighbors(0)
        assert (adj[0, : nb.size] == nb).all()
        assert (adj[0, nb.size:] == n_max).all()
        assert (np.asarray(batch.deg_ext[b, : g.n]) == g.degrees).all()


def test_color_batch_distance2_rejects_unsupported_opts():
    with pytest.raises(ValueError, match="not supported"):
        repro.color_batch([FIXTURES["er"]()], algorithm="distance2", coarsen=2)


def test_color_batch_fused_rejects_mismatched_packing():
    from repro.core.batch import color_batch_fused

    d1_batch = GraphBatch.from_graphs([FIXTURES["grid"]()])
    with pytest.raises(ValueError, match="packed with distance2=False"):
        color_batch_fused(d1_batch, distance2=True)
    d2_batch = GraphBatch.from_graphs([FIXTURES["grid"]()], distance2=True)
    with pytest.raises(ValueError, match="packed with distance2=True"):
        color_batch_fused(d2_batch)
    # a correctly-flagged pre-packed batch goes through
    (r,) = color_batch_fused(d2_batch, distance2=True)
    assert validate_d2(FIXTURES["grid"](), r.colors)


# --------------------------------------------------------------------------
# bipartite partial coloring / Jacobian compression
# --------------------------------------------------------------------------

def test_bipartite_graph_construction():
    pattern = np.array([[1, 1, 0], [0, 1, 1]], dtype=bool)
    bg = BipartiteGraph.from_dense(pattern)
    assert (bg.n_rows, bg.n_cols, bg.nnz) == (2, 3, 4)
    assert bg.row_to_col.tolist() == [0, 1, 1, 2]
    assert bg.col_to_row.tolist() == [0, 0, 1, 1]
    cg = bg.column_conflict_graph()
    assert cg.neighbors(1).tolist() == [0, 2]  # col 1 conflicts with both
    assert cg.neighbors(0).tolist() == [1]     # cols 0,2 never share a row


def test_bipartite_banded_recovers_optimal():
    """Acceptance: banded Jacobian -> exactly the optimal 2*band+1 groups."""
    for band in (1, 2, 3):
        bg = jacobian_band(60, band=band)
        r = api.color(bg, algorithm="bipartite")
        assert r.converged
        assert validate_bipartite(bg, r.colors)
        assert r.num_colors == 2 * band + 1
        oracle = greedy_serial_bipartite(bg)
        assert int(oracle.max()) == 2 * band + 1


def test_bipartite_strategies_bit_identical():
    bg = jacobian_tall_skinny(400, 24, nnz_per_row=3, seed=1)
    pre = color_bipartite(bg, strategy="precomputed")
    fly = color_bipartite(bg, strategy="onthefly")
    assert (pre.colors == fly.colors).all()
    assert validate_bipartite(bg, pre.colors)
    oracle = greedy_serial_bipartite(bg)
    assert validate_bipartite(bg, oracle)
    assert pre.num_colors <= int(oracle.max()) + 1


def test_compress_jacobian_pattern_end_to_end():
    bg = jacobian_band(50, band=2)
    cr = compress_jacobian_pattern(bg)
    assert cr.num_groups == 5
    # groups partition the columns
    all_cols = np.sort(np.concatenate(cr.groups))
    assert (all_cols == np.arange(bg.n_cols)).all()
    seed = cr.seed_matrix()
    assert seed.shape == (bg.n_cols, 5)
    assert (seed.sum(axis=1) == 1).all()
    # structural orthogonality: each row of J @ seed receives each of its
    # nonzero columns in a distinct group slot (no collisions)
    dense = np.zeros((bg.n_rows, bg.n_cols))
    for r in range(bg.n_rows):
        dense[r, bg.row_to_col[bg.row_offsets[r]: bg.row_offsets[r + 1]]] = 1
    collisions = dense @ seed
    assert collisions.max() == 1


def test_compress_accepts_dense_and_coo():
    pattern = np.array([[1, 1, 0, 0], [0, 1, 1, 0], [0, 0, 1, 1]], bool)
    via_dense = compress_jacobian_pattern(pattern)
    rows, cols = np.nonzero(pattern)
    via_coo = compress_jacobian_pattern((3, 4, rows, cols))
    assert via_dense.num_groups == via_coo.num_groups == 2
    assert (via_dense.coloring.colors == via_coo.coloring.colors).all()


def test_compress_refuses_unconverged_partition():
    bg = jacobian_band(40, band=2)
    # on_fail="raise" keeps the pre-§17 refuse-with-ValueError contract
    with pytest.raises(ValueError, match="did not converge"):
        compress_jacobian_pattern(bg, max_iters=1, on_fail="raise")
    # the default routes the same starved run through the §17 guarantee
    # ladder: a valid partition comes back, flagged on the degradations ledger
    cr = compress_jacobian_pattern(bg, max_iters=1)
    assert validate_bipartite(bg, cr.coloring.colors)
    assert any(d.get("stage") == "ladder" for d in cr.coloring.degradations)


def test_bipartite_empty():
    bg = BipartiteGraph.from_coo(0, 0, np.zeros(0, int), np.zeros(0, int))
    assert color_bipartite(bg).colors.shape == (0,)


# --------------------------------------------------------------------------
# serial oracles
# --------------------------------------------------------------------------

def test_serial_d2_largest_degree_first():
    g = FIXTURES["powerlaw"]()
    nat = greedy_serial_d2(g)
    ldf = greedy_serial_d2(g, order="largest_degree_first")
    assert validate_d2(g, nat) and validate_d2(g, ldf)


def test_serial_bipartite_valid():
    bg = jacobian_tall_skinny(200, 16, nnz_per_row=4, seed=3)
    colors = greedy_serial_bipartite(bg)
    assert validate_bipartite(bg, colors)
