"""Chromatic scheduling: the paper's 'discover concurrency' application."""
import numpy as np

from repro.core import color_data_driven, greedy_serial
from repro.core.scheduling import all_to_all_rounds, phases, schedule_quality
from repro.graphs import erdos_renyi


def test_phases_are_independent_sets():
    g = erdos_renyi(800, 8.0, seed=1)
    colors = color_data_driven(g).colors
    adj = {v: set(g.neighbors(v).tolist()) for v in range(g.n)}
    for phase in phases(colors):
        s = set(phase.tolist())
        for v in s:
            assert not (adj[v] & s), "phase contains adjacent vertices"


def test_phases_cover_all_vertices():
    g = erdos_renyi(500, 6.0, seed=2)
    colors = greedy_serial(g)
    total = sum(p.size for p in phases(colors))
    assert total == g.n


def test_schedule_quality_parallelism():
    g = erdos_renyi(1000, 6.0, seed=3)
    sq = schedule_quality(color_data_driven(g).colors)
    # fewer colors -> more parallelism; SGR should expose >= n/(maxdeg+1)
    assert sq["mean_parallelism"] >= g.n / (g.max_degree + 1)


def test_all_to_all_rounds_disjoint():
    """Every round is a matching: no sender or receiver appears twice."""
    P = 6
    rounds = all_to_all_rounds(P)
    seen = set()
    for rnd in rounds:
        senders = [s for s, _ in rnd]
        receivers = [r for _, r in rnd]
        assert len(senders) == len(set(senders))
        assert len(receivers) == len(set(receivers))
        seen.update(rnd)
    # complete all-to-all covered exactly once
    assert seen == {(i, j) for i in range(P) for j in range(P) if i != j}
    # greedy edge coloring lands within 2x of the optimal P-1 rounds
    assert len(rounds) <= 2 * (P - 1) + 1
