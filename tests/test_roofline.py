"""The §15 coloring roofline model (``benchmarks/roofline.py``).

The model turns ``ColoringResult.class_cells`` — per-degree-class gather
cells, fed straight from the engine's work accounting — into bytes moved
and achieved bytes/s.  These tests pin the bytes-per-cell constants on a
hand-countable graph, assert the partition invariant (class cells sum to
``padded_work`` exactly) on real engine runs, and check the peak-fraction
arithmetic the BENCH schema-5 records embed.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import (  # noqa: E402
    BYTES_PER_CELL_CSR,
    BYTES_PER_CELL_PACKED,
    BYTES_PER_CELL_PALLAS,
    BYTES_PER_CELL_SPLIT,
    coloring_roofline,
)
from repro.core import color_data_driven, csr_from_edges  # noqa: E402


def _star(n=9):
    return csr_from_edges(n, np.zeros(n - 1, np.int64),
                          np.arange(1, n, dtype=np.int64))


def test_star_graph_known_bytes():
    """K1,8: one fused bootstrap step, 9 lanes x width-8 tiles = 72 cells.
    8 B/cell packed -> 576 bytes, a number small enough to count by hand."""
    g = _star(9)
    r = color_data_driven(g, mode="fused")
    assert r.class_cells == ((8, 72),)
    rl = coloring_roofline(r)
    assert rl["bytes_per_cell"] == BYTES_PER_CELL_PACKED == 8
    assert rl["bytes_total"] == 576
    assert rl["classes"] == [{"width": 8, "cells": 72,
                              "bytes_per_cell": 8, "bytes": 576}]


@pytest.mark.parametrize("mode", ["workefficient", "fused"])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_class_cells_partition_padded_work(mode, backend):
    """Invariant: the per-class cells PARTITION the engine's padded_work —
    the roofline model accounts for every gather cell exactly once."""
    rng = np.random.default_rng(5)
    src = rng.integers(0, 400, 2400)
    dst = rng.integers(0, 400, 2400)
    g = csr_from_edges(400, src[src != dst], dst[src != dst])
    r = color_data_driven(g, mode=mode, backend=backend)
    assert r.class_cells, (mode, backend)
    assert sum(c for _, c in r.class_cells) == r.padded_work
    assert all(w > 0 and c > 0 for w, c in r.class_cells)


def test_roofline_rates_and_peak_fraction():
    r = coloring_roofline(((8, 72),), seconds=1e-6, peak_bytes_per_s=819e9)
    assert r["achieved_bytes_per_s"] == pytest.approx(576e6)
    assert r["frac_of_peak"] == pytest.approx(576e6 / 819e9)
    assert r["classes"][0]["achieved_bytes_per_s"] == pytest.approx(576e6)
    # no seconds -> static bytes only, no rate keys
    dry = coloring_roofline(((8, 72),))
    assert "achieved_bytes_per_s" not in dry and "frac_of_peak" not in dry


def test_packed_vs_split_cell_size():
    packed = coloring_roofline(((8, 72),), packed=True)
    split = coloring_roofline(((8, 72),), packed=False)
    assert split["bytes_per_cell"] == BYTES_PER_CELL_SPLIT == 12
    assert split["bytes_total"] == packed["bytes_total"] * 12 // 8 == 864


def test_mode_knob_cell_sizes():
    """Schema-8 records charge each backend its REAL traffic: the gathered
    pallas path materializes the split tiles in HBM and reads them back
    (2x split = 24 B), the §18 CSR-resident kernel reads id + packed word
    once (8 B).  The mode knob must beat the legacy packed flag and stamp
    per-class bytes_per_cell so the pallas vs pallas-csr delta is visible
    per degree class."""
    pallas = coloring_roofline(((8, 72),), mode="pallas")
    csr = coloring_roofline(((8, 72),), mode="csr")
    assert pallas["bytes_per_cell"] == BYTES_PER_CELL_PALLAS == 24
    assert csr["bytes_per_cell"] == BYTES_PER_CELL_CSR == 8
    assert pallas["mode"] == "pallas" and csr["mode"] == "csr"
    for doc in (pallas, csr):
        for c in doc["classes"]:
            assert c["bytes_per_cell"] == doc["bytes_per_cell"]
            assert c["bytes"] == c["cells"] * c["bytes_per_cell"]
    # mode overrides the legacy packed flag; packed stays the None default
    assert coloring_roofline(((8, 72),), packed=False,
                             mode="csr")["bytes_per_cell"] == 8
    assert coloring_roofline(((8, 72),), packed=False)["mode"] == "split"
    with pytest.raises(ValueError, match="unknown roofline mode"):
        coloring_roofline(((8, 72),), mode="simd")


def test_multiclass_bytes_sum():
    rl = coloring_roofline(((8, 100), (32, 50), (128, 10)), seconds=2.0)
    assert rl["bytes_total"] == sum(c["bytes"] for c in rl["classes"])
    assert rl["bytes_total"] == (100 + 50 + 10) * 8
    assert rl["achieved_bytes_per_s"] == pytest.approx(rl["bytes_total"] / 2.0)
