"""Unified API registry: dispatch, contract, and error behaviour."""
import pytest

import repro
from repro import api
from repro.core import ColoringResult, color_data_driven, is_valid_coloring
from repro.graphs import erdos_renyi, grid2d, power_law

FIXTURES = {
    "er": lambda: erdos_renyi(300, 6.0, seed=0),
    "grid": lambda: grid2d(12, 15),
    "powerlaw": lambda: power_law(300, 5.0, seed=1),
}

EXPECTED = {"serial", "data_driven", "fused", "topology", "jp", "multihash",
            "threestep", "distance2"}


def test_registry_contents():
    assert EXPECTED <= set(api.algorithms())


@pytest.mark.parametrize("gname", list(FIXTURES))
@pytest.mark.parametrize("algorithm", sorted(EXPECTED))
def test_every_algorithm_proper(gname, algorithm):
    g = FIXTURES[gname]()
    r = api.color(g, algorithm=algorithm)
    assert isinstance(r, ColoringResult)
    assert is_valid_coloring(g, r.colors), (gname, algorithm)
    assert r.converged
    assert r.num_colors >= 1


def test_unknown_algorithm_raises():
    g = FIXTURES["er"]()
    with pytest.raises(ValueError, match="unknown algorithm 'nope'"):
        api.color(g, algorithm="nope")
    # the error message lists every registered name
    with pytest.raises(ValueError) as exc:
        api.color(g, algorithm="nope")
    for name in api.algorithms():
        assert name in str(exc.value), name


def test_algorithms_stable_and_sorted():
    names = api.algorithms()
    assert list(names) == sorted(names)
    assert api.algorithms() == names          # repeated calls are stable
    assert {"bipartite", "distance2"} <= set(names)


def test_opts_pass_through():
    g = FIXTURES["er"]()
    base = api.color(g, "data_driven", heuristic="id", firstfit="scan")
    assert is_valid_coloring(g, base.colors)
    ref = color_data_driven(g, heuristic="id", firstfit="scan")
    assert (base.colors == ref.colors).all()


def test_fused_equals_mode_fused():
    g = FIXTURES["powerlaw"]()
    via_api = api.color(g, "fused")
    direct = color_data_driven(g, mode="fused")
    assert (via_api.colors == direct.colors).all()
    assert via_api.iterations == direct.iterations


def test_serial_result_contract():
    g = FIXTURES["grid"]()
    r = api.color(g, "serial")
    assert isinstance(r, ColoringResult)
    assert r.algorithm == "serial_greedy"
    assert r.num_colors <= g.max_degree + 1


def test_top_level_reexports():
    g = FIXTURES["er"]()
    assert set(repro.algorithms()) == set(api.algorithms())
    r = repro.color(g, "serial")
    assert is_valid_coloring(g, r.colors)


def test_color_batch_loop_fallback():
    graphs = [FIXTURES["er"](), FIXTURES["grid"]()]
    results = repro.color_batch(graphs, algorithm="serial")
    assert len(results) == 2
    for g, r in zip(graphs, results):
        assert is_valid_coloring(g, r.colors)


def test_color_batch_rejects_unsupported_fused_opts():
    graphs = [FIXTURES["er"]()]
    with pytest.raises(ValueError, match="coarsen_ff"):
        repro.color_batch(graphs, algorithm="fused", coarsen_ff=2)
    # supported opts still pass through
    results = repro.color_batch(graphs, algorithm="fused", heuristic="id",
                                firstfit="scan")
    assert is_valid_coloring(graphs[0], results[0].colors)


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="registered twice"):
        api.register("serial")(lambda g: None)


def test_register_same_fn_is_idempotent():
    fn = api.get_algorithm("serial")
    assert api.register("serial")(fn) is fn   # re-registering the SAME fn is ok
    assert api.get_algorithm("serial") is fn


def test_color_batch_fused_bad_opts_lists_supported():
    graphs = [FIXTURES["er"]()]
    with pytest.raises(ValueError) as exc:
        repro.color_batch(graphs, algorithm="fused", mode="fused", buckets=(4,))
    msg = str(exc.value)
    for opt in ("heuristic", "firstfit", "backend", "max_iters"):
        assert opt in msg                      # supported options are listed
    assert "buckets" in msg and "mode" in msg  # offending options are named
