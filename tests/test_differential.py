"""Cross-engine × cross-backend differential matrix (DESIGN.md §15).

The §15 contract in one file: every engine realization of the SGR schedule
(classic / ragged / padded / sharded / dynamic-full) must produce
**bit-identical** colors whether its super-step runs through the pure-JAX
formulation (``backend="jax"``) or the fused Pallas kernel
(``backend="pallas"``, interpret mode on CPU), for both the edge
(distance-1) and distance-2 relations, on the full benchmark suite plus the
adversarial shapes that historically break tile/worklist handling (empty
graph, single vertex, star, clique, isolated vertices, degrees exactly at a
tile threshold).  Every pallas result is additionally validated outright,
so a backend that "agrees" by being wrong the same way still has to be a
proper coloring.
"""
import functools

import numpy as np
import pytest

from repro.api import open_session
from repro.core import (
    CSRGraph,
    color_data_driven,
    csr_from_edges,
    is_valid_coloring,
)
from repro.d2 import color_distance2, validate_d2
from repro.graphs import build_graph

SUITE = ("rmat-er", "rmat-g", "G3_circuit", "europe.osm", "thermal2")
SUITE_SCALE = 0.01


def _star(n=9):
    return csr_from_edges(n, np.zeros(n - 1, np.int64),
                          np.arange(1, n, dtype=np.int64))


def _clique(k=9):
    src, dst = np.triu_indices(k, 1)
    return csr_from_edges(k, src, dst)


def _isolated():
    # 12 vertices, edges only among the first 6 — the tail must stay color 1
    rng = np.random.default_rng(3)
    src = rng.integers(0, 6, 20)
    dst = rng.integers(0, 6, 20)
    return csr_from_edges(12, src, dst)


def _threshold():
    # degrees exactly AT the explicit tile thresholds (4, 8): two disjoint
    # cliques K5 (degree 4) and K9 (degree 8) — every vertex sits on a
    # class boundary, the off-by-one hotspot of the tiled dispatch
    s5, d5 = np.triu_indices(5, 1)
    s9, d9 = np.triu_indices(9, 1)
    src = np.concatenate([s5, s9 + 5])
    dst = np.concatenate([d5, d9 + 5])
    return csr_from_edges(14, src, dst)


ADVERSARIAL = {
    "empty": lambda: CSRGraph(np.zeros(1, np.int64), np.zeros(0, np.int32)),
    "single": lambda: CSRGraph(np.zeros(2, np.int64), np.zeros(0, np.int32)),
    "star": _star,
    "clique": _clique,
    "isolated": _isolated,
    "threshold": _threshold,
}


@functools.lru_cache(maxsize=None)
def _graph(name: str) -> CSRGraph:
    if name in ADVERSARIAL:
        return ADVERSARIAL[name]()
    return build_graph(name, SUITE_SCALE)


ALL_GRAPHS = list(SUITE) + list(ADVERSARIAL)

EDGE_ENGINES = ("classic", "ragged", "padded", "sharded", "dynamic-full")
D2_ENGINES = ("ragged", "sharded")


def _edge_color(g: CSRGraph, engine: str, backend: str, trace: bool = False):
    if engine == "dynamic-full":
        # the dynamic engine's bit-identity surface: cold session coloring,
        # a deterministic delta, then the full-recolor escape hatch — all
        # three route through the ragged fused engine with the backend
        session = open_session(g, backend=backend, trace=trace)
        if g.n >= 2:
            rng = np.random.default_rng(7)
            k = max(1, g.n // 100)
            src = rng.integers(0, g.n, k)
            dst = rng.integers(0, g.n, k)
            keep = src != dst
            session.apply_delta(add_edges=(src[keep], dst[keep]))
            if session.frontier().size:
                session.recolor()
            return session.recolor(full=True), session.graph
        return session.result, g
    opts = {"engine": engine, "backend": backend, "trace": trace}
    if engine == "ragged":
        opts["mode"] = "fused"
    return color_data_driven(g, **opts), g


@pytest.mark.parametrize("engine", EDGE_ENGINES)
@pytest.mark.parametrize("gname", ALL_GRAPHS)
def test_edge_matrix_backends_bit_identical(gname, engine):
    g = _graph(gname)
    r_jax, g_jax = _edge_color(g, engine, "jax")
    r_pal, g_pal = _edge_color(g, engine, "pallas")
    np.testing.assert_array_equal(r_jax.colors, r_pal.colors)
    assert r_jax.iterations == r_pal.iterations, (gname, engine)
    assert r_jax.converged and r_pal.converged
    assert is_valid_coloring(g_pal, r_pal.colors), (gname, engine)
    assert is_valid_coloring(g_jax, r_jax.colors), (gname, engine)


@pytest.mark.parametrize("engine", D2_ENGINES)
@pytest.mark.parametrize("gname", ALL_GRAPHS)
def test_distance2_matrix_backends_bit_identical(gname, engine):
    g = _graph(gname)
    r_jax = color_distance2(g, engine=engine, backend="jax")
    r_pal = color_distance2(g, engine=engine, backend="pallas")
    np.testing.assert_array_equal(r_jax.colors, r_pal.colors)
    assert r_jax.iterations == r_pal.iterations, (gname, engine)
    assert r_jax.converged and r_pal.converged
    assert validate_d2(g, r_pal.colors), (gname, engine)


@pytest.mark.parametrize("gname", ["threshold", "rmat-g"])
def test_explicit_buckets_backends_bit_identical(gname):
    """Degree classes pinned exactly at (4, 8): per-class kernel tiles with
    W == threshold must agree with pure-JAX lane arithmetic on the boundary."""
    g = _graph(gname)
    for engine in ("ragged", "padded"):
        r_jax = color_data_driven(g, engine=engine, buckets=(4, 8),
                                  backend="jax")
        r_pal = color_data_driven(g, engine=engine, buckets=(4, 8),
                                  backend="pallas")
        np.testing.assert_array_equal(r_jax.colors, r_pal.colors)
        assert r_jax.iterations == r_pal.iterations, (gname, engine)
        assert is_valid_coloring(g, r_pal.colors)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("engine", EDGE_ENGINES)
@pytest.mark.parametrize("gname", ["rmat-g", "threshold"])
def test_trace_on_is_bit_identical_and_coherent(gname, engine, backend):
    """§16 zero-perturbation contract across the engine × backend matrix:
    ``trace=True`` changes nothing about the coloring (same colors, same
    iteration count) and the attached ``RunTrace`` passes its structural
    invariants on every engine realization."""
    from repro.obs import RunTrace

    g = _graph(gname)
    r_off, _ = _edge_color(g, engine, backend)
    r_on, g_on = _edge_color(g, engine, backend, trace=True)
    np.testing.assert_array_equal(r_off.colors, r_on.colors)
    assert r_off.iterations == r_on.iterations, (gname, engine, backend)
    assert r_off.trace is None
    assert isinstance(r_on.trace, RunTrace), (gname, engine, backend)
    assert r_on.trace.check(r_on) == [], (gname, engine, backend,
                                          r_on.trace.check(r_on))
    assert is_valid_coloring(g_on, r_on.colors)


@pytest.mark.parametrize("engine", D2_ENGINES)
def test_trace_on_distance2_bit_identical(engine):
    g = _graph("rmat-g")
    r_off = color_distance2(g, engine=engine)
    r_on = color_distance2(g, engine=engine, trace=True)
    np.testing.assert_array_equal(r_off.colors, r_on.colors)
    assert r_off.iterations == r_on.iterations
    assert r_off.trace is None and r_on.trace is not None
    assert r_on.trace.check(r_on) == []


# §18: the CSR-resident kernel column of the matrix.  classic exercises the
# gathered-kernel fallback (dense two-phase tiles), ragged the CSR kernel
# proper (fused mode, on-device tail), dynamic-full the session path with
# pow2-padded worklists — suite + adversarial, all bit-identical + validated.
CSR_ENGINES = ("classic", "ragged", "dynamic-full")


@pytest.mark.parametrize("engine", CSR_ENGINES)
@pytest.mark.parametrize("gname", ALL_GRAPHS)
def test_edge_matrix_pallas_csr_bit_identical(gname, engine):
    g = _graph(gname)
    r_jax, g_jax = _edge_color(g, engine, "jax")
    r_csr, g_csr = _edge_color(g, engine, "pallas-csr")
    np.testing.assert_array_equal(r_jax.colors, r_csr.colors)
    assert r_jax.iterations == r_csr.iterations, (gname, engine)
    assert r_jax.converged and r_csr.converged
    assert is_valid_coloring(g_csr, r_csr.colors), (gname, engine)


@pytest.mark.parametrize("gname", ["rmat-g", "threshold"])
def test_pallas_csr_equals_pallas(gname):
    """Direct pallas vs pallas-csr agreement (the §18 acceptance bar as
    stated: bit-identity to BOTH the gathered kernel and pure JAX)."""
    g = _graph(gname)
    r_pal, _ = _edge_color(g, "ragged", "pallas")
    r_csr, _ = _edge_color(g, "ragged", "pallas-csr")
    np.testing.assert_array_equal(r_pal.colors, r_csr.colors)
    assert r_pal.iterations == r_csr.iterations


@pytest.mark.parametrize("gname", ["rmat-g", "threshold"])
def test_distance2_pallas_csr_bit_identical(gname):
    """d2 precomputed strategy squares the graph into a DeviceCSR, so the
    CSR kernel engages; on-the-fly two-hop rows fall back to the gathered
    kernel — either way colors must match pure JAX bit for bit."""
    g = _graph(gname)
    for strategy in ("precomputed", "onthefly"):
        r_jax = color_distance2(g, backend="jax", strategy=strategy)
        r_csr = color_distance2(g, backend="pallas-csr", strategy=strategy)
        np.testing.assert_array_equal(r_jax.colors, r_csr.colors)
        assert r_jax.iterations == r_csr.iterations, (gname, strategy)
        assert validate_d2(g, r_csr.colors), (gname, strategy)


def test_pallas_equals_legacy_use_kernel():
    """backend='pallas' IS the use_kernel path — same results, new spelling.

    The old spelling stays one release as a shim (§19) and must warn."""
    g = _graph("rmat-er")
    new = color_data_driven(g, backend="pallas")
    with pytest.deprecated_call(match="use_kernel"):
        old = color_data_driven(g, use_kernel=True)
    np.testing.assert_array_equal(new.colors, old.colors)
    assert new.iterations == old.iterations


def test_backend_option_surface():
    g = _graph("star")
    with pytest.raises(ValueError, match="contradicts"):
        color_data_driven(g, backend="jax", use_kernel=True)
    with pytest.raises(ValueError, match="unknown backend"):
        color_data_driven(g, backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        color_distance2(g, backend="cuda")
    # auto resolves to a concrete backend on any platform
    r = color_data_driven(g, backend="auto")
    assert is_valid_coloring(g, r.colors)
    # pallas-csr is a first-class backend name everywhere backend= is taken
    r = color_data_driven(g, backend="pallas-csr")
    assert is_valid_coloring(g, r.colors)
    r2 = color_distance2(g, backend="pallas-csr")
    assert validate_d2(g, r2.colors)


# --------------------------------------------------------------------------
# §17 malformed-CSR corpus through the matrix (the ingest front door is the
# only thing standing between these inputs and silent garbage colorings)
# --------------------------------------------------------------------------

from repro import api  # noqa: E402
from repro.faultlab import ADVERSARIAL_GRAPHS  # noqa: E402
from repro.ingest import IngestError, sanitize_csr  # noqa: E402

MALFORMED = [k for k in ADVERSARIAL_GRAPHS if k != "empty"]
INGEST_ENGINES = ("classic", "ragged", "sharded", "dynamic-full")


@pytest.mark.parametrize("name", MALFORMED)
def test_malformed_strict_raises_structured(name):
    off, col = ADVERSARIAL_GRAPHS[name]
    with pytest.raises(IngestError) as ei:
        sanitize_csr(off.copy(), col.copy(), policy="strict")
    assert ei.value.report.issues, name
    assert not ei.value.report.ok
    # and through the api front door on a constructible CSRGraph
    g = CSRGraph(off.copy(), col.copy())
    with pytest.raises(IngestError):
        api.color(g, validate_input="strict")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("engine", INGEST_ENGINES)
@pytest.mark.parametrize("name", list(ADVERSARIAL_GRAPHS))
def test_malformed_repair_bit_identical_to_clean(name, engine, backend):
    """repair-mode coloring of a dirty CSR == coloring its sanitized twin,
    bit for bit, on every engine × backend — the repair path may not perturb
    the deterministic schedule."""
    off, col = ADVERSARIAL_GRAPHS[name]
    clean, report = sanitize_csr(off.copy(), col.copy(), policy="repair")
    dirty = CSRGraph(off.copy(), col.copy())
    if engine == "dynamic-full":
        s_dirty = open_session(dirty, backend=backend,
                               validate_input="repair")
        s_clean = open_session(clean, backend=backend)
        r_dirty, r_clean = s_dirty.result, s_clean.result
        gv = s_dirty.graph
    else:
        r_dirty = api.color(dirty, validate_input="repair", engine=engine,
                            backend=backend)
        r_clean = api.color(clean, engine=engine, backend=backend)
        gv = clean
    np.testing.assert_array_equal(r_dirty.colors, r_clean.colors)
    assert is_valid_coloring(gv, r_dirty.colors), (name, engine, backend)
    if name != "empty":
        assert report.repairs, name  # something was actually repaired


@pytest.mark.parametrize("name", list(ADVERSARIAL_GRAPHS))
def test_malformed_repair_records_degradations(name):
    off, col = ADVERSARIAL_GRAPHS[name]
    g = CSRGraph(off.copy(), col.copy())
    r = api.color(g, validate_input="repair")
    stages = {d["stage"] for d in r.degradations}
    if name == "empty":
        assert r.degradations == ()
    else:
        assert stages == {"ingest_repair"}, (name, r.degradations)
