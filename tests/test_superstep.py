"""Ragged CSR-native super-step engine (DESIGN.md §12).

Covers the fused superstep Pallas kernel against its independent pure-jnp
ref, the padded/ragged engine bit-identity contract, adaptive
tail-serialization, the CSR-native storage, and the satellite regressions
(``reuse_rows`` forwarding, ``coarsen_lanes`` chunk derivation).
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

import jax

import repro.core.coloring as C
from repro.core import (
    DeviceCSR,
    auto_tile_thresholds,
    color_data_driven,
    csr_from_edges,
    is_valid_coloring,
    num_colors,
)
from repro.core.serial import greedy_serial
from repro.graphs import build_graph, erdos_renyi, grid2d, power_law, rmat
from repro.kernels.superstep.csr_kernel import (
    serial_tail_csr_tpu,
    superstep_csr_tpu,
)
from repro.kernels.superstep.ops import superstep_tpu
from repro.kernels.superstep.ref import superstep_ref

GRAPHS = {
    "er": lambda: erdos_renyi(900, 7.0, seed=11),
    "grid": lambda: grid2d(25, 30),
    "rmat-g": lambda: rmat(1200, 9.0, seed=12),
    "powerlaw": lambda: power_law(900, 6.0, seed=13),
}


# --------------------------------------------------------------------------
# fused superstep kernel vs its independent ref (acceptance: bit-identical)
# --------------------------------------------------------------------------

SHAPES = [(7, 3), (8, 8), (64, 16), (100, 33), (256, 64), (33, 130), (512, 5)]


def _random_tile(w, W, seed):
    rng = np.random.default_rng(seed)
    ids = rng.permutation(w + 3)[:w].astype(np.int32)
    nid = rng.integers(0, w + 3, size=(w, W)).astype(np.int32)
    my_c = rng.integers(0, W + 2, size=(w,)).astype(np.int32)
    nc = rng.integers(0, W + 2, size=(w, W)).astype(np.int32)
    my_d = rng.integers(0, 9, size=(w,)).astype(np.int32)
    nd = rng.integers(0, 9, size=(w, W)).astype(np.int32)
    return tuple(map(jnp.asarray, (ids, nid, my_c, nc, my_d, nd)))


@pytest.mark.parametrize("w,W", SHAPES)
@pytest.mark.parametrize("heuristic", ["id", "degree"])
def test_superstep_kernel_matches_ref(w, W, heuristic):
    args = _random_tile(w, W, seed=w * 1000 + W)
    got_c, got_n = superstep_tpu(*args, heuristic)
    want_c, want_n = superstep_ref(*args, heuristic)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))


@pytest.mark.parametrize("block_n", [8, 16, 128])
def test_superstep_kernel_block_sizes(block_n):
    args = _random_tile(200, 17, seed=5)
    got_c, got_n = superstep_tpu(*args, "degree", block_n=block_n)
    want_c, want_n = superstep_ref(*args, "degree")
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))


def test_superstep_kernel_empty():
    c, n = superstep_tpu(*[jnp.zeros(s, jnp.int32) for s in
                           [(0,), (0, 4), (0,), (0, 4), (0,), (0, 4)]])
    assert c.shape == (0,) and n.shape == (0,)


def test_superstep_kernel_semantics():
    """Winner keeps; loser refits treating beaten neighbors as cleared."""
    # two adjacent vertices, both color 1; degree rule: larger degree keeps
    ids = jnp.asarray([0, 1], jnp.int32)
    nid = jnp.asarray([[1], [0]], jnp.int32)
    my_c = jnp.asarray([1, 1], jnp.int32)
    nc = jnp.asarray([[1], [1]], jnp.int32)
    my_d = jnp.asarray([5, 2], jnp.int32)
    nd = jnp.asarray([[2], [5]], jnp.int32)
    newc, need = superstep_tpu(ids, nid, my_c, nc, my_d, nd, "degree")
    np.testing.assert_array_equal(np.asarray(need), [False, True])
    # vertex 0 (winner) keeps 1; vertex 1 must avoid the winner's color
    np.testing.assert_array_equal(np.asarray(newc), [1, 2])


@pytest.mark.parametrize("W", [31, 32, 63, 64])
def test_superstep_kernel_nwords_boundary(W):
    """Every color 1..W forbidden forces FirstFit to W+1 — the bit that
    lives exactly at (or one past) a 32-bit bitset word boundary, where an
    off-by-one in ``nwords = (W + 1 + 31) // 32`` would truncate."""
    w = 4
    ids = jnp.arange(w, dtype=jnp.int32)
    nid = jnp.broadcast_to(jnp.arange(w, w + W, dtype=jnp.int32), (w, W))
    my_c = jnp.zeros(w, jnp.int32)  # uncolored: must FirstFit
    nc = jnp.broadcast_to(jnp.arange(1, W + 1, dtype=jnp.int32), (w, W))
    my_d = jnp.full(w, W, jnp.int32)
    nd = jnp.full((w, W), W, jnp.int32)
    got_c, got_n = superstep_tpu(ids, nid, my_c, nc, my_d, nd, "degree")
    want_c, want_n = superstep_ref(ids, nid, my_c, nc, my_d, nd, "degree")
    np.testing.assert_array_equal(np.asarray(got_c), np.full(w, W + 1))
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))


@pytest.mark.parametrize("heuristic", ["id", "degree"])
def test_superstep_kernel_all_conflict_worklist(heuristic):
    """A monochromatic clique tile: every lane conflicts with every other.
    Equal degrees leave a single total-order winner — the largest id under
    the "id" rule, the smallest under "degree"'s id tiebreak — who alone
    keeps color 1 while every loser refits around the winners it lost to."""
    k = 9
    ids = jnp.arange(k, dtype=jnp.int32)
    nid = jnp.asarray(
        [[v for v in range(k) if v != u] for u in range(k)], jnp.int32)
    my_c = jnp.ones(k, jnp.int32)
    nc = jnp.ones((k, k - 1), jnp.int32)
    my_d = jnp.full(k, k - 1, jnp.int32)
    nd = jnp.full((k, k - 1), k - 1, jnp.int32)
    got_c, got_n = superstep_tpu(ids, nid, my_c, nc, my_d, nd, heuristic)
    want_c, want_n = superstep_ref(ids, nid, my_c, nc, my_d, nd, heuristic)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))
    winner = k - 1 if heuristic == "id" else 0
    need = np.asarray(got_n)
    assert not need[winner] and need.sum() == k - 1
    assert int(got_c[winner]) == 1
    # losers all refit to 2: beaten neighbors' colors are not forbidden
    losers = np.asarray(got_c)[np.arange(k) != winner]
    np.testing.assert_array_equal(losers, np.full(k - 1, 2))


def test_superstep_kernel_worklist_smaller_than_block():
    """w < block_n: the grid pads the worklist axis; padding lanes must not
    corrupt the live ones nor the returned shapes."""
    args = _random_tile(3, 12, seed=21)
    for block_n in (8, 64, 256):
        got_c, got_n = superstep_tpu(*args, "degree", block_n=block_n)
        want_c, want_n = superstep_ref(*args, "degree")
        assert got_c.shape == (3,) and got_n.shape == (3,)
        np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
        np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))


def test_kernel_backend_matches_pure_jax_engine():
    g = GRAPHS["er"]()
    for mode in ("workefficient", "fused"):
        plain = color_data_driven(g, mode=mode)
        kern = color_data_driven(g, mode=mode, backend="pallas")
        assert (plain.colors == kern.colors).all(), mode
        assert plain.iterations == kern.iterations


# --------------------------------------------------------------------------
# engine bit-identity: ragged == padded == fused, tiled == untiled
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", list(GRAPHS))
def test_padded_and_ragged_engines_bit_identical(gname):
    g = GRAPHS[gname]()
    base = color_data_driven(g)
    assert is_valid_coloring(g, base.colors)
    assert base.converged
    for opts in (
        dict(engine="padded"),
        dict(engine="padded", mode="fused"),
        dict(mode="fused"),
        dict(tiling=None),
        dict(buckets=(8, 32)),
        dict(engine="padded", buckets=(8, 32)),
    ):
        r = color_data_driven(g, **opts)
        assert (r.colors == base.colors).all(), (gname, opts)
        assert r.iterations == base.iterations, (gname, opts)


def test_padded_work_counts_gather_cells():
    """Satellite: padded_work is lanes × tile width, so the ragged engine's
    bandwidth saving on skewed graphs is visible in the accounting."""
    g = GRAPHS["powerlaw"]()
    ragged = color_data_driven(g, buckets=(8, 32), tail_serial=None)
    padded = color_data_driven(g, buckets=(8, 32), engine="padded",
                               tail_serial=None)
    assert (ragged.colors == padded.colors).all()
    # identical schedule, but the ragged engine touches far fewer cells
    assert ragged.padded_work < padded.padded_work / 2


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        color_data_driven(GRAPHS["grid"](), engine="nope")


# --------------------------------------------------------------------------
# adaptive tail-serialization
# --------------------------------------------------------------------------

def test_tail_serialization_collapses_cascades():
    """Acceptance: >=3x fewer super-steps on the cascading circuit graphs."""
    for name in ("G3_circuit", "thermal2"):
        g = build_graph(name, 0.01)
        tail = color_data_driven(g)
        free = color_data_driven(g, tail_serial=None)
        assert is_valid_coloring(g, tail.colors), name
        assert tail.converged
        assert tail.iterations * 3 <= free.iterations, (
            name, tail.iterations, free.iterations)
        # quality stays within +1 of the serial greedy oracle on cascades
        assert tail.num_colors <= num_colors(greedy_serial(g)) + 1, name


def test_tail_disabled_still_converges():
    g = GRAPHS["er"]()
    r = color_data_driven(g, tail_serial=None)
    assert r.converged and is_valid_coloring(g, r.colors)


def test_explicit_tail_threshold():
    g = GRAPHS["er"]()
    r = color_data_driven(g, tail_serial=g.n + 1)  # serialize everything
    assert r.converged and is_valid_coloring(g, r.colors)
    assert r.iterations <= 2  # bootstrap + one serial pass


def test_tail_modes_and_engines_agree():
    g = build_graph("thermal2", 0.01)  # stall-triggered tail
    base = color_data_driven(g)
    for opts in (dict(mode="fused"), dict(engine="padded"),
                 dict(engine="padded", mode="fused")):
        r = color_data_driven(g, **opts)
        assert (r.colors == base.colors).all(), opts
        assert r.iterations == base.iterations, opts


# --------------------------------------------------------------------------
# CSR-native storage
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", list(GRAPHS))
def test_device_csr_gather_matches_padded_adjacency(gname):
    g = GRAPHS[gname]()
    dcsr = DeviceCSR.from_csr(g)
    W = max(g.max_degree, 1)
    ids = np.asarray([0, 1, g.n // 2, g.n - 1, g.n], np.int32)  # incl sentinel
    got = np.asarray(dcsr.gather_rows(jnp.asarray(ids), W))
    dense = g.padded_adjacency(W)
    want = np.concatenate([dense[ids[:-1]], np.full((1, W), g.n, np.int32)])
    np.testing.assert_array_equal(got, want)
    for v in ids:
        np.testing.assert_array_equal(
            np.asarray(dcsr.gather_row1(jnp.int32(v))),
            want[min(int(v), len(ids) - 1)] if v == g.n else dense[v],
        )


def test_auto_tile_thresholds_properties():
    deg = np.concatenate([np.full(5000, 3), np.full(400, 20), np.full(40, 200)])
    ts = auto_tile_thresholds(deg)
    assert ts and list(ts) == sorted(ts)           # ascending log-spaced
    assert all(t >= 8 for t in ts)
    # tiny graphs and flat histograms: single class
    assert auto_tile_thresholds(np.full(100, 50)) == ()
    assert auto_tile_thresholds(np.full(5000, 9)) == ()


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------

def test_classic_fused_forwards_reuse_rows(monkeypatch):
    """Regression: reuse_rows was silently dropped by the classic fused driver."""
    seen = {}
    orig = C.sgr_step

    def spy(*args, **kwargs):
        seen.update(kwargs)
        return orig(*args, **kwargs)

    monkeypatch.setattr(C, "sgr_step", spy)
    g = erdos_renyi(300, 5.0, seed=3)
    r = color_data_driven(g, engine="classic", mode="fused", reuse_rows=True)
    assert seen.get("reuse_rows") is True
    base = color_data_driven(g, engine="classic", mode="fused")
    assert (r.colors == base.colors).all()  # pure perf knob: same colors


@pytest.mark.parametrize("buckets", [(), (8, 32)])
@pytest.mark.parametrize("lanes", [64, 300, 10**6])
def test_coarsen_lanes_derivation(monkeypatch, buckets, lanes):
    """Satellite: coarsen_lanes derives ceil(cap / lanes) chunks per step and
    the derived chunking is bit-identical to the explicit equivalent."""
    recorded = []
    orig = C._tiled_superstep

    def spy(provider, deg_ext, colors_ext, wls, **kw):
        recorded.append((tuple(int(w.shape[0]) for w in wls), kw["chunks"]))
        return orig(provider, deg_ext, colors_ext, wls, **kw)

    monkeypatch.setattr(C, "_tiled_superstep", spy)
    # also patch the jitted wrapper used by the workefficient driver
    monkeypatch.setattr(
        C, "provider_tiled_superstep",
        lambda provider, deg_ext, colors_ext, wls, **kw: spy(
            provider, deg_ext, colors_ext, wls, **kw),
    )
    g = erdos_renyi(700, 6.0, seed=4)
    r = color_data_driven(g, coarsen_lanes=lanes, buckets=buckets)
    assert is_valid_coloring(g, r.colors)
    assert recorded
    for caps, chunks in recorded:
        assert chunks == tuple(max(1, math.ceil(c / lanes)) for c in caps)
    # derived chunking == equivalent explicit coarsen_ff, bit for bit
    if lanes >= 10**6:
        explicit = color_data_driven(g, coarsen_ff=1, buckets=buckets)
        assert (r.colors == explicit.colors).all()
        assert r.iterations == explicit.iterations


def test_classic_engine_unchanged_contract():
    g = GRAPHS["grid"]()
    r = color_data_driven(g, engine="classic")
    assert is_valid_coloring(g, r.colors)
    assert r.converged
    assert r.num_colors <= g.max_degree + 1


# --------------------------------------------------------------------------
# CSR-resident fused kernel (DESIGN.md §18): gathers straight from R/C
# --------------------------------------------------------------------------

def _csr_inputs(g, seed, extra_sentinels=0):
    """(DeviceCSR, colors_ext, packed table, full worklist) for ``g``."""
    rng = np.random.default_rng(seed)
    dev = DeviceCSR.from_csr(g)
    W = dev.max_width
    colors = rng.integers(0, W + 2, g.n).astype(np.int32)
    colors_ext = jnp.asarray(np.concatenate([colors, [0]]).astype(np.int32))
    wl = np.arange(g.n, dtype=np.int32)
    if extra_sentinels:
        wl = np.concatenate([wl, np.full(extra_sentinels, g.n, np.int32)])
    return dev, colors_ext, colors_ext + (dev.deg_ext << 16), jnp.asarray(wl)


def _gathered_step(dev, colors_ext, wl, W, heuristic):
    rows = dev.gather_rows(wl, W)
    return superstep_tpu(wl, rows, colors_ext[wl], colors_ext[rows],
                         dev.deg_ext[wl], dev.deg_ext[rows], heuristic)


def _mask(wl, n, newc, need):
    valid = wl < n
    return jnp.where(valid, newc, 0), need & valid


@pytest.mark.parametrize("W", [31, 32, 63, 64])
@pytest.mark.parametrize("heuristic", ["id", "degree"])
def test_csr_kernel_word_boundary_widths(W, heuristic):
    """A (W+1)-clique puts every row at degree exactly W — the gather width
    sits at (or one past) a 32-bit bitset word boundary, where an off-by-one
    in the kernel's nwords or lane masking would corrupt colors."""
    k = W + 1
    src, dst = np.triu_indices(k, 1)
    g = csr_from_edges(k, src, dst)
    dev, colors_ext, packed, wl = _csr_inputs(g, seed=W)
    g_c, g_n = _gathered_step(dev, colors_ext, wl, W, heuristic)
    c_c, c_n = superstep_csr_tpu(dev.row_starts, dev.col_padded, packed,
                                 wl, W, heuristic)
    np.testing.assert_array_equal(np.asarray(g_c), np.asarray(c_c))
    np.testing.assert_array_equal(np.asarray(g_n), np.asarray(c_n))


@pytest.mark.parametrize("gname", ["er", "powerlaw", "grid"])
@pytest.mark.parametrize("heuristic", ["id", "degree"])
def test_csr_kernel_ragged_rows_match_gathered(gname, heuristic):
    """Ragged degrees: lanes past a row's degree alias the NEXT row's ids in
    raw C storage — the kernel must mask them to the inert sentinel, exactly
    reproducing DeviceCSR.gather_rows + the packed pure-JAX gather."""
    g = GRAPHS[gname]()
    dev, colors_ext, packed, wl = _csr_inputs(g, seed=17)
    W = dev.max_width
    g_c, g_n = _gathered_step(dev, colors_ext, wl, W, heuristic)
    c_c, c_n = superstep_csr_tpu(dev.row_starts, dev.col_padded, packed,
                                 wl, W, heuristic)
    np.testing.assert_array_equal(np.asarray(g_c), np.asarray(c_c))
    np.testing.assert_array_equal(np.asarray(g_n), np.asarray(c_n))


def test_csr_kernel_sentinel_padded_worklist():
    """Pow2-padded worklists (dynamic sessions) carry trailing sentinel ids;
    after the caller-side validity mask both kernels must agree and the
    sentinel lanes must come back inert (color 0, need False)."""
    g = GRAPHS["er"]()
    dev, colors_ext, packed, wl = _csr_inputs(g, seed=23, extra_sentinels=37)
    W = dev.max_width
    g_c, g_n = _mask(wl, g.n, *_gathered_step(dev, colors_ext, wl, W,
                                              "degree"))
    c_c, c_n = _mask(wl, g.n, *superstep_csr_tpu(
        dev.row_starts, dev.col_padded, packed, wl, W, "degree"))
    np.testing.assert_array_equal(np.asarray(g_c), np.asarray(c_c))
    np.testing.assert_array_equal(np.asarray(g_n), np.asarray(c_n))
    assert not np.asarray(c_n)[g.n:].any()
    assert (np.asarray(c_c)[g.n:] == 0).all()


@pytest.mark.parametrize("block_n", [8, 16, 128])
def test_csr_kernel_block_sizes(block_n):
    g = GRAPHS["er"]()
    dev, colors_ext, packed, wl = _csr_inputs(g, seed=29)
    W = dev.max_width
    g_c, g_n = _gathered_step(dev, colors_ext, wl, W, "degree")
    c_c, c_n = superstep_csr_tpu(dev.row_starts, dev.col_padded, packed,
                                 wl, W, "degree", block_n=block_n)
    np.testing.assert_array_equal(np.asarray(g_c), np.asarray(c_c))
    np.testing.assert_array_equal(np.asarray(g_n), np.asarray(c_n))


def test_csr_kernel_empty():
    c, n = superstep_csr_tpu(jnp.zeros(3, jnp.int32), jnp.zeros(4, jnp.int32),
                             jnp.zeros(3, jnp.int32), jnp.zeros(0, jnp.int32),
                             4)
    assert c.shape == (0,) and n.shape == (0,)


@pytest.mark.parametrize("gname", ["er", "grid", "powerlaw"])
@pytest.mark.parametrize("kind", ["bitset", "scan"])
def test_csr_tail_matches_serial_tail_oracle(gname, kind):
    """The grid=1 on-device tail vs the fori_loop ``serial_tail_step``: the
    same clear-then-sequential-FirstFit over the live state, so colors must
    match bit for bit regardless of the FirstFit kind (every kind returns
    the smallest free color)."""
    g = GRAPHS[gname]()
    dev, colors_ext, _, _ = _csr_inputs(g, seed=31)
    W = dev.max_width
    rng = np.random.default_rng(37)
    wl = rng.choice(g.n, min(64, g.n), replace=False).astype(np.int32)
    wl = np.concatenate([wl, np.full(7, g.n, np.int32)])  # sentinel padding
    wl = C.order_tail(jnp.asarray(wl), dev.deg_ext)
    want = C.serial_tail_step(dev.row1, colors_ext, wl, kind)
    got = serial_tail_csr_tpu(dev.row_starts, dev.col_padded, dev.deg_ext,
                              colors_ext, wl, W)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def _eqn_shapes(jaxpr, out):
    """All operand/result shapes in ``jaxpr``, recursing through sub-jaxprs
    but NOT into pallas_call bodies (kernel-internal VMEM tiles are the
    point of the CSR path — only host-visible arrays count)."""
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            continue
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "shape", None) is not None:
                out.add(tuple(aval.shape))
        for val in eqn.params.values():
            if hasattr(val, "jaxpr"):          # ClosedJaxpr
                _eqn_shapes(val.jaxpr, out)
            elif hasattr(val, "eqns"):         # raw Jaxpr
                _eqn_shapes(val, out)


def test_csr_superstep_jaxpr_has_no_materialized_tile():
    """Acceptance (§18): the CSR path's superstep jaxpr contains no
    ``(w, W)`` array — the gather happens inside the kernel — while the
    gathered-kernel path provably materializes that tile in HBM."""
    g = GRAPHS["er"]()
    dev = DeviceCSR.from_csr(g)
    W = dev.max_width
    w = 200  # not a multiple of 8: distinct from any kernel-internal block
    wl = jnp.arange(w, dtype=jnp.int32)
    colors_ext = jnp.zeros(g.n + 1, jnp.int32)

    def step(use_kernel):
        def f(colors_ext, wl):
            return C.ragged_superstep(
                lambda ids: dev.gather_rows(ids, W), dev.deg_ext,
                colors_ext, wl, use_kernel=use_kernel, pack_degrees=True,
                provider=dev, width=W)
        return jax.make_jaxpr(f)(colors_ext, wl)

    shapes_csr, shapes_gathered = set(), set()
    _eqn_shapes(step("csr").jaxpr, shapes_csr)
    _eqn_shapes(step(True).jaxpr, shapes_gathered)
    assert (w, W) not in shapes_csr, "CSR path materialized a gather tile"
    assert (w, W) in shapes_gathered  # the control: gathered path does


def test_pick_block_n_vmem_accounting():
    """Satellite: the VMEM budget must cover the bitset words and the
    first-fit (nwords, 32) expansion, not just the input tiles — at large W
    the old divisor (W*4*3) overshot the budget by ~45%."""
    from repro.kernels.superstep.ops import _VMEM_BUDGET, _pick_block_n

    for W in (16, 100, 1000, 5000, 20000):
        for tiles in (3, 4):
            bn = _pick_block_n(10**6, W, tiles=tiles)
            nwords = (W + 1 + 31) // 32
            per_row = tiles * W * 4 + nwords * 4 + nwords * 32 * 4
            assert bn >= 8 and bn % 8 == 0
            # the floor of 8 rows may exceed the budget by construction at
            # extreme W; otherwise the working set must fit
            if bn > 8:
                assert bn * per_row <= _VMEM_BUDGET, (W, tiles, bn)


def test_csr_backend_no_silent_tile_in_engine(monkeypatch):
    """backend='pallas-csr' on the ragged engine must route through the CSR
    kernel (not silently fall back to the gathered kernel) when the packed
    gather is legal and the provider is a DeviceCSR."""
    import repro.kernels.superstep.csr_kernel as ck

    calls = {"step": 0, "tail": 0}
    orig_step, orig_tail = ck.superstep_csr_tpu, ck.serial_tail_csr_tpu
    monkeypatch.setattr(ck, "superstep_csr_tpu",
                        lambda *a, **k: (calls.__setitem__(
                            "step", calls["step"] + 1), orig_step(*a, **k))[1])
    monkeypatch.setattr(ck, "serial_tail_csr_tpu",
                        lambda *a, **k: (calls.__setitem__(
                            "tail", calls["tail"] + 1), orig_tail(*a, **k))[1])
    g = GRAPHS["grid"]()  # cascades: exercises the on-device tail too
    base = color_data_driven(g, backend="jax", tail_serial="auto")
    r = color_data_driven(g, backend="pallas-csr", tail_serial="auto")
    assert calls["step"] > 0, "CSR superstep kernel never engaged"
    assert calls["tail"] > 0, "on-device CSR tail never engaged"
    np.testing.assert_array_equal(base.colors, r.colors)
    assert base.iterations == r.iterations
