"""Optimizer math vs numpy oracle; loss-decreases; data-pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.training import AdamWConfig, adamw_init, adamw_update
from repro.training.data import SyntheticConfig, SyntheticData
from repro.training.optimizer import cosine_lr


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=100,
                      weight_decay=0.1, clip_norm=1e9)
    p = {"w": jnp.asarray(np.linspace(-1, 1, 6).reshape(2, 3), jnp.float32)}
    g = {"w": jnp.asarray(np.full((2, 3), 0.5), jnp.float32)}
    opt = adamw_init(p)
    new_p, new_opt, stats = adamw_update(p, g, opt, jnp.int32(0), cfg)

    # numpy oracle
    lr = float(cosine_lr(jnp.int32(0), cfg))
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    w = np.linspace(-1, 1, 6).reshape(2, 3)
    want = w - lr * (mh / (np.sqrt(vh) + cfg.eps) + 0.1 * w)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    np.testing.assert_allclose(float(stats["grad_norm"]),
                               np.sqrt((0.5 ** 2) * 6), rtol=1e-5)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=0.1, warmup_steps=0, total_steps=10)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    opt = adamw_init(p)
    _, new_opt, stats = adamw_update(p, g, opt, jnp.int32(0), cfg)
    # post-clip first moment: |g_clipped| = clip_norm/||g|| * g
    scale = 0.1 / float(stats["grad_norm"])
    np.testing.assert_allclose(
        np.asarray(new_opt["m"]["w"]), 0.1 * 100.0 * scale, rtol=1e-4)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(jnp.int32(s), cfg)) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_loss_decreases_quick():
    """30 steps on the synthetic affine-recurrence language -> loss drops."""
    from repro.launch.train import train_loop

    cfg = get_config("qwen3-4b").reduced()
    out = train_loop(cfg, steps=30, batch_size=8, seq_len=32, lr=3e-3,
                     log_every=5)
    assert out["final_loss"] < out["first_loss"] - 0.3, out["losses"]


def test_data_deterministic_and_stateless():
    cfg = SyntheticConfig(vocab=100, seq_len=16, batch_size=4)
    d1, d2 = SyntheticData(cfg), SyntheticData(cfg)
    b5a = d1.batch(5)
    _ = d1.batch(6)
    b5b = d2.batch(5)   # fresh pipeline, same step -> identical batch
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"], b5a["tokens"])


def test_data_families():
    enc = SyntheticData(SyntheticConfig(vocab=32, seq_len=8, batch_size=2,
                                        family="encoder", d_frontend=16))
    b = enc.batch(0)
    assert b["frames"].shape == (2, 8, 16) and b["labels"].max() < 32
    vlm = SyntheticData(SyntheticConfig(vocab=32, seq_len=8, batch_size=2,
                                        family="vlm", d_frontend=16,
                                        n_patches=4))
    assert vlm.batch(0)["patches"].shape == (2, 4, 16)
