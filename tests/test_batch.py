"""Batched multi-graph engine: packing invariants, validity, bit-exactness."""
import numpy as np

import repro
from repro.core import (
    GraphBatch,
    batched_sgr_step,
    color_batch_fused,
    color_data_driven,
    csr_from_edges,
    is_valid_coloring,
)
from repro.graphs import (
    erdos_renyi,
    grid2d,
    honeycomb,
    power_law,
    road,
    small_world,
)

import jax.numpy as jnp


def _mixed_graphs():
    """B=9 heterogeneous graphs: mixed generators, sizes, and densities."""
    return [
        erdos_renyi(300, 5.0, seed=2),
        grid2d(15, 20),
        power_law(500, 6.0, seed=3),
        honeycomb(10, 12),
        road(200, seed=4),
        small_world(350, 6, seed=5),
        erdos_renyi(64, 3.0, seed=6),
        power_law(900, 8.0, seed=7),
        erdos_renyi(1200, 10.0, seed=8),
    ]


def test_graphbatch_packing():
    graphs = _mixed_graphs()
    batch = GraphBatch.from_graphs(graphs)
    n_max = max(g.n for g in graphs)
    assert batch.B == len(graphs)
    assert batch.n_max == n_max
    assert batch.adj.shape == (batch.B, n_max, batch.width)
    assert batch.width >= max(g.max_degree for g in graphs)
    adj = np.asarray(batch.adj)
    deg = np.asarray(batch.deg_ext)
    for b, g in enumerate(graphs):
        # real rows hold the graph's neighbors, sentinel-remapped to n_max
        for v in range(0, g.n, max(1, g.n // 7)):
            nb = g.neighbors(v)
            assert (adj[b, v, : nb.size] == nb).all()
            assert (adj[b, v, nb.size:] == n_max).all()
        # padding rows beyond n_i are all-sentinel, padding degrees 0
        assert (adj[b, g.n:] == n_max).all()
        assert (deg[b, : g.n] == g.degrees).all()
        assert (deg[b, g.n:] == 0).all()


def test_batch_colors_valid_and_bit_identical_to_fused():
    """One jitted call over B>=8 graphs == per-graph fused runs, bit for bit."""
    graphs = _mixed_graphs()
    results = color_batch_fused(graphs)
    assert len(results) == len(graphs)
    for g, r in zip(graphs, results):
        assert is_valid_coloring(g, r.colors), r.algorithm
        assert r.converged
        single = color_data_driven(g, mode="fused")
        assert (r.colors == single.colors).all()
        assert r.iterations == single.iterations


def test_batch_heuristic_and_firstfit_options():
    graphs = _mixed_graphs()[:4]
    for heuristic, ff in (("id", "scan"), ("degree", "sort")):
        results = color_batch_fused(graphs, heuristic=heuristic, firstfit=ff)
        for g, r in zip(graphs, results):
            assert is_valid_coloring(g, r.colors), (heuristic, ff)
            single = color_data_driven(g, mode="fused", heuristic=heuristic,
                                       firstfit=ff)
            assert (r.colors == single.colors).all()


def test_batched_sgr_step_matches_per_graph_step():
    """vmap lifting: one batched super-step == B independent super-steps."""
    from repro.core.coloring import sgr_step

    graphs = [erdos_renyi(128, 5.0, seed=10), grid2d(8, 16), road(100, seed=11)]
    batch = GraphBatch.from_graphs(graphs)
    n_max = batch.n_max
    ids = jnp.arange(n_max, dtype=jnp.int32)
    sizes = jnp.asarray(np.asarray(batch.sizes, np.int32))
    wl = jnp.where(ids[None, :] < sizes[:, None], ids[None, :], n_max)
    colors = jnp.zeros((batch.B, n_max + 1), dtype=jnp.int32)
    bc, bwl, bcnt = batched_sgr_step(batch.adj, batch.deg_ext, colors, wl)
    for b in range(batch.B):
        sc, swl, scnt = sgr_step(batch.adj[b], batch.deg_ext[b], colors[b],
                                 wl[b], heuristic="degree", kind="bitset")
        assert (np.asarray(bc[b]) == np.asarray(sc)).all()
        assert (np.asarray(bwl[b]) == np.asarray(swl)).all()
        assert int(bcnt[b]) == int(scnt)


def test_batch_uniform_sizes():
    """Homogeneous batch (no padding rows) is the degenerate easy case."""
    graphs = [erdos_renyi(256, 6.0, seed=s) for s in range(5)]
    for g, r in zip(graphs, color_batch_fused(graphs)):
        assert is_valid_coloring(g, r.colors)
        assert (r.colors == color_data_driven(g, mode="fused").colors).all()


def test_batch_edge_cases():
    assert color_batch_fused([]) == []
    empty = csr_from_edges(0, np.zeros(0, int), np.zeros(0, int))
    only_empty = color_batch_fused([empty, empty])
    assert all(r.colors.shape == (0,) and r.converged for r in only_empty)
    # an empty graph and an edgeless graph mixed into a real batch
    edgeless = csr_from_edges(5, np.zeros(0, int), np.zeros(0, int))
    graphs = [empty, edgeless, erdos_renyi(100, 4.0, seed=12)]
    results = color_batch_fused(graphs)
    assert results[0].colors.shape == (0,)
    assert (results[1].colors == 1).all()      # isolated vertices take color 1
    assert is_valid_coloring(graphs[2], results[2].colors)


def test_batch_via_api():
    graphs = _mixed_graphs()[:3]
    results = repro.color_batch(graphs)           # algorithm="fused" default
    for g, r in zip(graphs, results):
        assert is_valid_coloring(g, r.colors)
        assert r.algorithm == "batched_fused_sgr"


def test_batch_work_accounting():
    graphs = _mixed_graphs()[:4]
    results = color_batch_fused(graphs)
    steps = max(r.iterations for r in results)
    n_max = max(g.n for g in graphs)
    for g, r in zip(graphs, results):
        assert r.work_items >= g.n                 # first step touches all
        assert r.padded_work >= steps * n_max      # full-capacity lanes
        assert r.iterations <= steps
