"""End-to-end behaviour tests for the whole system."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_end_to_end_color_pipeline():
    """Generate -> color (all algorithms) -> validate -> schedule, one flow."""
    from repro.core import (color_data_driven, color_multihash, greedy_serial,
                            is_valid_coloring, num_colors)
    from repro.core.scheduling import phases
    from repro.graphs import build_graph

    g = build_graph("rmat-g", scale=0.05)
    serial = greedy_serial(g)
    opt = color_data_driven(g, coarsen_lanes=16384)
    mis = color_multihash(g, 2)
    assert is_valid_coloring(g, opt.colors)
    # the paper's headline quality claim, end to end
    assert num_colors(opt.colors) <= num_colors(serial) + 2
    assert num_colors(mis.colors) > num_colors(opt.colors)
    assert sum(p.size for p in phases(opt.colors)) == g.n


def test_train_driver_cli(tmp_path):
    """The launcher trains a reduced model for 20 steps from the CLI."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-4b",
         "--reduced", "--steps", "20", "--batch", "4", "--seq", "32",
         "--lr", "3e-3", "--ckpt-dir", str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, timeout=900, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss=" in out.stdout
    assert (tmp_path / "ck" / "step_20").exists()


def test_quickstart_example():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=900, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "valid=True" in out.stdout


def test_serve_driver():
    from repro.configs import get_config
    from repro.launch.serve_lm import serve_batch

    cfg = get_config("qwen3-4b").reduced()
    out = serve_batch(cfg, batch=2, prompt_len=8, gen=6)
    assert out["generated"].shape == (2, 6)


def test_run_with_restarts(tmp_path):
    from repro.configs import get_config
    from repro.distributed.fault_tolerance import run_with_restarts
    from repro.launch.train import train_loop

    cfg = get_config("qwen3-4b").reduced()
    ck = str(tmp_path / "ck")
    calls = {"n": 0}

    def run(start):
        calls["n"] += 1
        fail = 6 if calls["n"] == 1 else None   # first attempt dies at step 6
        return train_loop(cfg, steps=10, batch_size=2, seq_len=16, lr=1e-3,
                          ckpt_dir=ck, ckpt_every=3, log_every=5, seed=1,
                          resume=start > 0, fail_at_step=fail)

    out = run_with_restarts(run, ckpt_dir=ck, max_restarts=2)
    assert calls["n"] == 2 and out["steps"] == 4   # resumed from step 6
