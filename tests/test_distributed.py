"""Multi-device behaviour via subprocess (8 fake host devices).

Kept out of the main pytest process so ordinary tests see the single real
device (the dry-run contract: XLA flags only inside launch/dryrun.py).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(code: str, flags="--xla_force_host_platform_device_count=8") -> str:
    env = dict(os.environ, PYTHONPATH=SRC, XLA_FLAGS=flags,
               REPRO_DRYRUN_FLAGS=flags)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_suite_bit_identity_8dev():
    """§13 acceptance: sharded ≡ ragged on EVERY suite graph, halo < 4n."""
    out = _run(
        """
import jax
assert jax.device_count() == 8
from repro.core import color_data_driven, color_distributed, is_valid_coloring
from repro.graphs.suite import build_suite
for name, g in build_suite(0.02).items():
    r = color_distributed(g)
    s = color_data_driven(g)
    assert (r.colors == s.colors).all(), f"{name}: sharded != ragged"
    assert is_valid_coloring(g, r.colors), name
    assert r.halo_bytes_per_step < 4 * g.n, (
        name, r.halo_bytes_per_step, 4 * g.n)
    assert r.algorithm == "sharded_sgr_8dev"
print("SWEEP_OK")
"""
    )
    assert "SWEEP_OK" in out


def test_sharded_unpacked_halo_8dev():
    """n >= 2**15 disables halo packing; identity + halo bound still hold."""
    out = _run(
        """
import jax
from repro.core import color_data_driven, color_distributed
from repro.graphs import road
g = road(40000, seed=9)
assert g.n >= 2**15
r = color_distributed(g)
s = color_data_driven(g)
assert (r.colors == s.colors).all()
assert r.halo_bytes_per_step < 4 * g.n, r.halo_bytes_per_step
print("BIG_OK")
"""
    )
    assert "BIG_OK" in out


def test_sharded_d2_bipartite_8dev():
    """Distance-2 and bipartite run sharded, both strategies, bit-identical."""
    out = _run(
        """
import numpy as np
from repro.d2 import (color_bipartite, color_distance2, validate_bipartite,
                      validate_d2)
from repro.d2.bipartite import BipartiteGraph
from repro.graphs import erdos_renyi, grid2d
for g in [erdos_renyi(500, 6.0, seed=0), grid2d(15, 18)]:
    for strat in ("precomputed", "onthefly"):
        r = color_distance2(g, engine="sharded", strategy=strat)
        base = color_distance2(g, strategy=strat)
        assert (r.colors == base.colors).all(), strat
        assert validate_d2(g, r.colors), strat
        assert r.algorithm == "distance2_sgr_sharded_8dev"
bg = BipartiteGraph.from_dense(np.random.default_rng(0).random((80, 120)) < 0.06)
for strat in ("precomputed", "onthefly"):
    r = color_bipartite(bg, engine="sharded", strategy=strat)
    base = color_bipartite(bg, strategy=strat)
    assert (r.colors == base.colors).all(), strat
    assert validate_bipartite(bg, r.colors), strat
print("D2_OK")
"""
    )
    assert "D2_OK" in out


def test_sharded_batch_8dev():
    """Batch placement: shard-per-graph and partition-within-graph paths."""
    out = _run(
        """
import repro
from repro.core import is_valid_coloring
from repro.graphs.suite import serving_mix
graphs = serving_mix(10, scale=0.3)
base = repro.color_batch(graphs, algorithm="fused")
for engine_graphs in (graphs, graphs[:2]):  # B >= ndev and B < ndev
    sh = repro.color_batch(engine_graphs, algorithm="fused", engine="sharded")
    for g, rb, rs in zip(engine_graphs, base, sh):
        assert (rb.colors == rs.colors).all()
        assert is_valid_coloring(g, rs.colors)
d2b = repro.color_batch(graphs[:9], algorithm="distance2")
d2s = repro.color_batch(graphs[:9], algorithm="distance2", engine="sharded")
for rb, rs in zip(d2b, d2s):
    assert (rb.colors == rs.colors).all()
print("BATCH_OK")
"""
    )
    assert "BATCH_OK" in out


def test_sharded_error_paths_8dev():
    """engine='sharded' raises the ragged path's exact heuristic error."""
    out = _run(
        """
import repro
from repro.graphs import grid2d
g = grid2d(10, 12)
msgs = []
for engine in ("ragged", "sharded"):
    try:
        repro.color(g, "data_driven", engine=engine, heuristic="nope")
        raise SystemExit(f"{engine}: no error raised")
    except ValueError as e:
        msgs.append(str(e))
assert msgs[0] == msgs[1], msgs
assert "unknown heuristic" in msgs[0]
print("ERR_OK")
"""
    )
    assert "ERR_OK" in out


def test_dryrun_cell_on_tiny_mesh(tmp_path):
    """The dry-run driver lowers+compiles a full-size arch on a 2x4 mesh."""
    out_file = tmp_path / "res.json"
    env = dict(
        os.environ,
        PYTHONPATH=SRC,
        REPRO_DRYRUN_FLAGS="--xla_force_host_platform_device_count=8",
    )
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-4b",
         "--shape", "decode_32k", "--mesh", "single", "--mesh-shape", "2x4",
         "--out", str(out_file)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    recs = json.loads(out_file.read_text())
    rec = recs[0]
    assert rec["ok"], rec.get("error")
    assert rec["analysis"]["flops"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0


def test_sharding_resolver_rules():
    """Pure resolver logic — no devices needed."""
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.distributed.sharding import act_spec, param_spec

    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))

    # big 2D param: TP on last dim, FSDP on first
    assert param_spec((8192, 4096), mesh) == jax.sharding.PartitionSpec(
        "data", "model")
    # scan-stacked 3D: layer dim never sharded
    s = param_spec((36, 2560, 9728), mesh)
    assert s[0] is None and s[2] == "model"
    # tiny params replicate
    assert param_spec((64,), mesh) == jax.sharding.PartitionSpec(None)
    # batch=1 long-context: sequence takes the data axes
    s = act_spec((1, 524288, 2560), mesh)
    assert s[1] in ("data", ("data",))
    # kv_heads=8 cannot split 16 ways -> time dim takes model
    s = act_spec((128, 32768, 8, 128), mesh)
    assert s[0] in ("data", ("data",)) and "model" in s
    # indivisible dims never sharded
    s = act_spec((3, 7, 11), mesh)
    assert s == jax.sharding.PartitionSpec(None, None, None)
