"""Multi-device behaviour via subprocess (8 fake host devices).

Kept out of the main pytest process so ordinary tests see the single real
device (the dry-run contract: XLA flags only inside launch/dryrun.py).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(code: str, flags="--xla_force_host_platform_device_count=8") -> str:
    env = dict(os.environ, PYTHONPATH=SRC, XLA_FLAGS=flags,
               REPRO_DRYRUN_FLAGS=flags)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_coloring_8dev():
    out = _run(
        """
import jax
assert jax.device_count() == 8
from repro.core.distributed import color_distributed
from repro.core import is_valid_coloring, color_data_driven
from repro.graphs import erdos_renyi, rmat
for g in [erdos_renyi(1000, 8.0, seed=3), rmat(2048, 10.0, seed=5)]:
    r = color_distributed(g)
    assert is_valid_coloring(g, r.colors), "invalid distributed coloring"
    single = color_data_driven(g)
    assert r.num_colors <= single.num_colors + 3
print("DIST_OK")
"""
    )
    assert "DIST_OK" in out


def test_dryrun_cell_on_tiny_mesh(tmp_path):
    """The dry-run driver lowers+compiles a full-size arch on a 2x4 mesh."""
    out_file = tmp_path / "res.json"
    env = dict(
        os.environ,
        PYTHONPATH=SRC,
        REPRO_DRYRUN_FLAGS="--xla_force_host_platform_device_count=8",
    )
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-4b",
         "--shape", "decode_32k", "--mesh", "single", "--mesh-shape", "2x4",
         "--out", str(out_file)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    recs = json.loads(out_file.read_text())
    rec = recs[0]
    assert rec["ok"], rec.get("error")
    assert rec["analysis"]["flops"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0


def test_sharding_resolver_rules():
    """Pure resolver logic — no devices needed."""
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.distributed.sharding import act_spec, param_spec

    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))

    # big 2D param: TP on last dim, FSDP on first
    assert param_spec((8192, 4096), mesh) == jax.sharding.PartitionSpec(
        "data", "model")
    # scan-stacked 3D: layer dim never sharded
    s = param_spec((36, 2560, 9728), mesh)
    assert s[0] is None and s[2] == "model"
    # tiny params replicate
    assert param_spec((64,), mesh) == jax.sharding.PartitionSpec(None)
    # batch=1 long-context: sequence takes the data axes
    s = act_spec((1, 524288, 2560), mesh)
    assert s[1] in ("data", ("data",))
    # kv_heads=8 cannot split 16 ways -> time dim takes model
    s = act_spec((128, 32768, 8, 128), mesh)
    assert s[0] in ("data", ("data",)) and "model" in s
    # indivisible dims never sharded
    s = act_spec((3, 7, 11), mesh)
    assert s == jax.sharding.PartitionSpec(None, None, None)
