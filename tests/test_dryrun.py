"""Dry-run machinery: HLO analyzer correctness, cell planning, roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.hlo_analysis import analyze_hlo


def test_analyzer_scan_trip_counts():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, x).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == 2 * 128 ** 3 * 10
    assert cost.unknown_trip_counts == 0


def test_analyzer_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(g).lower(x, x).compile()
    assert analyze_hlo(c.as_text()).flops == 2 * 64 ** 3 * 20


def test_analyzer_plain_dot_and_traffic():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == 2 * 256 * 512 * 128
    expect_traffic = (256 * 512 + 512 * 128 + 256 * 128) * 4
    assert cost.traffic >= expect_traffic


def test_plan_cells_counts():
    from repro.launch.dryrun import SHAPES, plan_cells

    cells = plan_cells()
    assert len(cells) == 10 * len(SHAPES)          # 40 nominal cells
    skips = [(a, s) for a, s, sk in cells if sk]
    runs = [(a, s) for a, s, sk in cells if not sk]
    # hubert: 2 decode skips; long_500k: 7 archs skip (incl hubert) = 8 unique
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("mixtral-8x22b", "long_500k") in runs   # SWA is sub-quadratic
    assert ("rwkv6-1.6b", "long_500k") in runs
    assert ("recurrentgemma-2b", "long_500k") in runs
    assert ("qwen3-32b", "long_500k") in skips
    assert len(runs) == 32


def test_collective_stats_parsing():
    from repro.launch.dryrun import collective_stats

    text = """
  %ag = bf16[2048,5120]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[1,256]<=[256], to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(%z), channel_id=3, source_target_pairs={{0,1}}
"""
    s = collective_stats(text)
    ag = 2048 * 5120 * 2
    assert s["all-gather"]["count"] == 1
    assert abs(s["all-gather"]["moved_bytes"] - ag * 15 / 16) < 1
    ar = 1024 * 4
    assert abs(s["all-reduce"]["moved_bytes"] - ar * 2 * 255 / 256) < 1
    assert s["collective-permute"]["moved_bytes"] == 256


def test_roofline_terms():
    from benchmarks.roofline import roofline_terms

    rec = {
        "analysis": {"flops": 197e12, "traffic_bytes": 819e9,
                     "collective_bytes": 50e9},
        "model_flops": 197e12 * 256 * 0.5,
        "mesh": "single",
    }
    t = roofline_terms(rec)
    assert abs(t["compute_s"] - 1.0) < 1e-6      # exactly 1s of MXU
    assert abs(t["memory_s"] - 1.0) < 1e-6       # exactly 1s of HBM
    assert abs(t["collective_s"] - 1.0) < 1e-6   # exactly 1s of ICI
    assert t["bottleneck"] in ("compute", "memory", "collective")
    assert abs(t["useful_flops_ratio"] - 0.5) < 1e-6
