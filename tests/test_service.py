"""§19 ColoringService: admission, backpressure, eviction, micro-batching.

The service is a worker thread behind a bounded queue, so these tests
prefer SYNCHRONOUS submissions (deterministic one-request micro-batches)
except where the point is the async path itself — async drain cycles are
timing-dependent and any assertion on how requests happened to coalesce
would flake.
"""
import numpy as np
import pytest

import repro
from repro.core import csr_from_edges, is_valid_coloring
from repro.errors import Overloaded, ReproError, SessionEvicted
from repro.launch.coloring_service import ColoringService


def _graph(n=60, m=240, seed=0):
    rng = np.random.default_rng(seed)
    return csr_from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))


@pytest.fixture()
def svc():
    s = ColoringService(pool_size=4, queue_limit=16, max_batch=8)
    yield s
    s.shutdown()


# --------------------------------------------------------------------------
# one-shot coloring through the micro-batcher
# --------------------------------------------------------------------------

def test_color_bit_identical_to_direct(svc):
    g = _graph()
    served = svc.color(g)
    direct = repro.color(g, "fused")
    np.testing.assert_array_equal(served.colors, direct.colors)
    assert served.num_colors == direct.num_colors


def test_color_async_burst_all_valid_and_identical(svc):
    graphs = [_graph(seed=s) for s in range(6)]
    tickets = [svc.color(g, wait=False) for g in graphs]
    results = [t.wait(60) for t in tickets]
    for g, r in zip(graphs, results):
        assert is_valid_coloring(g, r.colors)
        np.testing.assert_array_equal(r.colors, repro.color(g, "fused").colors)
    m = svc.metrics()
    assert m["completed"] == len(graphs)
    assert m["batched_requests"] == len(graphs)


def test_bucket_jit_key_is_stable_across_repeats(svc):
    g = _graph()
    svc.color(g)                       # first presentation compiles
    before = svc.metrics()["bucket_jit_misses"]
    for _ in range(4):                 # same (bucket, B=1) key every time
        svc.color(g)
    after = svc.metrics()["bucket_jit_misses"]
    assert after == before
    assert svc.metrics()["bucket_jit_hits"] >= 4


def test_incompatible_options_take_slow_path(svc):
    g = _graph()
    r = svc.color(g, ensure_valid=True)   # ladder is per-request only
    assert is_valid_coloring(g, r.colors)
    assert svc.metrics()["slow_requests"] == 1


def test_distinct_options_get_distinct_buckets(svc):
    g = _graph()
    svc.color(g)
    svc.color(g, heuristic="id")
    assert len(svc.metrics()["buckets"]) == 2


def test_request_errors_cross_the_thread_boundary(svc):
    with pytest.raises(KeyError):
        svc.recolor("never-opened")
    with pytest.raises(TypeError):
        svc.open_session("bad", object())
    assert svc.metrics()["failed"] == 2


# --------------------------------------------------------------------------
# backpressure: bounded queue, structured Overloaded
# --------------------------------------------------------------------------

def test_overload_rejects_structured_and_bounded():
    g = _graph()
    with ColoringService(pool_size=2, queue_limit=4, max_batch=2) as svc:
        tickets, errors = [], []
        for _ in range(40):
            try:
                tickets.append(svc.color(g, wait=False))
            except Overloaded as e:
                errors.append(e)
        for t in tickets:
            assert is_valid_coloring(g, t.wait(60).colors)
        assert errors, "flooding a queue_limit=4 service must shed load"
        e = errors[0]
        assert isinstance(e, ReproError)
        assert e.limit == 4 and e.queue_depth >= e.limit
        assert e.retry_after >= 0.0
        p = e.payload()
        assert p["error"] == "Overloaded" and p["limit"] == 4
        m = svc.metrics()
        assert m["rejected"] == len(errors)
        assert m["completed"] + m["rejected"] == 40


def test_shutdown_refuses_new_work(svc):
    svc.shutdown()
    with pytest.raises(RuntimeError):
        svc.color(_graph())


# --------------------------------------------------------------------------
# session pool: LRU admission, eviction, spill/restore
# --------------------------------------------------------------------------

def test_eviction_without_spill_is_structured():
    g = _graph()
    with ColoringService(pool_size=1, queue_limit=16) as svc:
        svc.open_session("a", g)
        out = svc.open_session("b", g)
        assert out["evicted"] == "a"
        with pytest.raises(SessionEvicted) as ei:
            svc.colors("a")
        assert ei.value.session_id == "a"
        assert ei.value.payload()["error"] == "SessionEvicted"
        assert svc.metrics()["evictions"] == 1


def test_eviction_spills_and_restores(tmp_path):
    g = _graph()
    with ColoringService(pool_size=1, queue_limit=16,
                         spill_dir=str(tmp_path)) as svc:
        svc.open_session("a", g)
        svc.apply_delta("a", add_edges=(np.array([0, 1]), np.array([2, 3])))
        svc.recolor("a")
        want = svc.colors("a")
        svc.open_session("b", g)              # evicts "a" to disk
        assert svc.metrics()["spills"] == 1
        got = svc.colors("a")                 # transparent restore (LRU bump)
        np.testing.assert_array_equal(got, want)
        m = svc.metrics()
        assert m["restores"] == 1 and m["pool_occupancy"] == 1


def test_session_ops_match_direct_session(svc):
    g = _graph()
    svc.open_session("s", g, heuristic="id")
    direct = repro.open_session(g, heuristic="id")
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    for _ in range(3):
        add = (rng_a.integers(0, g.n, 8), rng_a.integers(0, g.n, 8))
        svc.apply_delta("s", add_edges=add)
        svc.recolor("s")
        direct.apply_delta(add_edges=(rng_b.integers(0, g.n, 8),
                                      rng_b.integers(0, g.n, 8)))
        direct.recolor()
    np.testing.assert_array_equal(svc.colors("s"), direct.colors)
    assert (svc.session_metrics("s")["recolors"]
            == direct.metrics()["recolors"])


def test_reopen_replaces_and_close_forgets(svc):
    g = _graph()
    svc.open_session("s", g)
    svc.apply_delta("s", add_edges=(np.array([0]), np.array([5])))
    svc.open_session("s", g)                  # replace: pending delta gone
    np.testing.assert_array_equal(svc.colors("s"),
                                  repro.open_session(g).colors)
    assert svc.close_session("s") is True
    assert svc.close_session("s") is False
    with pytest.raises(KeyError):
        svc.colors("s")


def test_maintain_compacts_deferred_overlays(svc):
    g = _graph()
    svc.open_session("s", g)
    rng = np.random.default_rng(3)
    for _ in range(12):                       # grow overlays past the due
        svc.apply_delta("s", add_edges=(rng.integers(0, g.n, 30),
                                        rng.integers(0, g.n, 30)))
        svc.recolor("s")
    done = svc.maintain("s")
    assert "compact" in done["s"]
    assert svc.maintain() == {"s": []}        # sweep: nothing left due
    assert is_valid_coloring(svc._touch("s").graph, svc.colors("s"))


# --------------------------------------------------------------------------
# durability: checkpoint -> kill -> restore (faultlab scenario, §17 x §19)
# --------------------------------------------------------------------------

def test_spilled_session_survives_service_kill(tmp_path):
    from repro.dynamic.session import ColoringSession

    g = _graph(n=120, m=600, seed=4)
    ref = repro.open_session(g)
    rng = np.random.default_rng(11)

    svc = ColoringService(pool_size=1, queue_limit=16,
                          spill_dir=str(tmp_path))
    svc.open_session("live", g)
    for _ in range(5):
        k = 10
        a, b = rng.integers(0, g.n, k), rng.integers(0, g.n, k)
        svc.apply_delta("live", add_edges=(a, b))
        svc.recolor("live")
        ref.apply_delta(add_edges=(a, b))
        ref.recolor()
    svc.open_session("other", g)              # spill "live" durably
    svc.shutdown()                            # the "kill"
    del svc

    rest = ColoringSession.restore(str(tmp_path / "live"))
    assert rest.recovery is not None and not rest.recovery["truncated"]
    np.testing.assert_array_equal(rest.colors, ref.colors)
    # post-restore lockstep: restored session behaves like the original
    a, b = rng.integers(0, g.n, 10), rng.integers(0, g.n, 10)
    rest.apply_delta(add_edges=(a, b))
    rest.recolor()
    ref.apply_delta(add_edges=(a, b))
    ref.recolor()
    np.testing.assert_array_equal(rest.colors, ref.colors)


def test_spill_journal_corruption_is_detected(tmp_path):
    from repro import faultlab
    from repro.dynamic.session import ColoringSession

    g = _graph(seed=5)
    svc = ColoringService(pool_size=1, queue_limit=16,
                          spill_dir=str(tmp_path))
    svc.open_session("live", g)
    svc.open_session("other", g)              # spill "live": snapshot on disk
    svc.colors("live")                        # restore; journal reattached
    rng = np.random.default_rng(2)
    for _ in range(4):                        # journaled through the service
        svc.apply_delta("live", add_edges=(rng.integers(0, g.n, 8),
                                           rng.integers(0, g.n, 8)))
        svc.recolor("live")
    svc.shutdown()

    faultlab.truncate_journal(str(tmp_path / "live"), mode="tear")
    rest = ColoringSession.restore(str(tmp_path / "live"))
    assert rest.recovery["truncated"]         # detector fires
    # the torn tail may have cut a recolor record, leaving its delta's
    # frontier legitimately pending — one repair restores validity
    rest.recolor()
    assert rest.validate()


# --------------------------------------------------------------------------
# telemetry
# --------------------------------------------------------------------------

def test_trace_spans_cover_requests_and_microbatches():
    g = _graph()
    with ColoringService(pool_size=2, queue_limit=16, trace=True) as svc:
        svc.open_session("s", g)
        svc.recolor("s")
        svc.color(g)
        names = {e.name for e in svc.take_spans()}
    assert "serve_request" in names and "serve_microbatch" in names
    assert svc.take_spans() == []             # drained


def test_metrics_shape():
    g = _graph()
    with ColoringService(pool_size=2, queue_limit=16) as svc:
        svc.open_session("s", g)
        svc.color(g)
        m = svc.metrics()
    for key in ("admitted", "completed", "rejected", "queue_depth",
                "queue_limit", "pool_occupancy", "pool_size",
                "bucket_jit_hits", "bucket_jit_misses",
                "session_engine_cache_hits", "session_engine_cache_misses",
                "ewma_request_seconds", "buckets"):
        assert key in m, key
    assert m["pool_occupancy"] == 1 and m["queue_depth"] == 0
