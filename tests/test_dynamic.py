"""Streaming incremental recoloring engine (DESIGN.md §14).

Covers the DeltaCSR overlay semantics (property-style consistency against a
rebuilt-from-scratch CSR), the ColoringSession guarantees (validity after
churn, empty-delta bit-identity, full=True cold parity, frontier-
proportional work), the batch-session layer, and the api registration.
"""
import numpy as np
import pytest

from repro import api
from repro.core import (
    SessionBatch,
    color_data_driven,
    is_valid_coloring,
    open_session_batch,
)
from repro.core.csr import csr_from_edges
from repro.core.serial import color_serial
from repro.dynamic import (
    ColoringSession,
    DeltaCSR,
    churn_delta,
    open_session,
)
from repro.graphs import build_graph, erdos_renyi, grid2d, power_law

# --------------------------------------------------------------------------
# DeltaCSR: overlay semantics vs a rebuilt-from-scratch CSR
# --------------------------------------------------------------------------


def _ref_apply(edges: set, op: str, a, b):
    """Track the same mutation on a plain python edge set (the oracle)."""
    for x, y in zip(np.atleast_1d(a), np.atleast_1d(b)):
        x, y = int(x), int(y)
        if x == y:
            continue
        if op == "add":
            edges.add((min(x, y), max(x, y)))
        else:
            edges.discard((min(x, y), max(x, y)))


def _ref_graph(n: int, edges: set):
    if not edges:
        return csr_from_edges(n, np.zeros(0, np.int64), np.zeros(0, np.int64))
    arr = np.array(sorted(edges), dtype=np.int64)
    return csr_from_edges(n, arr[:, 0], arr[:, 1])


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delta_random_sequence_matches_scratch_rebuild(seed):
    """Random insert/delete batches == rebuilding the CSR from scratch."""
    g0 = erdos_renyi(300, 5.0, seed=seed)
    d = DeltaCSR(g0, compact_frac=0.2)
    src, dst = g0.edges()
    und = src < dst
    ref = set(zip(src[und].tolist(), dst[und].tolist()))
    rng = np.random.default_rng(seed + 100)
    for step in range(30):
        k = int(rng.integers(1, 20))
        if rng.random() < 0.5:
            a = rng.integers(0, d.n, k)
            b = rng.integers(0, d.n, k)
            d.add_edges(a, b)
            _ref_apply(ref, "add", a, b)
        else:
            # mix genuine deletions with misses (no-ops)
            cs, cd = d.graph().edges()
            if cs.size:
                pick = rng.integers(0, cs.size, k)
                a, b = cs[pick], cd[pick]
            else:
                a = rng.integers(0, d.n, k)
                b = rng.integers(0, d.n, k)
            d.remove_edges(a, b)
            _ref_apply(ref, "del", a, b)
        if step % 7 == 3:
            gr = _ref_graph(d.n, ref)
            gc = d.graph()
            np.testing.assert_array_equal(gc.row_offsets, gr.row_offsets)
            np.testing.assert_array_equal(gc.col_indices, gr.col_indices)
    gc = d.compact()
    gr = _ref_graph(d.n, ref)
    np.testing.assert_array_equal(gc.row_offsets, gr.row_offsets)
    np.testing.assert_array_equal(gc.col_indices, gr.col_indices)
    np.testing.assert_array_equal(gc.degrees, gr.degrees)
    assert d.overlay_size == 0


def test_delta_noop_mutations_dirty_nothing():
    g = grid2d(6, 6)
    d = DeltaCSR(g)
    src, dst = g.edges()
    # re-adding existing edges: no-op, no dirty ids
    assert d.add_edges(src[:5], dst[:5]).size == 0
    # removing absent edges: no-op
    assert d.remove_edges([0, 1], [35, 30]).size == 0
    assert d.overlay_size == 0
    np.testing.assert_array_equal(d.graph().col_indices, g.col_indices)
    # self loops are dropped
    assert d.add_edges([3, 3], [3, 3]).size == 0


def test_delta_add_remove_roundtrip_restores_graph():
    g = erdos_renyi(120, 4.0, seed=9)
    d = DeltaCSR(g)
    dirty = d.add_edges([0, 1], [50, 60])
    assert set(dirty.tolist()) == {0, 1, 50, 60}
    dirty = d.remove_edges([0, 1], [50, 60])
    assert set(dirty.tolist()) == {0, 1, 50, 60}
    gc = d.compact()
    np.testing.assert_array_equal(gc.row_offsets, g.row_offsets)
    np.testing.assert_array_equal(gc.col_indices, g.col_indices)


def test_delta_vertex_semantics():
    g = grid2d(5, 5)
    d = DeltaCSR(g)
    ids = d.add_vertices(3)
    np.testing.assert_array_equal(ids, [25, 26, 27])
    assert d.n == 28 and d.graph().n == 28
    assert d.graph().degrees[25:].sum() == 0  # isolated until wired
    d.add_edges([25, 26], [0, 25])
    assert d.graph().degrees[25] == 2
    # removing a vertex drops its edges but keeps the slot (ids stable)
    touched = d.remove_vertices([25])
    assert 25 in touched and 0 in touched and 26 in touched
    assert d.n == 28
    assert d.graph().degrees[25] == 0
    # the ex-neighbor kept its other edges
    assert d.graph().degrees[0] == g.degrees[0]
    # removing an already-isolated vertex is a no-op and dirties nobody,
    # even when batched with a connected one (25's removal above isolated 26)
    assert d.remove_vertices([25]).size == 0
    touched = d.remove_vertices([26, 27, 0])
    assert 27 not in touched and 26 not in touched  # both edge-less no-ops
    assert 0 in touched  # 0 really lost its grid edges


def test_delta_auto_compaction_preserves_graph():
    g = erdos_renyi(150, 4.0, seed=3)
    d = DeltaCSR(g, compact_frac=0.01)
    rng = np.random.default_rng(5)
    for _ in range(10):
        d.add_edges(rng.integers(0, 150, 30), rng.integers(0, 150, 30))
    assert d.compactions > 0
    # graph is identical whether or not compaction fired mid-sequence
    d2 = DeltaCSR(g, compact_frac=1e9)
    rng = np.random.default_rng(5)
    for _ in range(10):
        d2.add_edges(rng.integers(0, 150, 30), rng.integers(0, 150, 30))
    assert d2.compactions == 0
    np.testing.assert_array_equal(
        d.graph().col_indices, d2.graph().col_indices)


# --------------------------------------------------------------------------
# ColoringSession guarantees
# --------------------------------------------------------------------------


_churn = churn_delta  # the shared workload generator IS the tested one


def test_open_session_from_coo_and_graph():
    g = erdos_renyi(200, 5.0, seed=1)
    src, dst = g.edges()
    s1 = open_session(src, dst, n=g.n)
    s2 = open_session(g)
    np.testing.assert_array_equal(s1.colors, s2.colors)
    assert s1.validate() and s2.validate()
    with pytest.raises(ValueError, match="max endpoint"):
        open_session([0, 5], [1, 2], n=3)
    with pytest.raises(TypeError, match="CSRGraph"):
        open_session("nope")


def test_cold_session_matches_fused_ragged():
    g = power_law(600, 6.0, seed=7)
    s = open_session(g)
    ref = color_data_driven(g, mode="fused", engine="ragged")
    np.testing.assert_array_equal(s.colors, ref.colors)


def test_empty_delta_recolor_is_bit_identical_noop():
    g = erdos_renyi(300, 5.0, seed=2)
    s = open_session(g)
    before = s.colors.copy()
    r = s.recolor()
    np.testing.assert_array_equal(r.colors, before)
    assert r.work_items == 0 and r.padded_work == 0 and r.iterations == 0
    assert r.converged
    # no-op mutations also leave the frontier empty
    src, dst = g.edges()
    s.apply_delta(add_edges=(src[:3], dst[:3]))
    assert s.frontier().size == 0
    r = s.recolor()
    np.testing.assert_array_equal(r.colors, before)
    assert r.work_items == 0


@pytest.mark.parametrize("mode", ["fused", "workefficient"])
def test_recolor_after_churn_is_valid(mode):
    """Validity matches the serial oracle's on the mutated graph."""
    g = erdos_renyi(800, 6.0, seed=4)
    s = open_session(g, mode=mode)
    rng = np.random.default_rng(11)
    for _ in range(3):
        rem, add = _churn(s.graph, 0.02, rng)
        s.apply_delta(remove_edges=rem, add_edges=add)
        r = s.recolor()
        assert r.converged and r.algorithm == "dynamic_sgr"
        oracle = color_serial(s.graph)
        assert is_valid_coloring(s.graph, r.colors) == is_valid_coloring(
            s.graph, oracle.colors) == True  # noqa: E712


def test_insertion_conflict_is_repaired():
    # two same-colored vertices forced adjacent must split colors
    g = grid2d(10, 10)  # bipartite: 2 colors, lots of same-color pairs
    s = open_session(g)
    c = s.colors
    same = np.argwhere(c[:, None] == c[None, :])
    pair = next((p for p in same if p[0] < p[1]), None)
    assert pair is not None
    u, v = int(pair[0]), int(pair[1])
    s.apply_delta(add_edges=([u], [v]))
    r = s.recolor()
    assert r.colors[u] != r.colors[v]
    assert s.validate()


def test_deletion_only_churn_stays_valid():
    g = erdos_renyi(400, 6.0, seed=6)
    s = open_session(g)
    src, dst = g.edges()
    und = src < dst
    s.apply_delta(remove_edges=(src[und][:40], dst[und][:40]))
    r = s.recolor()
    assert s.validate() and r.converged
    assert r.work_items < g.n  # frontier-sized, not n-sized


def test_vertex_stream_grow_and_remove():
    g = erdos_renyi(200, 5.0, seed=8)
    s = open_session(g)
    ids = s.apply_delta(add_vertices=2, add_edges=([200, 200, 201],
                                                   [0, 201, 5]))
    assert {200, 201} <= set(ids.tolist())
    r = s.recolor()
    assert s.n == 202 and r.colors.shape[0] == 202
    assert s.validate()
    assert r.colors[200] > 0 and r.colors[201] > 0
    s.apply_delta(remove_vertices=[200])
    r = s.recolor()
    assert s.validate()
    assert r.colors[200] == 1  # isolated slot takes the trivial color


def test_full_escape_hatch_is_bit_identical_to_cold():
    g = power_law(700, 6.0, seed=10)
    s = open_session(g)
    rng = np.random.default_rng(13)
    rem, add = _churn(g, 0.05, rng)
    s.apply_delta(remove_edges=rem, add_edges=add)
    r = s.recolor(full=True)
    gc = s.delta.graph()
    assert s.delta.overlay_size == 0  # compacted
    ref = color_data_driven(gc, mode="fused", engine="ragged")
    np.testing.assert_array_equal(r.colors, ref.colors)
    assert r.work_items == ref.work_items
    assert s.frontier().size == 0


def test_incremental_work_is_frontier_proportional():
    """Acceptance: >= 5x less work than cold color at 1% churn."""
    g = build_graph("G3_circuit", 0.02)
    s = open_session(g)
    rng = np.random.default_rng(21)
    rem, add = _churn(g, 0.01, rng)
    s.apply_delta(remove_edges=rem, add_edges=add)
    r = s.recolor()
    cold = color_data_driven(s.graph, mode="fused")
    assert s.validate()
    assert cold.work_items >= 5 * r.work_items, (
        f"incremental work {r.work_items} not frontier-proportional vs "
        f"cold {cold.work_items}")


def test_session_survives_many_rounds_with_compaction():
    g = erdos_renyi(500, 5.0, seed=14)
    s = open_session(g, compact_frac=0.05)
    rng = np.random.default_rng(15)
    for _ in range(6):
        rem, add = _churn(s.graph, 0.03, rng)
        s.apply_delta(remove_edges=rem, add_edges=add)
        s.recolor()
        assert s.validate()
    assert s.delta.compactions > 0


# --------------------------------------------------------------------------
# batch sessions + api layer
# --------------------------------------------------------------------------


def test_session_batch_only_dirty_graphs_pay():
    graphs = [erdos_renyi(250 + 17 * i, 5.0, seed=i) for i in range(4)]
    sb = open_session_batch(graphs)
    assert isinstance(sb, SessionBatch) and sb.B == 4
    rng = np.random.default_rng(16)
    rem, add = _churn(graphs[2], 0.05, rng)
    sb.apply_delta(2, remove_edges=rem, add_edges=add)
    assert sb.dirty() == [2]
    before = [s.colors.copy() for s in sb.sessions]
    results = sb.recolor()
    assert len(results) == 4
    for b in (0, 1, 3):
        np.testing.assert_array_equal(results[b].colors, before[b])
        assert results[b].work_items == 0
    assert results[2].work_items > 0
    assert sb.validate()
    # matches a standalone session fed the same delta
    solo = ColoringSession(graphs[2])
    solo.apply_delta(remove_edges=rem, add_edges=add)
    np.testing.assert_array_equal(
        solo.recolor().colors, results[2].colors)


def test_api_registration_and_cold_parity():
    assert "dynamic" in api.algorithms()
    g = erdos_renyi(300, 5.0, seed=17)
    r = api.color(g, algorithm="dynamic")
    ref = api.color(g, algorithm="fused", engine="ragged")
    np.testing.assert_array_equal(r.colors, ref.colors)
    s = api.open_session(g)
    assert s.validate()


def test_recolor_nonconvergence_raises_and_preserves_state():
    g = erdos_renyi(400, 6.0, seed=18)
    s = open_session(g)
    before = s.colors.copy()
    # cripple the incremental engine AFTER the healthy cold start: tail off
    # + 1 super-step means the frontier cannot settle
    s._tail_serial = None
    s._max_iters = 1
    rng = np.random.default_rng(19)
    rem, add = _churn(g, 0.05, rng)
    s.apply_delta(remove_edges=rem, add_edges=add)
    with pytest.raises(RuntimeError, match="before converging"):
        s.recolor()
    np.testing.assert_array_equal(s.colors, before)  # not committed
    assert s.frontier().size > 0  # delta still pending
