"""Telemetry substrate tests (DESIGN.md §16): rings, traces, spans, export.

Four layers, matching the module layout:

* pure ring/record mechanics (``resolve_trace_cap``, ``HostRing``,
  ``ring_rows``, ``RunTrace`` round-trips) — no jax;
* engine integration: every engine family called with ``trace=True``
  attaches a ``RunTrace`` whose structural invariants hold
  (``retired + conflicts == live`` per row, worklist continuity,
  ``Σ retired == initial worklist``, ``Σ cells == padded_work`` on
  single-graph engines) and whose mode-specific work linkage matches
  ``ColoringResult`` (workefficient: ``Σ live == work_items``; fused:
  ``Σ conflicts == work_items`` — the boot row charges the first
  super-step's incoming worklist);
* conflict counts against hand-built oracles: an edgeless graph retires
  everything in one conflict-free step, a clique's final ``max_color``
  is its order, the serial-tail row drains its worklist with
  ``conflicts == 0``;
* spans (compile-vs-execute jit attribution) and the Chrome-trace
  export / text-report round-trip.
"""
import json

import numpy as np
import pytest

from repro import api
from repro.core import (
    CSRGraph,
    color_data_driven,
    csr_from_edges,
    is_valid_coloring,
)
from repro.core.batch import color_batch_fused
from repro.d2 import color_distance2
from repro.graphs import build_graph
from repro.obs import (
    NF,
    HostRing,
    RunTrace,
    chrome_trace,
    empty_trace,
    export_chrome_trace,
    jit_span,
    recorder,
    resolve_trace_cap,
    ring_rows,
    span,
)
from repro.obs import report as obs_report
from repro.obs.spans import jit_key_seen


def _suite_graph():
    return build_graph("rmat-g", 0.01)


# ---------------------------------------------------------------- mechanics


def test_resolve_trace_cap():
    assert resolve_trace_cap(False) == 0
    assert resolve_trace_cap(None) == 0
    assert resolve_trace_cap(0) == 0
    assert resolve_trace_cap(-3) == 0
    assert resolve_trace_cap(True) == 512
    assert resolve_trace_cap(7) == 7
    # max_iters bounds the ring (+2 for the host's boot/tail rows)
    assert resolve_trace_cap(True, max_iters=10) == 12
    assert resolve_trace_cap(4, max_iters=100) == 4


def test_host_ring_drop_oldest():
    ring = HostRing(3)
    for i in range(5):
        ring.append(live=10 - i, retired=1, conflicts=9 - i, max_color=i,
                    cells=i)
    rows = ring.rows()
    assert ring.recorded == 5
    assert rows.shape == (3, NF)
    # kept window is the most recent 3 rows, in order
    np.testing.assert_array_equal(rows[:, 0], [8, 7, 6])


def test_device_ring_rows_wrap():
    cap = 4
    buf = np.zeros((cap, NF), np.int32)
    for s in range(6):                       # writes at s % cap
        buf[s % cap, 0] = 100 + s
    rows = ring_rows(buf, 6)
    np.testing.assert_array_equal(rows[:, 0], [102, 103, 104, 105])
    assert ring_rows(buf, 0).shape == (0, NF)
    assert ring_rows(buf, 2).shape == (2, NF)


def test_runtrace_roundtrip_and_summary():
    steps = np.array([[8, 0, 8, 1, 0, 0, 0, 0],
                      [8, 5, 3, 2, 64, 0, 0, 0],
                      [3, 3, 0, 3, 24, 1, 0, 0]], np.int64)
    t = RunTrace(steps=steps, iterations=3, engine="unit")
    assert t.check() == []
    assert t.tail_step == 2
    t2 = RunTrace.from_dict(t.to_dict())
    np.testing.assert_array_equal(t2.steps, t.steps)
    s = t.summary(max_points=2)
    assert s["supersteps"] == 3 and s["series_from"] == 1
    assert s["live"] == [8, 3] and s["conflicts"] == [3, 0]
    assert "halo_bytes" not in s          # all-zero series is omitted


def test_runtrace_check_catches_broken_rows():
    steps = np.array([[8, 0, 8, 1, 0, 0, 0, 0],
                      [8, 4, 3, 2, 64, 0, 0, 0]], np.int64)  # 4 + 3 != 8
    bad = RunTrace(steps=steps, iterations=2).check()
    assert any("retired + conflicts" in b for b in bad)
    steps2 = np.array([[8, 0, 8, 1, 0, 0, 0, 0],
                       [5, 5, 0, 2, 64, 0, 0, 0]], np.int64)  # 8 != live 5
    bad2 = RunTrace(steps=steps2, iterations=2).check()
    assert any("continuity" in b for b in bad2)


# ---------------------------------------------------- engine integration


def _assert_coherent(result, *, batch=False):
    t = result.trace
    assert isinstance(t, RunTrace)
    assert t.check(result) == [], t.check(result)
    s = t.steps
    if s.shape[0] == 0:
        return t
    if t.dropped == 0:
        assert int(t.series("retired").sum()) == int(s[0, 0])
        cells = int(t.series("cells").sum())
        if batch:
            assert cells <= result.padded_work
        else:
            assert cells == result.padded_work
    assert int(s[-1, 3]) == result.num_colors
    return t


def test_trace_off_attaches_nothing():
    g = _suite_graph()
    assert color_data_driven(g).trace is None
    assert color_data_driven(g, mode="fused").trace is None


@pytest.mark.parametrize("mode", ["workefficient", "fused"])
def test_single_graph_trace_invariants_and_work_linkage(mode):
    g = _suite_graph()
    off = color_data_driven(g, mode=mode, tail_serial=False)
    on = color_data_driven(g, mode=mode, tail_serial=False, trace=True)
    np.testing.assert_array_equal(off.colors, on.colors)
    assert off.iterations == on.iterations
    t = _assert_coherent(on)
    assert t.iterations == on.iterations
    assert t.tail_step == -1
    # mode-specific work linkage (no tail, no ring drop)
    if mode == "workefficient":
        assert int(t.series("live").sum()) == on.work_items
    else:
        assert int(t.series("conflicts").sum()) == on.work_items


@pytest.mark.parametrize("engine", ["classic", "ragged", "padded"])
def test_engine_matrix_traces(engine):
    g = _suite_graph()
    opts = {"engine": engine, "trace": True}
    if engine == "ragged":
        opts["mode"] = "fused"
    r = color_data_driven(g, **opts)
    _assert_coherent(r)
    assert is_valid_coloring(g, r.colors)


def test_distance2_trace():
    g = _suite_graph()
    r = color_distance2(g, trace=True)
    t = _assert_coherent(r)
    assert "superstep_loop" in {e.name for e in t.spans}


def test_batch_traces_per_graph():
    graphs = [build_graph("rmat-g", 0.01), build_graph("G3_circuit", 0.01)]
    plain = color_batch_fused(graphs)
    traced = color_batch_fused(graphs, trace=True)
    for off, on in zip(plain, traced):
        np.testing.assert_array_equal(off.colors, on.colors)
        assert off.iterations == on.iterations
        _assert_coherent(on, batch=True)
        assert on.trace.spans, "batch results must share the recorded spans"


def test_ring_wraparound_keeps_coherent_window():
    g = _suite_graph()
    full = color_data_driven(g, mode="fused", tail_serial=False, trace=True)
    tiny = color_data_driven(g, mode="fused", tail_serial=False, trace=2)
    t = tiny.trace
    assert t.iterations == full.trace.iterations
    assert t.dropped == t.iterations - 2 > 0
    assert t.check() == [], t.check()        # kept window stays contiguous
    np.testing.assert_array_equal(t.steps, full.trace.steps[-2:])


# -------------------------------------------------------- hand-built oracles


def test_edgeless_graph_oracle():
    """No edges: everything retires in one conflict-free super-step."""
    n = 17
    g = CSRGraph(np.zeros(n + 1, np.int64), np.zeros(0, np.int32))
    r = color_data_driven(g, mode="fused", tail_serial=False, trace=True)
    t = r.trace
    np.testing.assert_array_equal(t.series("live"), [n, n])
    np.testing.assert_array_equal(t.series("conflicts"), [n, 0])
    np.testing.assert_array_equal(t.series("retired"), [0, n])
    assert int(t.steps[-1, 3]) == 1          # one color suffices


def test_clique_oracle():
    """K6 needs exactly 6 colors; the trace's final max_color agrees with
    both the result and the validator's view of the colors array."""
    k = 6
    src, dst = np.triu_indices(k, 1)
    g = csr_from_edges(k, src.astype(np.int64), dst.astype(np.int64))
    r = color_data_driven(g, mode="fused", tail_serial=False, trace=True)
    assert is_valid_coloring(g, r.colors)
    assert r.num_colors == k
    t = r.trace
    assert int(t.steps[-1, 3]) == k == int(np.max(r.colors))
    # conflicts strictly shrink: a clique retires >= 1 vertex per step
    conf = t.series("conflicts")
    assert all(conf[i] > conf[i + 1] for i in range(len(conf) - 1))


def test_serial_tail_row_semantics():
    """Force the tail: its row is last, drains the surviving worklist
    (conflicts == 0), and tail_step points at it."""
    g = _suite_graph()
    r = color_data_driven(g, mode="fused", tail_serial=g.n, trace=True)
    t = r.trace
    assert t.tail_step >= 0
    last = t.steps[-1]
    assert int(last[5]) == 1 and int(last[2]) == 0
    assert t.tail_step == t.dropped + t.steps.shape[0] - 1
    assert t.check(r) == [], t.check(r)


def test_empty_graph_trace():
    g = CSRGraph(np.zeros(1, np.int64), np.zeros(0, np.int32))
    r = color_data_driven(g, trace=True)
    assert r.trace is not None
    assert r.trace.iterations == 0 and r.trace.check(r) == []
    assert empty_trace("x").tail_step == -1


# ------------------------------------------------------------------- spans


def test_span_noop_without_recorder():
    with span("never_kept"):
        pass
    with recorder() as rec:
        with span("kept", answer=42):
            pass
    assert [e.name for e in rec.events] == ["kept"]
    assert rec.events[0].meta == {"answer": 42}


def test_jit_span_compile_then_execute():
    key = ("test_obs", "unique-key-A")
    with recorder() as rec:
        with jit_span("dispatch", key):
            pass
        with jit_span("dispatch", key):
            pass
    cats = [e.cat for e in rec.events]
    assert cats == ["compile", "execute"]
    agg = rec.by_name()["dispatch"]
    assert agg["count"] == 2
    assert agg["compile_seconds"] <= agg["seconds"]


def test_jit_key_registry_advances_unrecorded():
    """A dispatch nobody recorded still warms the key, so the first
    *recorded* dispatch of a warm key is labeled execute, not compile."""
    key = ("test_obs", "unique-key-B")
    with jit_span("dispatch", key):          # no recorder active
        pass
    assert jit_key_seen(key) is True
    with recorder() as rec:
        with jit_span("dispatch", key):
            pass
    assert rec.events[0].cat == "execute"


def test_engine_spans_visible_to_outer_recorder():
    g = _suite_graph()
    with recorder() as rec:
        r = color_data_driven(g, mode="fused", trace=True)
    names = {e.name for e in rec.events}
    assert {"csr_build", "superstep_loop"} <= names
    # the engine's internal recorder captured the same phases on the trace
    assert {"csr_build", "superstep_loop"} <= {e.name for e in r.trace.spans}


def test_session_metrics_and_jit_cache_accounting():
    g = _suite_graph()
    session = api.open_session(g, trace=True)
    rng = np.random.default_rng(5)
    from repro.dynamic import churn_delta

    for _ in range(2):
        rem, add = churn_delta(session.graph, 0.02, rng)
        session.apply_delta(remove_edges=rem, add_edges=add)
        inc = session.recolor()
    assert inc.trace is not None and inc.trace.check(inc) == []
    assert inc.trace.spans, "session recolor must attach spans"
    m = session.metrics()
    assert m["deltas"] == 2 and m["recolors"] == 2
    assert m["engine_cache_hits"] + m["engine_cache_misses"] == 2
    assert m["engine_cache_misses"] >= 1     # first round always compiles
    assert m["supersteps_total"] > 0 and m["work_total"] > 0
    assert m["pending_frontier"] == 0
    assert session.validate()


# ------------------------------------------------------- export and report


def test_chrome_export_roundtrip(tmp_path):
    g = _suite_graph()
    r = color_data_driven(g, mode="fused", trace=True)
    path = tmp_path / "trace.json"
    export_chrome_trace(str(path), {"fused/rmat-g": r})
    doc = json.loads(path.read_text())
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert phases <= {"M", "X", "C", "I"}
    assert any(e["ph"] == "X" and e["name"] == "superstep_loop"
               for e in doc["traceEvents"])
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len([e for e in counters if e["name"] == "worklist"]) \
        == r.trace.steps.shape[0]
    # otherData.repro reconstructs the full RunTrace
    back = RunTrace.from_dict(doc["otherData"]["repro"]["fused/rmat-g"])
    np.testing.assert_array_equal(back.steps, r.trace.steps)
    # the text reporter accepts the exported file
    assert obs_report.main([str(path)]) == 0


def test_chrome_export_skips_untraced_runs():
    g = _suite_graph()
    doc = chrome_trace({"off": color_data_driven(g)})
    assert doc["traceEvents"] == [] and doc["otherData"]["repro"] == {}


def test_report_formats():
    g = _suite_graph()
    r = color_data_driven(g, mode="fused", trace=True)
    line = obs_report.format_result("fused", r)
    assert "colors=" in line and "work=" in line
    table = obs_report.format_trace(r.trace, last=3)
    assert "live" in table and str(r.trace.iterations) in table
    assert obs_report.format_spans(r.trace.spans).count("\n") >= 1
    block = obs_report.format_metrics({"a": 1, "bb": 2.5}, "t:")
    assert block.splitlines()[0] == "t:" and " a " in block


def test_report_bench_document(tmp_path):
    doc = {
        "schema": 6, "backend": "jax", "engine": "ragged",
        "algorithms": {"fused": {"g": {
            "colors": 3, "valid": True,
            "trace": {"supersteps": 2, "tail_step": -1, "series_from": 0,
                      "live": [4, 4], "retired": [0, 4],
                      "conflicts": [4, 0], "max_color": [1, 3],
                      "cells": [0, 32]},
        }}},
        "dynamic": {"g": {
            "rounds_detail": [{"round": 0, "frontier": 9, "work": 40,
                               "supersteps": 3, "tail_step": 2,
                               "cache_hit": False}],
            "jit": {"hits": 0, "misses": 1},
        }},
    }
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps(doc))
    assert obs_report.main([str(p)]) == 0
    assert obs_report.main([str(tmp_path / "missing--"), "x"]) == 2
