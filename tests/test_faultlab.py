"""§17 fault-injection matrix: every detector fires, every recovery heals.

Four injected fault families (``repro.faultlab``), each asserted twice —
once that the corruption is *detected* (never silently accepted) and once
that the §17 recovery path (guarantee ladder, journal replay, full
recolor) restores a valid state.
"""
import numpy as np
import pytest

from repro import faultlab
from repro.api import color, open_session
from repro.core import csr_from_edges, is_valid_coloring
from repro.core.guarantee import residual_vertices, serial_repair
from repro.ingest import check_halo_words, pack_halo_words


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(11)
    n = 300
    return csr_from_edges(n, rng.integers(0, n, 2200),
                          rng.integers(0, n, 2200))


# --------------------------------------------------------------------------
# fault 1: colors corrupted between engine and commit
# --------------------------------------------------------------------------

def test_corrupt_colors_is_detected(graph):
    with faultlab.corrupt_colors(fraction=0.05, seed=3):
        r = color(graph, "data_driven")
    assert not is_valid_coloring(graph, r.colors)  # detector fires


def test_corrupt_colors_recovered_by_ladder(graph):
    with faultlab.corrupt_colors(fraction=0.05, seed=3):
        r = color(graph, "data_driven", ensure_valid=True)
    assert r.converged
    assert is_valid_coloring(graph, r.colors)
    rungs = [d["rung"] for d in r.degradations if d["stage"] == "ladder"]
    assert rungs, r.degradations  # the escalation is on the ledger


def test_corrupt_colors_restores_registry(graph):
    with faultlab.corrupt_colors():
        pass
    r = color(graph, "data_driven")
    assert is_valid_coloring(graph, r.colors)  # patching fully undone


def test_corrupt_session_colors_full_recolor_heals(graph):
    s = open_session(graph)
    assert s.validate()
    # fault lands directly on the committed colors (device-memory model)
    s.colors = faultlab._corrupt(s.graph, s.colors, 0.05, seed=1)
    assert not s.validate()                  # detector
    s.recolor(full=True)
    assert s.validate()                      # recovery


def test_serial_repair_survives_garbage_colors(graph):
    # even colors far outside any legal range must not break the repair
    rng = np.random.default_rng(0)
    colors = rng.integers(-5, 10**6, graph.n).astype(np.int32)
    colors[:10] = 0           # uncolored
    colors[10:20] = -3        # negative garbage
    residual = residual_vertices(graph, colors)
    out = serial_repair(graph, colors, np.arange(graph.n), order="oracle")
    assert is_valid_coloring(graph, out)
    assert residual.size >= 20  # the planted defects are all caught


# --------------------------------------------------------------------------
# fault 2: poisoned packed halo words
# --------------------------------------------------------------------------

def test_poisoned_halo_words_detected():
    n = 200
    rng = np.random.default_rng(4)
    ids = rng.integers(0, n, 64)
    colors = rng.integers(1, 12, 64)
    words = pack_halo_words(ids, colors)
    assert check_halo_words(words, n).size == 0      # clean words pass
    poisoned = faultlab.poison_halo_words(words, n, fraction=0.25, seed=9)
    bad = check_halo_words(poisoned, n)
    changed = np.nonzero(poisoned != words)[0]
    assert changed.size > 0
    assert set(changed) <= set(bad.tolist())         # every poison detected


def test_poison_covers_all_flavors():
    words = pack_halo_words(np.zeros(30, np.int64), np.ones(30, np.int64))
    poisoned = faultlab.poison_halo_words(words, 30, fraction=1.0, seed=0)
    assert (poisoned < 0).any()                      # negative word
    ids = (poisoned.astype(np.int64) >> 16)
    assert (ids > 30).any()                          # out-of-range id
    cols = poisoned & 0xFFFF
    assert ((poisoned >= 0) & (cols > 30)).any()     # impossible color


# --------------------------------------------------------------------------
# fault 3: torn / corrupted write-ahead journal
# --------------------------------------------------------------------------

def _churn(s, n, seed, rounds=6):
    rng = np.random.default_rng(seed)
    for i in range(rounds):
        k = max(1, n // 100)  # ~1% churn per round
        s.apply_delta(add_edges=(rng.integers(0, n, k),
                                 rng.integers(0, n, k)))
        if i % 2:
            s.apply_delta(remove_edges=(rng.integers(0, n, k // 2 + 1),
                                        rng.integers(0, n, k // 2 + 1)))
        s.recolor()


def test_checkpoint_kill_restore_bit_identical(graph, tmp_path):
    """The §17 acceptance scenario: durable session under 1% churn, killed,
    restored — colors, counters, and future behavior all bit-identical to
    the uninterrupted twin."""
    ref = open_session(graph)
    dur = open_session(graph, durable_dir=str(tmp_path), snapshot_every=5)
    _churn(ref, graph.n, 21)
    _churn(dur, graph.n, 21)
    del dur                                   # the "kill"
    from repro.dynamic.session import ColoringSession

    rest = ColoringSession.restore(str(tmp_path))
    assert rest.recovery is not None and not rest.recovery["truncated"]
    np.testing.assert_array_equal(ref.colors, rest.colors)
    assert rest.validate()
    # post-restore lockstep: the restored session behaves like the original
    _churn(ref, graph.n, 33)
    _churn(rest, graph.n, 33)
    np.testing.assert_array_equal(ref.colors, rest.colors)
    assert rest.metrics()["recolors"] == ref.metrics()["recolors"]


@pytest.mark.parametrize("mode", ["tear", "garbage"])
def test_truncated_journal_detected_and_recovered(graph, tmp_path, mode):
    s = open_session(graph, durable_dir=str(tmp_path), snapshot_every=1000)
    _churn(s, graph.n, 5, rounds=4)
    del s
    faultlab.truncate_journal(str(tmp_path), mode=mode)
    from repro.dynamic.session import ColoringSession

    rest = ColoringSession.restore(str(tmp_path))
    assert rest.recovery["truncated"]         # detector fires
    assert rest.validate()                    # last consistent state is valid
    rest.apply_delta(add_edges=(np.array([0]), np.array([1])))
    rest.recolor()                            # and the session keeps working
    assert rest.validate()


def test_dropped_tail_replays_clean_prefix(graph, tmp_path):
    s = open_session(graph, durable_dir=str(tmp_path), snapshot_every=1000)
    _churn(s, graph.n, 5, rounds=4)
    total = s.metrics()["journal_seq"]
    del s
    faultlab.truncate_journal(str(tmp_path), mode="drop", records=2)
    from repro.dynamic.session import ColoringSession

    rest = ColoringSession.restore(str(tmp_path))
    # a cleanly-shortened journal is not corruption — just an earlier state
    assert not rest.recovery["truncated"]
    assert rest.metrics()["journal_seq"] == total - 2
    assert rest.validate()


# --------------------------------------------------------------------------
# fault 4: forced non-convergence
# --------------------------------------------------------------------------

def test_starved_run_detected(graph):
    r = color(graph, "data_driven", engine="classic",
              **faultlab.starved_opts())
    assert not r.converged                    # detector: honest flag


def test_starved_run_recovered_by_ladder(graph):
    r = color(graph, "data_driven", engine="classic", ensure_valid=True,
              **faultlab.starved_opts())
    assert r.converged
    assert is_valid_coloring(graph, r.colors)
    outcomes = {d["rung"]: d["outcome"] for d in r.degradations
                if d["stage"] == "ladder"}
    assert outcomes, r.degradations
    assert any(v == "resolved" for v in outcomes.values())


def test_starved_session_raise_vs_ladder(graph):
    with pytest.raises(RuntimeError, match="ladder"):
        s = open_session(graph, **faultlab.starved_opts())
        s.apply_delta(add_edges=(np.arange(0, 100, dtype=np.int64),
                                 np.arange(100, 200, dtype=np.int64)))
        # a dense clique forces conflicts the starved engine cannot clear
        k = np.arange(40)
        src, dst = np.meshgrid(k, k)
        s.apply_delta(add_edges=(src.ravel(), dst.ravel()))
        s.recolor()
    s = open_session(graph, on_fail="ladder", **faultlab.starved_opts())
    assert s.result.converged and s.validate()
    k = np.arange(40)
    src, dst = np.meshgrid(k, k)
    s.apply_delta(add_edges=(src.ravel(), dst.ravel()))
    r = s.recolor()
    assert r.converged and s.validate()
    assert any(d["stage"] == "ladder" for d in r.degradations)


def test_ladder_trace_spans_surface(graph):
    r = color(graph, "data_driven", engine="classic", ensure_valid=True,
              trace=True, **faultlab.starved_opts())
    names = [s.name for s in r.trace.spans]
    assert "guarantee_ladder" in names
