"""Checkpointing: atomic roundtrip, retention, restart, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.models import build_model
from repro.training import init_train_state
from repro.training.checkpoint import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)


def _state():
    cfg = get_config("qwen3-4b").reduced()
    model = build_model(cfg)
    return init_train_state(model, jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 3, state)
    got = restore_checkpoint(str(tmp_path), 3, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep_last=2)
    assert list_steps(str(tmp_path)) == [4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore leaves direct to device with explicit shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 1, state)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    sh = {"w": NamedSharding(mesh, P())}
    got = restore_checkpoint(str(tmp_path), 1, state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8))
    assert got["w"].sharding == sh["w"]


def test_crash_restart_resumes_identically(tmp_path):
    """Fault tolerance: crash mid-run, resume from checkpoint, same trajectory."""
    cfg = get_config("qwen3-4b").reduced()
    ck = str(tmp_path / "ck")

    # uninterrupted run
    ref = train_loop(cfg, steps=12, batch_size=4, seq_len=16, lr=1e-3,
                     ckpt_dir=str(tmp_path / "ref"), ckpt_every=100,
                     log_every=1, seed=3)

    # crashing run: dies at step 8, checkpointing every 4
    with pytest.raises(RuntimeError):
        train_loop(cfg, steps=12, batch_size=4, seq_len=16, lr=1e-3,
                   ckpt_dir=ck, ckpt_every=4, log_every=1, seed=3,
                   fail_at_step=8)
    assert latest_step(ck) == 8
    out = train_loop(cfg, steps=12, batch_size=4, seq_len=16, lr=1e-3,
                     ckpt_dir=ck, ckpt_every=4, log_every=1, seed=3,
                     resume=True)
    # the resumed trajectory ends at the same loss as the uninterrupted one
    assert abs(out["final_loss"] - ref["final_loss"]) < 1e-3


def test_atomic_no_partial_visible(tmp_path):
    state = {"x": jnp.zeros((4,))}
    save_checkpoint(str(tmp_path), 1, state)
    names = os.listdir(tmp_path)
    assert all(not n.startswith("tmp.") for n in names)
