"""Core coloring-engine behaviour: validity, quality, work-efficiency."""
import numpy as np
import pytest

from repro.core import (
    color_data_driven,
    color_jp,
    color_multihash,
    color_threestep,
    color_topology,
    csr_from_edges,
    greedy_serial,
    is_valid_coloring,
    num_colors,
    quality_report,
)
from repro.graphs import erdos_renyi, grid2d, honeycomb, power_law, rmat

GRAPHS = {
    "er": lambda: erdos_renyi(1200, 8.0, seed=1),
    "rmat-g": lambda: rmat(1500, 10.0, seed=2),
    "grid": lambda: grid2d(30, 40),
    "powerlaw": lambda: power_law(1200, 7.0, seed=3),
    "honeycomb": lambda: honeycomb(24, 40),
}

ALGOS = {
    "serial": lambda g: greedy_serial(g),
    "data_opt": lambda g: color_data_driven(g).colors,
    "data_base": lambda g: color_data_driven(g, heuristic="id", firstfit="scan").colors,
    "data_sort": lambda g: color_data_driven(g, firstfit="sort").colors,
    "data_fused": lambda g: color_data_driven(g, mode="fused").colors,
    "data_lb": lambda g: color_data_driven(g, buckets=(8, 32)).colors,
    "data_coarse": lambda g: color_data_driven(g, coarsen_ff=4, coarsen_cr=2).colors,
    "data_lanes": lambda g: color_data_driven(g, coarsen_lanes=256).colors,
    "topo": lambda g: color_topology(g).colors,
    "jp": lambda g: color_jp(g).colors,
    "multihash": lambda g: color_multihash(g, 2).colors,
    "threestep": lambda g: color_threestep(g).colors,
}


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("aname", list(ALGOS))
def test_valid_coloring(gname, aname):
    g = GRAPHS[gname]()
    colors = ALGOS[aname](g)
    assert is_valid_coloring(g, colors), (gname, aname)


@pytest.mark.parametrize("gname", ["er", "rmat-g"])
def test_greedy_bound(gname):
    """Greedy variants respect the max_degree+1 bound; MIS variants may not."""
    g = GRAPHS[gname]()
    for aname in ("serial", "data_opt", "data_base", "topo", "threestep"):
        nc = num_colors(ALGOS[aname](g))
        assert nc <= g.max_degree + 1, aname


def test_quality_ordering_matches_paper():
    """Fig. 8: SGR-family colors ~= serial; multi-hash MIS needs far more."""
    g = GRAPHS["rmat-g"]()
    serial_c = num_colors(greedy_serial(g))
    sgr_c = num_colors(color_data_driven(g).colors)
    mis_c = num_colors(color_multihash(g, 2).colors)
    assert sgr_c <= serial_c * 1.5 + 2
    assert mis_c > sgr_c * 1.5  # MIS quality is decisively worse


def test_data_driven_work_efficiency():
    """Fig. 3: the worklist implementation does less work than topology-driven."""
    g = GRAPHS["grid"]()
    data = color_data_driven(g, heuristic="id", firstfit="bitset")
    topo = color_topology(g, heuristic="id")
    assert data.work_items < topo.work_items


def test_heuristic_reduces_iterations():
    """Fig. 4: degree-priority conflict resolve converges at least as fast."""
    g = GRAPHS["rmat-g"]()
    base = color_data_driven(g, heuristic="id")
    heur = color_data_driven(g, heuristic="degree")
    assert heur.iterations <= base.iterations + 1


def test_deterministic():
    g = GRAPHS["er"]()
    a = color_data_driven(g).colors
    b = color_data_driven(g).colors
    assert (a == b).all()


def test_empty_and_tiny_graphs():
    g0 = csr_from_edges(0, np.zeros(0, int), np.zeros(0, int))
    assert color_data_driven(g0).colors.shape == (0,)
    g1 = csr_from_edges(3, np.array([0]), np.array([1]))
    r = color_data_driven(g1)
    assert is_valid_coloring(g1, r.colors)
    # isolated vertex gets color 1
    assert r.colors[2] == 1


def test_quality_report():
    g = GRAPHS["er"]()
    rep = quality_report(g, greedy_serial(g))
    assert rep["valid"] and rep["num_colors"] <= rep["greedy_bound"]


def test_kernel_backend_path_matches():
    g = erdos_renyi(600, 6.0, seed=5)
    plain = color_data_driven(g)
    kern = color_data_driven(g, backend="pallas")
    assert is_valid_coloring(g, kern.colors)
    assert (plain.colors == kern.colors).all()  # same deterministic schedule
