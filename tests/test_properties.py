"""Hypothesis property tests (randomized sweeps against host oracles).

Collected only when hypothesis is installed (see requirements-dev.txt);
``pytest.importorskip`` skips the whole module cleanly otherwise, keeping
tier-1 collection green on minimal environments.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import open_session  # noqa: E402
from repro.core import color_data_driven, csr_from_edges  # noqa: E402
from repro.core.firstfit import FF_FUNCS  # noqa: E402
from repro.core.heuristics import (  # noqa: E402
    conflict_lose_flags,
    conflict_lose_lanes,
)
from repro.kernels.firstfit.ref import firstfit_ref  # noqa: E402
from repro.kernels.superstep.ops import superstep_tpu  # noqa: E402
from repro.kernels.superstep.ref import superstep_ref  # noqa: E402


def _oracle_row(row):
    present = set(int(c) for c in row if c > 0)
    c = 1
    while c in present:
        c += 1
    return c


@given(
    st.integers(1, 30),                   # rows
    st.integers(1, 40),                   # width
    st.integers(0, 2**31 - 1),            # seed
)
@settings(max_examples=40, deadline=None)
def test_firstfit_variants_match_oracle(w, W, seed):
    rng = np.random.default_rng(seed)
    nc = rng.integers(0, W + 3, size=(w, W)).astype(np.int32)
    want = np.array([_oracle_row(r) for r in nc], dtype=np.int32)
    for name, fn in FF_FUNCS.items():
        got = np.asarray(fn(jnp.asarray(nc)))
        np.testing.assert_array_equal(got, want, err_msg=name)
    np.testing.assert_array_equal(np.asarray(firstfit_ref(jnp.asarray(nc))), want)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_conflict_exactly_one_loser(seed):
    """For every monochromatic edge, exactly one endpoint loses (both rules)."""
    rng = np.random.default_rng(seed)
    n = 10
    deg = rng.integers(0, 7, size=n + 1).astype(np.int32)
    deg[n] = 0
    colors = rng.integers(0, 3, size=n + 1).astype(np.int32)
    colors[n] = 0
    for heuristic in ("id", "degree"):
        for u in range(n):
            for v in range(n):
                if u == v or colors[u] == 0 or colors[u] != colors[v]:
                    continue
                lu = conflict_lose_flags(
                    jnp.asarray([u]), jnp.asarray([[v]]),
                    jnp.asarray([colors[u]]), jnp.asarray([[colors[v]]]),
                    jnp.asarray([deg[u]]), jnp.asarray([[deg[v]]]), heuristic)
                lv = conflict_lose_flags(
                    jnp.asarray([v]), jnp.asarray([[u]]),
                    jnp.asarray([colors[v]]), jnp.asarray([[colors[u]]]),
                    jnp.asarray([deg[v]]), jnp.asarray([[deg[u]]]), heuristic)
                assert bool(lu[0]) != bool(lv[0]), (heuristic, u, v)


def _pure_jax_superstep(ids, nid, my_c, nc, my_d, nd, heuristic):
    """The production pure-JAX formulation of one rotated super-step, built
    from the same pieces the ragged engine composes (conflict_lose_flags +
    bitset FirstFit) — the §15 bit-identity contract in miniature."""
    same, lose = conflict_lose_lanes(ids, nid, my_c, nc, my_d, nd, heuristic)
    need = jnp.any(lose, axis=1) | (my_c == 0)
    ff = FF_FUNCS["bitset"](jnp.where(same & ~lose, 0, nc))
    return jnp.where(need, ff, my_c.astype(jnp.int32)), need


@given(
    st.integers(1, 60),                   # worklist lanes
    st.integers(1, 70),                   # tile width (crosses nwords=2)
    st.sampled_from(["id", "degree"]),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_superstep_kernel_ref_purejax_triple_agree(w, W, heuristic, seed):
    """Fuzz the §15 triple: Pallas kernel (interpret off-TPU) == independent
    quadratic oracle == production pure-JAX step, on random padded tiles."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(w + 5)[:w].astype(np.int32)
    nid = rng.integers(0, w + 5, size=(w, W)).astype(np.int32)
    my_c = rng.integers(0, W + 2, size=(w,)).astype(np.int32)
    nc = rng.integers(0, W + 2, size=(w, W)).astype(np.int32)
    my_d = rng.integers(0, 9, size=(w,)).astype(np.int32)
    nd = rng.integers(0, 9, size=(w, W)).astype(np.int32)
    args = tuple(map(jnp.asarray, (ids, nid, my_c, nc, my_d, nd)))
    kern_c, kern_n = superstep_tpu(*args, heuristic)
    ref_c, ref_n = superstep_ref(*args, heuristic)
    jax_c, jax_n = _pure_jax_superstep(*args, heuristic)
    np.testing.assert_array_equal(np.asarray(kern_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(kern_n), np.asarray(ref_n))
    np.testing.assert_array_equal(np.asarray(jax_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(jax_n), np.asarray(ref_n))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_dynamic_churn_matches_cold_recolor(seed):
    """DeltaCSR churn property (§14/§15): after any add/remove sequence the
    incremental session stays valid, its overlay graph equals a from-scratch
    CSR rebuild of the surviving edges, and ``recolor(full=True)`` is
    bit-identical to a cold fused coloring of the mutated graph."""
    rng = np.random.default_rng(seed)
    n = 60
    src = rng.integers(0, n, 150)
    dst = rng.integers(0, n, 150)
    keep = src != dst
    edges = {tuple(sorted(e)) for e in zip(src[keep], dst[keep])}
    g0 = csr_from_edges(n, src[keep], dst[keep])
    session = open_session(g0)
    assert session.validate()
    for _ in range(3):
        a_src = rng.integers(0, n, 12)
        a_dst = rng.integers(0, n, 12)
        ka = a_src != a_dst
        edges |= {tuple(sorted(e)) for e in zip(a_src[ka], a_dst[ka])}
        session.apply_delta(add_edges=(a_src[ka], a_dst[ka]))
        if edges:
            pool = sorted(edges)
            drop = [pool[i] for i in
                    rng.choice(len(pool), min(6, len(pool)), replace=False)]
            edges -= set(drop)
            r_src = np.array([e[0] for e in drop], np.int64)
            r_dst = np.array([e[1] for e in drop], np.int64)
            session.apply_delta(remove_edges=(r_src, r_dst))
        if session.frontier().size:
            session.recolor()
        assert session.validate()
    full = session.recolor(full=True)
    live = session.graph
    if edges:
        scratch = csr_from_edges(
            n, np.array([e[0] for e in edges], np.int64),
            np.array([e[1] for e in edges], np.int64))
        np.testing.assert_array_equal(live.row_offsets, scratch.row_offsets)
        np.testing.assert_array_equal(live.col_indices, scratch.col_indices)
    cold = color_data_driven(live, engine="ragged", mode="fused")
    np.testing.assert_array_equal(full.colors, cold.colors)
    assert full.iterations == cold.iterations


@given(st.integers(2, 200), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_csr_from_edges_random(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(0, 4 * n)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    g = csr_from_edges(n, src, dst)
    s2, d2 = g.edges()
    assert (s2 != d2).all()
    assert g.row_offsets[-1] == g.m
