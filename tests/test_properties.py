"""Hypothesis property tests (randomized sweeps against host oracles).

Collected only when hypothesis is installed (see requirements-dev.txt);
``pytest.importorskip`` skips the whole module cleanly otherwise, keeping
tier-1 collection green on minimal environments.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import csr_from_edges  # noqa: E402
from repro.core.firstfit import FF_FUNCS  # noqa: E402
from repro.core.heuristics import conflict_lose_flags  # noqa: E402
from repro.kernels.firstfit.ref import firstfit_ref  # noqa: E402


def _oracle_row(row):
    present = set(int(c) for c in row if c > 0)
    c = 1
    while c in present:
        c += 1
    return c


@given(
    st.integers(1, 30),                   # rows
    st.integers(1, 40),                   # width
    st.integers(0, 2**31 - 1),            # seed
)
@settings(max_examples=40, deadline=None)
def test_firstfit_variants_match_oracle(w, W, seed):
    rng = np.random.default_rng(seed)
    nc = rng.integers(0, W + 3, size=(w, W)).astype(np.int32)
    want = np.array([_oracle_row(r) for r in nc], dtype=np.int32)
    for name, fn in FF_FUNCS.items():
        got = np.asarray(fn(jnp.asarray(nc)))
        np.testing.assert_array_equal(got, want, err_msg=name)
    np.testing.assert_array_equal(np.asarray(firstfit_ref(jnp.asarray(nc))), want)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_conflict_exactly_one_loser(seed):
    """For every monochromatic edge, exactly one endpoint loses (both rules)."""
    rng = np.random.default_rng(seed)
    n = 10
    deg = rng.integers(0, 7, size=n + 1).astype(np.int32)
    deg[n] = 0
    colors = rng.integers(0, 3, size=n + 1).astype(np.int32)
    colors[n] = 0
    for heuristic in ("id", "degree"):
        for u in range(n):
            for v in range(n):
                if u == v or colors[u] == 0 or colors[u] != colors[v]:
                    continue
                lu = conflict_lose_flags(
                    jnp.asarray([u]), jnp.asarray([[v]]),
                    jnp.asarray([colors[u]]), jnp.asarray([[colors[v]]]),
                    jnp.asarray([deg[u]]), jnp.asarray([[deg[v]]]), heuristic)
                lv = conflict_lose_flags(
                    jnp.asarray([v]), jnp.asarray([[u]]),
                    jnp.asarray([colors[v]]), jnp.asarray([[colors[u]]]),
                    jnp.asarray([deg[v]]), jnp.asarray([[deg[u]]]), heuristic)
                assert bool(lu[0]) != bool(lv[0]), (heuristic, u, v)


@given(st.integers(2, 200), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_csr_from_edges_random(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(0, 4 * n)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    g = csr_from_edges(n, src, dst)
    s2, d2 = g.edges()
    assert (s2 != d2).all()
    assert g.row_offsets[-1] == g.m
