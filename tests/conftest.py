import os
import sys

import pytest

# src-layout import path (tests also work without `pip install -e .`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# single real device; only launch/dryrun.py (and subprocess tests) fake a fleet.


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop compiled XLA executables between test modules.

    The full suite jit-compiles several hundred programs; letting them all
    accumulate in one CPU client has segfaulted XLA's compiler late in the
    run.  Modules share almost no (shape, static-arg) signatures anyway, so
    per-module clearing bounds the live executable count without measurable
    recompilation cost.
    """
    yield
    import jax

    jax.clear_caches()
