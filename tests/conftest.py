import os
import sys

# src-layout import path (tests also work without `pip install -e .`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# single real device; only launch/dryrun.py (and subprocess tests) fake a fleet.
