"""Convergence guarantee ladder (DESIGN.md §17).

The speculative engines converge in practice, but "in practice" is not a
contract: a starved iteration budget, a disabled tail, or an injected fault
(``repro.faultlab``) can leave a run unconverged or its colors corrupt.
Before this module the stack's answer was a raise — after the super-steps
already did their work.  The ladder replaces that with *bounded escalation*:

1. **reseed** — deterministically reseed the speculation by flipping the
   conflict heuristic (``degree`` ↔ ``id``): a completely different
   winner/loser trajectory through the same engine, no randomness.
2. **budget_extension** — rerun with the full ``n + 1`` iteration budget
   and the adaptive serial tail enabled; the tail makes convergence certain
   for any finite budget the first run was starved of.
3. **serialize_survivors** — keep every color the failed run got right and
   sequentially FirstFit only the *residual* (uncolored vertices plus the
   loser endpoint of every monochromatic edge) in the engine's tail order
   (degree-descending, id-ascending).  By the §14 freeze argument the
   residual covers at least one endpoint of every violation, so the sweep
   always terminates in a proper coloring of the whole graph.
4. **serial_oracle** — trust nothing: recompute the residual and hand it to
   the Algorithm-1 serial oracle order (ascending ids), falling back to a
   full ``greedy_serial`` recoloring if even the residual state is garbage.
   Unconditionally valid.

Each rung taken is recorded as a ``{"stage": "ladder", "rung": ...}`` entry
in ``ColoringResult.degradations`` and emitted as a ``guarantee_ladder``
obs span (§16), so a degraded-but-valid answer is always *observable* —
``color(g, ensure_valid=True)`` never returns an invalid coloring and never
hides what it cost to get there.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csr import CSRGraph
from repro.core.validate import is_valid_coloring
from repro.obs.spans import span

__all__ = [
    "LADDER_RUNGS",
    "residual_vertices",
    "serial_repair",
    "square_graph",
    "ensure_valid_result",
]

LADDER_RUNGS = ("reseed", "budget_extension", "serialize_survivors",
                "serial_oracle")


def residual_vertices(g: CSRGraph, colors: np.ndarray) -> np.ndarray:
    """Vertices that must recolor: uncolored ∪ per-violation loser endpoints.

    Mirrors the engine's loser rule under the ``degree`` heuristic (smaller
    degree loses, ties lose to the larger id) so the residual the ladder
    recolors matches the set the super-step itself would have kept live.
    Recoloring the residual suffices: every monochromatic edge has at least
    one endpoint in it.
    """
    n = g.n
    c = np.zeros(n, np.int64)
    colors = np.asarray(colors)
    take = min(n, colors.shape[0])
    c[:take] = colors[:take]
    bad = c <= 0
    src, dst = g.edges()
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    mono = (c[src] == c[dst]) & (c[src] > 0)
    if mono.any():
        deg = g.degrees.astype(np.int64)
        s, d = src[mono], dst[mono]
        lose_s = (deg[s] < deg[d]) | ((deg[s] == deg[d]) & (s > d))
        bad[np.where(lose_s, s, d)] = True
    return np.nonzero(bad)[0].astype(np.int64)


def serial_repair(g: CSRGraph, colors: np.ndarray, residual: np.ndarray,
                  order: str = "tail") -> np.ndarray:
    """Sequentially FirstFit ``residual`` against the frozen complement.

    ``order="tail"`` matches the engine's serial tail (degree-descending,
    id-ascending); ``order="oracle"`` is the Algorithm-1 ascending-id sweep.
    Returns a full length-``n`` color array; the complement keeps its
    colors bit-for-bit.
    """
    n = g.n
    out = np.zeros(n, np.int32)
    colors = np.asarray(colors)
    take = min(n, colors.shape[0])
    out[:take] = colors[:take]
    residual = np.asarray(residual, dtype=np.int64)
    out[residual] = 0
    if order == "tail":
        deg = g.degrees
        residual = residual[np.lexsort((residual, -deg[residual]))]
    elif order != "oracle":
        raise ValueError(f"unknown repair order {order!r}")
    R, C = g.row_offsets, g.col_indices
    # vertex-stamped colorMask (Alg. 1): O(deg(v)) per vertex, no clearing
    color_mask = np.full(g.max_degree + 2, -1, dtype=np.int64)
    for v in residual:
        neigh = C[R[v] : R[v + 1]]
        color_mask[np.clip(out[neigh], 0, color_mask.shape[0] - 1)] = v
        limit = neigh.shape[0] + 2
        free = np.nonzero(color_mask[1:limit] != v)[0]
        out[v] = free[0] + 1
    return out


def square_graph(g: CSRGraph) -> CSRGraph:
    """G² — the distance-2 conflict relation as a distance-1 CSR graph.

    Host-side and O(Σ deg²): built only on the ladder's repair path (the
    engines never materialize it), where correctness outranks cost.
    """
    return g.square()


def _merged(base, colors, iterations_extra, converged, degradations):
    return dataclasses.replace(
        base,
        colors=np.asarray(colors, dtype=np.int32),
        iterations=base.iterations + iterations_extra,
        converged=converged,
        degradations=tuple(degradations),
    )


def ensure_valid_result(g: CSRGraph, result, rerun=None):
    """Walk the §17 ladder until ``result`` validates against ``g``.

    ``g`` is the *conflict* graph — the graph itself for distance-1, its
    square for distance-2, the column-conflict graph for bipartite — so one
    ladder serves every relation.  ``rerun(rung)`` (optional) re-executes
    the failed engine run with the rung's perturbation (``"reseed"`` /
    ``"budget_extension"``) and returns a new ``ColoringResult``, or None
    when the rung does not apply; without it the ladder starts at the
    host-side repair rungs.  Always returns a result with ``converged=True``
    and valid colors; every rung taken lands in ``result.degradations``.
    """
    if result.converged and is_valid_coloring(g, result.colors):
        return result
    degr = list(result.degradations)
    best = result

    # -- rungs 1-2: engine reruns (only useful when convergence failed) ----
    if not best.converged:
        for rung in ("reseed", "budget_extension"):
            if rerun is None:
                break
            with span("guarantee_ladder", rung=rung):
                try:
                    cand = rerun(rung)
                except TypeError:
                    cand = None  # algorithm lacks the rung's knob
            if cand is None:
                degr.append({"stage": "ladder", "rung": rung,
                             "outcome": "unavailable"})
                continue
            ok = bool(cand.converged) and is_valid_coloring(g, cand.colors)
            degr.append({"stage": "ladder", "rung": rung,
                         "outcome": "resolved" if ok else "failed",
                         "iterations": int(cand.iterations)})
            if ok:
                return _merged(best, np.asarray(cand.colors),
                               int(cand.iterations), True, degr)
            if cand.converged:
                best = cand  # converged-but-invalid beats unconverged
                break

    # -- rung 3: serialize the survivors (engine tail order) ----------------
    with span("guarantee_ladder", rung="serialize_survivors"):
        residual = residual_vertices(g, best.colors)
        colors = serial_repair(g, best.colors, residual, order="tail")
        ok = is_valid_coloring(g, colors)
    degr.append({"stage": "ladder", "rung": "serialize_survivors",
                 "outcome": "resolved" if ok else "failed",
                 "residual": int(residual.size)})
    if ok:
        return _merged(best, colors, 1, True, degr)

    # -- rung 4: serial oracle (residual first, whole graph if needed) ------
    with span("guarantee_ladder", rung="serial_oracle"):
        residual = residual_vertices(g, colors)
        colors = serial_repair(g, colors, residual, order="oracle")
        if not is_valid_coloring(g, colors):
            from repro.core.serial import greedy_serial

            colors = greedy_serial(g, "natural")
            residual = np.arange(g.n, dtype=np.int64)
    degr.append({"stage": "ladder", "rung": "serial_oracle",
                 "outcome": "resolved", "residual": int(residual.size)})
    assert is_valid_coloring(g, colors), "serial oracle must produce validity"
    return _merged(best, colors, 1, True, degr)
