"""Batched multi-graph SGR engine — one device program colors B graphs.

The serving-scale generalization of ``coloring.py``'s ``fused`` mode
(DESIGN.md §4).  ``fused`` proved the whole coloring of ONE graph runs as a
single jitted ``lax.while_loop``; here the same super-step is lifted over a
leading batch axis with ``jax.vmap`` so a single dispatch colors a *batch*
of heterogeneous graphs concurrently — amortizing launch overhead across
requests the way Rokos/Bogle amortize it across subdomains.

Layout (``GraphBatch``): B CSR graphs pack into one stacked padded-adjacency
tensor ``(B, n_max, W)``.  Every graph shares the sentinel ``n_max`` (its
per-graph sentinel ``n_i`` is remapped at pack time), so the ``colors_ext``
trick from ``core/csr.py`` carries over per batch row: ``colors_ext`` is
``(B, n_max + 1)`` with slot ``n_max`` pinned to color 0, making both the
padding lanes inside a row and the all-sentinel padding *rows* of smaller
graphs inert.  Worklists are ``(B, n_max)`` with sentinel fill; a finished
graph's row compacts to all-sentinel and its lanes become no-ops.

Since §12 the batched super-step is the ROTATED one (one gather serves
conflict detection and FirstFit) and the per-graph adaptive
tail-serialization carries over: a graph whose worklist drops to its tail
threshold — or stalls — FREEZES (its lanes turn sentinel) while the others
keep speculating; when every graph is frozen or done, one vmapped serial
tail pass finishes all of them.  Each graph therefore sees exactly the
schedule the per-graph fused driver would give it, so the batched result is
bit-identical to per-graph ``mode="fused"`` runs whenever those resolve to a
single degree class (always true below the auto-tiling size gate) — tested
in ``tests/test_batch.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coloring import (
    ColoringResult,
    DenseRows,
    _packed_gather_ok,
    _stalled,
    order_tail,
    ragged_superstep,
    resolve_tail_threshold,
    serial_tail_step,
    sgr_step,
)
from repro.core.csr import CSRGraph, next_pow2
from repro.obs.spans import SpanRecorder, jit_span, span
from repro.obs.trace import (
    assemble_trace,
    empty_trace,
    resolve_trace_cap,
    ring_rows,
)

__all__ = ["GraphBatch", "SessionBatch", "batched_sgr_step",
           "batched_ragged_step", "color_batch_fused", "color_batch_sharded",
           "open_session_batch", "session_shape_class"]


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """B CSR graphs packed into one stacked padded-adjacency layout."""

    adj: jax.Array            # (B, n_max, W) int32; sentinel n_max in padding
    deg_ext: jax.Array        # (B, n_max + 1) int32; sentinel slot holds 0
    sizes: tuple[int, ...]    # per-graph vertex counts n_i
    n_max: int
    distance2: bool = False   # True when adj holds the SQUARE adjacencies

    @property
    def B(self) -> int:
        return len(self.sizes)

    @property
    def width(self) -> int:
        return int(self.adj.shape[2])

    @classmethod
    def from_graphs(
        cls,
        graphs: Sequence[CSRGraph],
        width: int | None = None,
        distance2: bool = False,
        validate_input: str | None = None,
    ) -> "GraphBatch":
        """Pack ``graphs``; ``width`` may widen (never narrow) the adjacency.

        ``distance2=True`` packs each graph's SQUARE adjacency (G², two-hop
        neighborhoods) while keeping the ORIGINAL degrees for the conflict
        loser rule — the same convention as ``repro.d2.color_distance2``'s
        precomputed strategy, so batched D2 stays bit-identical to per-graph
        fused D2 runs (DESIGN.md §11).

        ``validate_input="strict"|"repair"`` runs every member through the
        §17 ingest front door before packing (padded rows silently absorb a
        malformed CSR — an unsorted or duplicated row packs into garbage
        adjacency slots without erroring, so the batch is where validation
        pays off most).
        """
        graphs = list(graphs)
        if validate_input is not None:
            from repro.ingest import sanitize_csr

            graphs = [
                sanitize_csr(g, policy=validate_input)[0] for g in graphs
            ]
        sizes = tuple(g.n for g in graphs)
        n_max = max(sizes, default=0)
        adj_graphs = [g.square() for g in graphs] if distance2 else graphs
        need = max((g.max_degree for g in adj_graphs), default=0)
        W = max(need, width or 0, 1)
        adj = np.full((len(graphs), n_max, W), n_max, dtype=np.int32)
        deg = np.zeros((len(graphs), n_max + 1), dtype=np.int32)
        for b, (g, ag) in enumerate(zip(graphs, adj_graphs)):
            if g.n == 0:
                continue
            a = ag.padded_adjacency(W)
            adj[b, : g.n] = np.where(a == g.n, n_max, a)  # shared sentinel
            deg[b, : g.n] = g.degrees
        return cls(jnp.asarray(adj), jnp.asarray(deg), sizes, n_max, distance2)


@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "coarsen_ff", "coarsen_cr",
                     "use_kernel"),
)
def batched_sgr_step(
    adj,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    use_kernel: bool = False,
):
    """Classic ``sgr_step`` over a leading batch axis: (B, …) in, (B, …) out."""
    step = partial(
        sgr_step,
        heuristic=heuristic,
        kind=kind,
        coarsen_ff=coarsen_ff,
        coarsen_cr=coarsen_cr,
        use_kernel=use_kernel,
    )
    return jax.vmap(step)(adj, deg_ext, colors_ext, wl)


def _graph_ragged_step(adj, deg_ext, colors_ext, wl, *, heuristic, kind,
                       use_kernel, pack_degrees=False):
    """One graph's rotated super-step over its dense packed adjacency."""
    return ragged_superstep(
        DenseRows(adj).rows, deg_ext, colors_ext, wl,
        heuristic=heuristic, kind=kind, use_kernel=use_kernel,
        pack_degrees=pack_degrees,
    )


@partial(jax.jit, static_argnames=("heuristic", "kind", "use_kernel",
                                   "pack_degrees"))
def batched_ragged_step(adj, deg_ext, colors_ext, wl, *,
                        heuristic: str = "degree", kind: str = "bitset",
                        use_kernel: bool = False, pack_degrees: bool = False):
    """Rotated super-step over a leading batch axis (§12)."""
    step = partial(_graph_ragged_step, heuristic=heuristic, kind=kind,
                   use_kernel=use_kernel, pack_degrees=pack_degrees)
    return jax.vmap(step)(adj, deg_ext, colors_ext, wl)


@partial(jax.jit, static_argnames=("heuristic", "kind", "use_kernel",
                                   "tail_enabled", "pack_degrees",
                                   "trace_cap"))
def _run_batch(adj, deg_ext, sizes, thrs, max_iters, *, heuristic, kind,
               use_kernel, tail_enabled, pack_degrees=False, trace_cap=0):
    """Speculative phase: per-graph freeze on threshold/stall (§12).

    ``trace_cap`` (§16, static) threads a ``(cap, B, 3)`` ring through the
    carry recording ``[live_in, live_out, max_color]`` per graph per global
    step (``live_in = -1`` marks a frozen/finished graph); ``trace_cap=0``
    compiles the identical pre-§16 program.
    """
    B, n_max, _ = adj.shape
    ids = jnp.arange(n_max, dtype=jnp.int32)
    in_graph = ids[None, :] < sizes[:, None]
    wl0 = jnp.where(in_graph, ids[None, :], n_max)
    # bootstrap identity: every real vertex takes color 1 (see coloring.py)
    colors0 = jnp.concatenate(
        [jnp.where(in_graph, 1, 0), jnp.zeros((B, 1), jnp.int32)], axis=1
    ).astype(jnp.int32)
    counts0 = sizes.astype(jnp.int32)
    iters0 = (sizes > 0).astype(jnp.int32)
    zeros = jnp.zeros((B,), dtype=jnp.int32)
    active0 = counts0 > (thrs if tail_enabled else 0)

    def cond(state):
        return jnp.any(state[4]) & (state[7] < max_iters)

    def body(state):
        colors_ext, wl, counts, prev, active, iters_b, work_b, it = state[:8]
        wl_in = jnp.where(active[:, None], wl, n_max)
        colors_ext, wl_new, cnt_new = batched_ragged_step(
            adj, deg_ext, colors_ext, wl_in,
            heuristic=heuristic, kind=kind, use_kernel=use_kernel,
            pack_degrees=pack_degrees,
        )
        new_counts = jnp.where(active, cnt_new, counts)
        new_prev = jnp.where(active, counts, prev)
        wl = jnp.where(active[:, None], wl_new, wl)
        iters_b = iters_b + active.astype(jnp.int32)
        work_b = work_b + jnp.where(active, cnt_new, 0)
        out = (colors_ext, wl, new_counts, new_prev, active, iters_b,
               work_b, it + 1)
        if trace_cap:
            row = jnp.stack(
                [jnp.where(active, counts, -1),
                 jnp.where(active, cnt_new, -1),
                 jnp.max(colors_ext[:, :-1], axis=1)], axis=-1,
            ).astype(jnp.int32)
            idx = lax.rem(it - 1, jnp.int32(trace_cap))
            out = out + (state[8].at[idx].set(row),)
        still = active & (new_counts > 0) & (it + 1 < max_iters)
        if tail_enabled:
            still &= (new_counts > thrs) & ~_stalled(iters_b, new_counts,
                                                     new_prev)
        return out[:4] + (still,) + out[5:]

    state = (colors0, wl0, counts0, counts0, active0, iters0, zeros,
             jnp.int32(1))
    if trace_cap:
        state = state + (jnp.zeros((trace_cap, B, 3), jnp.int32),)
    return lax.while_loop(cond, body, state)


@partial(jax.jit, static_argnames=("kind",))
def _run_batch_tail(adj, deg_ext, colors_ext, wl, run_tail, stalled, sizes, *,
                    kind):
    """Vmapped serial tail: one sequential pass finishes every live graph.

    Stalled graphs discard their speculative colors and serialize ALL their
    vertices (largest-degree-first); threshold-frozen graphs serialize just
    their remaining worklists — exactly what the per-graph driver does.
    """
    B, n_max, _ = adj.shape
    ids = jnp.arange(n_max, dtype=jnp.int32)
    full_wl = jnp.where(ids[None, :] < sizes[:, None], ids[None, :], n_max)
    wl = jnp.where(stalled[:, None], full_wl, wl)
    ordered = jax.vmap(order_tail)(wl, deg_ext)
    wl_in = jnp.where(run_tail[:, None], ordered, n_max)

    def tail_one(adj_b, colors_b, wl_b):
        return serial_tail_step(DenseRows(adj_b).row1, colors_b, wl_b, kind)

    return jax.vmap(tail_one)(adj, colors_ext, wl_in)


def color_batch_fused(
    graphs: "Iterable[CSRGraph] | GraphBatch",
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    max_iters: int | None = None,
    distance2: bool = False,
    tail_serial="auto",
    backend: str | None = None,
    trace=False,
) -> list[ColoringResult]:
    """Color B graphs in ONE jitted batched ``while_loop``; one result each.

    ``trace=True`` (§16) attaches a per-graph ``RunTrace`` to every result,
    assembled from one shared on-device ring over the batched loop — each
    graph's rows cover exactly the global steps it was live in, so frozen
    capacity steps (charged to ``padded_work``) do NOT appear in its
    ``cells`` series (the trace sum is a lower bound there).

    ``backend="pallas"`` routes the vmapped rotated super-step through the
    fused Pallas kernel (§15; the kernel vmaps over the batch axis in both
    compiled and interpret mode) — colors are bit-identical to
    ``backend="jax"``.

    The speculative loop runs until every graph converges, freezes at its
    tail threshold, or stalls; frozen graphs idle as all-sentinel no-op rows
    (their reported ``iterations`` count only live super-steps).  One
    vmapped ``serial_tail_step`` then finishes all frozen worklists at once.
    ``padded_work`` charges every graph the full ``n_max × W`` gather cells
    per global step — the capacity cost of batching — while ``work_items``
    counts its genuinely live worklist entries.

    ``distance2=True`` is the batched D2 path: the packed adjacency is each
    graph's square (see ``GraphBatch.from_graphs``), everything downstream
    is unchanged, and results are bit-identical to per-graph
    ``color_distance2(mode="fused", strategy="precomputed")`` runs.
    """
    from repro.kernels.dispatch import resolve_backend

    # resolve once; recursion below passes the resolved knob (idempotent:
    # resolve_backend(None, use_kernel=True) -> "pallas").  The batch's
    # dense stacked layout has no per-graph CSR arrays, so pallas-csr
    # degrades to the gathered kernel (bit-identical, §18)
    use_kernel = resolve_backend(backend, use_kernel) in (
        "pallas", "pallas-csr")
    if isinstance(graphs, GraphBatch):
        if graphs.distance2 != distance2:
            raise ValueError(
                f"GraphBatch was packed with distance2={graphs.distance2} but "
                f"color_batch_fused was called with distance2={distance2}; "
                f"re-pack with GraphBatch.from_graphs(graphs, distance2=...)"
            )
        batch = graphs
    else:
        # Width-bucketed sub-batches (batch-level Merrill load balancing,
        # §12): one skewed graph would otherwise force its Δmax padding onto
        # every row of the stacked tensor.  Results are per-graph independent
        # (each graph sees exactly its per-graph fused schedule), so grouping
        # is a pure perf policy — colors are identical either way.  Callers
        # who pre-packed a GraphBatch keep their own layout.
        graphs = list(graphs)
        keys = [
            next_pow2(max(
                g.two_hop_degree_bound() if distance2 else g.max_degree, 1))
            for g in graphs
        ]
        if len(set(keys)) > 1:
            by_key: dict[int, list[int]] = {}
            for i, k in enumerate(keys):
                by_key.setdefault(k, []).append(i)
            results: list = [None] * len(graphs)
            for idxs in by_key.values():
                sub = color_batch_fused(
                    GraphBatch.from_graphs([graphs[i] for i in idxs],
                                           distance2=distance2),
                    heuristic=heuristic, firstfit=firstfit,
                    backend=("pallas" if use_kernel else "jax"),
                    max_iters=max_iters,
                    distance2=distance2, tail_serial=tail_serial,
                    trace=trace,
                )
                for i, r in zip(idxs, sub):
                    results[i] = r
            return results
        batch = GraphBatch.from_graphs(graphs, distance2=distance2)
    algo = "batched_fused_sgr_d2" if distance2 else "batched_fused_sgr"
    if batch.B == 0:
        return []
    if batch.n_max == 0:
        out = [ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True, algo)
               for _ in range(batch.B)]
        if trace:
            for r in out:
                r.trace = empty_trace(algo)
        return out
    max_iters = max_iters or batch.n_max + 1
    trace_cap = resolve_trace_cap(trace, max_iters)

    def run():
        sizes = jnp.asarray(np.asarray(batch.sizes, dtype=np.int32))
        tail_enabled, _ = resolve_tail_threshold(tail_serial, batch.n_max)
        thrs_np = np.asarray(
            [resolve_tail_threshold(tail_serial, n)[1] for n in batch.sizes],
            dtype=np.int32,
        )
        pack = _packed_gather_ok(batch.width)
        loop_key = ("batch", batch.B, batch.n_max, batch.width, heuristic,
                    firstfit, use_kernel, tail_enabled, pack, max_iters,
                    trace_cap)
        with span("superstep_loop", mode="batched", B=batch.B), \
                jit_span("batched_loop", loop_key):
            state = _run_batch(
                batch.adj, batch.deg_ext, sizes, jnp.asarray(thrs_np),
                jnp.int32(max_iters),
                heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
                tail_enabled=tail_enabled,
                # degrees <= packed width, colors <= width + 1 (coloring.py)
                pack_degrees=pack, trace_cap=trace_cap,
            )
        colors_ext, wl, counts, prev, _, iters_b, work_b, it = state[:8]
        counts = np.asarray(counts)
        prev = np.asarray(prev)
        iters_b = np.asarray(iters_b).copy()
        work_b = np.asarray(work_b).copy()
        steps = int(it) - 1
        ordered = ring_rows(np.asarray(state[8]), steps) if trace_cap else None
        sizes_np = np.asarray(batch.sizes, dtype=np.int32)
        run_tail = tail_enabled & (counts > 0) & (iters_b < max_iters)
        stalled = (run_tail & (counts > thrs_np)
                   & _stalled(iters_b, counts, prev))
        counts_pre = counts.copy()
        if run_tail.any():
            with span("serial_tail", live=int(counts[run_tail].sum())):
                colors_ext = _run_batch_tail(
                    batch.adj, batch.deg_ext, colors_ext, wl,
                    jnp.asarray(run_tail), jnp.asarray(stalled),
                    jnp.asarray(sizes_np), kind=firstfit,
                )
            iters_b += run_tail
            work_b += np.where(stalled, sizes_np,
                               np.where(run_tail, counts, 0))
            counts = np.where(run_tail, 0, counts)
        colors = np.asarray(colors_ext[:, : batch.n_max])
        cells = batch.n_max * batch.width
        out = []
        for b, n in enumerate(batch.sizes):
            # the bootstrap step processes all n vertices; work_b accumulates
            # the live counts of every later step (mirrors the fused driver)
            res = ColoringResult(
                colors[b, :n].copy(),
                int(iters_b[b]),
                int(work_b[b]) + n if n else 0,
                steps * cells + (cells if run_tail[b] else 0),
                converged=int(counts[b]) == 0,
                algorithm=algo,
            )
            if trace_cap:
                # per-graph rows from the shared (cap, B, 3) ring; a graph's
                # live steps are a PREFIX of the global steps, so its kept
                # rows stay contiguous — drop the boot row whenever the ring
                # overwrote any of its early live steps
                spec = [(int(r[b, 0]), int(r[b, 1]), int(r[b, 2]))
                        for r in ordered if int(r[b, 0]) >= 0]
                k_b = int(iters_b[b]) - (1 if n else 0) - int(run_tail[b])
                rows_b = ([(n, 0, n, 1, 0, 0, 0, 0)]
                          if n and len(spec) == k_b else [])
                rows_b += [(li, li - lo, lo, mc, cells, 0, 0, 0)
                           for li, lo, mc in spec]
                if run_tail[b]:
                    rows_b.append((int(counts_pre[b]), int(counts_pre[b]), 0,
                                   int(colors[b, :n].max(initial=0)),
                                   cells, 1, 0, 0))
                res.trace = assemble_trace(rows_b, int(iters_b[b]),
                                           trace_cap, algo)
            out.append(res)
        return out

    if not trace:
        return run()
    with SpanRecorder() as rec:
        out = run()
    for r in out:
        if r.trace is not None:
            r.trace.spans = rec.events
    return out


def session_shape_class(session) -> tuple:
    """The pow2 shape class a session's recolor dispatch buckets under.

    ``(pow2 n, pow2 max_degree)`` — the two quantities that dominate a
    frontier recolor's jit cache key (§14: padded DeviceCSR width and
    worklist/class shapes both derive from them).  Two sessions in the
    same class fed similar-size frontiers present REPEATING keys to the
    jitted engine, so the serving layer's micro-batcher (§19) keys its
    buckets on ``(session_shape_class(s), ColorOptions)``: the first
    request of a bucket compiles, the rest of the bucket reuses.
    """
    g = session.delta.graph()
    return (next_pow2(max(session.n, 1)),
            next_pow2(max(g.max_degree, 1)))


class SessionBatch:
    """Per-graph ``ColoringSession``s for B-graph churn (§14 serving path).

    The streaming analogue of ``color_batch_fused``: B user graphs are held
    open as persistent sessions, mutations arrive per graph
    (``apply_delta(b, ...)``), and one ``recolor()`` sweep repairs exactly
    the sessions whose graphs are dirty — clean graphs return their
    committed coloring as a zero-work no-op, so a sweep's total work is
    proportional to the *churned* frontier across the batch, not to
    ``Σ n_i``.  Sessions are independent (their frontiers never interact),
    so per-graph recoloring is exact, and each graph's colors match what a
    standalone ``ColoringSession`` fed the same deltas would hold.

    Dispatch is BUCKETED (§19): a ``recolor()`` sweep orders the dirty
    sessions by pow2 shape class (``session_shape_class``) so same-class
    sessions run consecutively and share the jitted engine's cache
    entries — per-graph results still come back in graph order, and the
    order sessions run in cannot change any colors (independence above).

    Accepts the unified ``ColorOptions`` (``options=``) or the equivalent
    loose session kwargs, like ``open_session`` (§19).
    """

    def __init__(self, graphs: "Iterable[CSRGraph]", *, options=None,
                 **opts):
        from repro.dynamic import ColoringSession  # lazy: dynamic -> core

        if options is not None or opts:
            from repro.options import ColorOptions

            opts = ColorOptions.normalize(options, **opts).session_kwargs()
        self.sessions = [ColoringSession(g, **opts) for g in graphs]

    @property
    def B(self) -> int:
        return len(self.sessions)

    def apply_delta(self, b: int, **delta) -> np.ndarray:
        """Mutate graph ``b``; returns the ids it dirtied (see ColoringSession)."""
        return self.sessions[b].apply_delta(**delta)

    def dirty(self) -> list[int]:
        """Indices of graphs with a pending (non-empty) frontier."""
        return [b for b, s in enumerate(self.sessions) if s.frontier().size]

    def buckets(self) -> "dict[tuple, list[int]]":
        """Dirty graph indices grouped by pow2 shape class (dispatch order)."""
        out: dict[tuple, list[int]] = {}
        for b, s in enumerate(self.sessions):
            if s.pending_dirty:
                out.setdefault(session_shape_class(s), []).append(b)
        return out

    def recolor(self, *, full: bool = False) -> list[ColoringResult]:
        """Repair every dirty session; one (possibly no-op) result per graph.

        Dirty sessions run bucket-by-bucket (see class doc); clean ones
        no-op afterwards.  Results come back in graph order regardless.
        """
        results: list = [None] * self.B
        for _, idxs in sorted(self.buckets().items()):
            for b in idxs:
                results[b] = self.sessions[b].recolor(full=full)
        for b, s in enumerate(self.sessions):
            if results[b] is None:
                results[b] = s.recolor(full=full)
        return results

    def results(self) -> list[ColoringResult]:
        return [s.result for s in self.sessions]

    def validate(self) -> bool:
        return all(s.validate() for s in self.sessions)

    def metrics(self) -> dict:
        """Aggregated engine-cache accounting, per shape-class bucket."""
        per_bucket: dict = {}
        hits = misses = 0
        for s in self.sessions:
            m = s.metrics()
            key = repr(session_shape_class(s))
            agg = per_bucket.setdefault(
                key, {"sessions": 0, "engine_cache_hits": 0,
                      "engine_cache_misses": 0})
            agg["sessions"] += 1
            agg["engine_cache_hits"] += m["engine_cache_hits"]
            agg["engine_cache_misses"] += m["engine_cache_misses"]
            hits += m["engine_cache_hits"]
            misses += m["engine_cache_misses"]
        return {"engine_cache_hits": hits, "engine_cache_misses": misses,
                "buckets": per_bucket}


def open_session_batch(graphs: "Iterable[CSRGraph]", *, options=None,
                       **opts) -> SessionBatch:
    """Open per-graph streaming sessions over ``graphs`` (§14 churn serving)."""
    return SessionBatch(graphs, options=options, **opts)


_EMPTY = CSRGraph(np.zeros(1, np.int64), np.zeros(0, np.int32))


def color_batch_sharded(
    graphs: "Iterable[CSRGraph]",
    *,
    devices=None,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    max_iters: int | None = None,
    distance2: bool = False,
    tail_serial="auto",
    backend: str | None = None,
    trace=False,
) -> list[ColoringResult]:
    """Place a multi-graph batch across devices (§13 batch placement).

    Two regimes, both bit-identical to the single-device batched engine
    (which is itself bit-identical to per-graph ``mode="fused"`` runs):

    * ``B >= ndev`` — **shard-per-graph**: the usual width-bucketed
      sub-batches, with each sub-batch's stacked tensors sharded on the
      BATCH axis (padded to a device multiple with empty no-op graphs).
      Graphs are independent, so the partitioned program needs no
      cross-device communication at all — placement is a pure perf policy.
    * ``B < ndev`` — **partition-within-graph**: too few graphs to fill the
      mesh, so each one runs the single-graph sharded engine (§13 halo
      exchange) over all devices in turn.
    """
    devices = list(devices) if devices is not None else jax.devices()
    ndev = len(devices)
    graphs = list(graphs)
    B = len(graphs)
    opts = dict(heuristic=heuristic, firstfit=firstfit,
                max_iters=max_iters, tail_serial=tail_serial, trace=trace)
    if ndev <= 1 or B == 0:
        return color_batch_fused(graphs, distance2=distance2,
                                 use_kernel=use_kernel, backend=backend,
                                 **opts)
    if use_kernel:
        raise ValueError("sharded batch placement does not support "
                         "use_kernel=True")
    from repro.kernels.dispatch import resolve_backend

    # §15 fallback: multi-device placement runs pure-JAX regardless of a
    # pallas request (bit-identical colors); validate the name regardless
    resolve_backend(backend)
    if B < ndev:
        if distance2:
            from repro.d2.coloring import color_distance2

            return [color_distance2(g, engine="sharded", devices=devices,
                                    backend=backend, **opts) for g in graphs]
        from repro.core.coloring import color_data_driven

        return [color_data_driven(g, engine="sharded", devices=devices,
                                  backend=backend, **opts) for g in graphs]

    mesh = Mesh(np.asarray(devices), ("b",))
    sh3 = NamedSharding(mesh, P("b", None, None))
    sh2 = NamedSharding(mesh, P("b", None))
    keys = [
        next_pow2(max(
            g.two_hop_degree_bound() if distance2 else g.max_degree, 1))
        for g in graphs
    ]
    by_key: dict[int, list[int]] = {}
    for i, k in enumerate(keys):
        by_key.setdefault(k, []).append(i)
    results: list = [None] * B
    for idxs in by_key.values():
        sub = [graphs[i] for i in idxs]
        sub += [_EMPTY] * ((-len(sub)) % ndev)  # no-op rows to a device multiple
        batch = GraphBatch.from_graphs(sub, distance2=distance2)
        batch = dataclasses.replace(
            batch,
            adj=jax.device_put(batch.adj, sh3),
            deg_ext=jax.device_put(batch.deg_ext, sh2),
        )
        res = color_batch_fused(batch, distance2=distance2,
                                use_kernel=use_kernel, **opts)
        for i, r in zip(idxs, res):
            results[i] = r
    return results
