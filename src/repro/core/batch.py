"""Batched multi-graph SGR engine — one device program colors B graphs.

The serving-scale generalization of ``coloring.py``'s ``fused`` mode
(DESIGN.md §4).  ``fused`` proved the whole coloring of ONE graph runs as a
single jitted ``lax.while_loop``; here the same super-step is lifted over a
leading batch axis with ``jax.vmap`` so a single dispatch colors a *batch*
of heterogeneous graphs concurrently — amortizing launch overhead across
requests the way Rokos/Bogle amortize it across subdomains.

Layout (``GraphBatch``): B CSR graphs pack into one stacked padded-adjacency
tensor ``(B, n_max, W)``.  Every graph shares the sentinel ``n_max`` (its
per-graph sentinel ``n_i`` is remapped at pack time), so the ``colors_ext``
trick from ``core/csr.py`` carries over per batch row: ``colors_ext`` is
``(B, n_max + 1)`` with slot ``n_max`` pinned to color 0, making both the
padding lanes inside a row and the all-sentinel padding *rows* of smaller
graphs inert.  Worklists are ``(B, n_max)`` with sentinel fill; a finished
graph's row compacts to all-sentinel and its lanes become no-ops.

Determinism: with ``coarsen_ff == coarsen_cr == 1`` (the batched default)
each graph's color evolution depends only on its own rows, so the batched
result is bit-identical to running ``mode="fused"`` per graph — tested in
``tests/test_batch.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.coloring import ColoringResult, sgr_step
from repro.core.csr import CSRGraph

__all__ = ["GraphBatch", "batched_sgr_step", "color_batch_fused"]


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """B CSR graphs packed into one stacked padded-adjacency layout."""

    adj: jax.Array            # (B, n_max, W) int32; sentinel n_max in padding
    deg_ext: jax.Array        # (B, n_max + 1) int32; sentinel slot holds 0
    sizes: tuple[int, ...]    # per-graph vertex counts n_i
    n_max: int
    distance2: bool = False   # True when adj holds the SQUARE adjacencies

    @property
    def B(self) -> int:
        return len(self.sizes)

    @property
    def width(self) -> int:
        return int(self.adj.shape[2])

    @classmethod
    def from_graphs(
        cls,
        graphs: Sequence[CSRGraph],
        width: int | None = None,
        distance2: bool = False,
    ) -> "GraphBatch":
        """Pack ``graphs``; ``width`` may widen (never narrow) the adjacency.

        ``distance2=True`` packs each graph's SQUARE adjacency (G², two-hop
        neighborhoods) while keeping the ORIGINAL degrees for the conflict
        loser rule — the same convention as ``repro.d2.color_distance2``'s
        precomputed strategy, so batched D2 stays bit-identical to per-graph
        fused D2 runs (DESIGN.md §11).
        """
        graphs = list(graphs)
        sizes = tuple(g.n for g in graphs)
        n_max = max(sizes, default=0)
        adj_graphs = [g.square() for g in graphs] if distance2 else graphs
        need = max((g.max_degree for g in adj_graphs), default=0)
        W = max(need, width or 0, 1)
        adj = np.full((len(graphs), n_max, W), n_max, dtype=np.int32)
        deg = np.zeros((len(graphs), n_max + 1), dtype=np.int32)
        for b, (g, ag) in enumerate(zip(graphs, adj_graphs)):
            if g.n == 0:
                continue
            a = ag.padded_adjacency(W)
            adj[b, : g.n] = np.where(a == g.n, n_max, a)  # shared sentinel
            deg[b, : g.n] = g.degrees
        return cls(jnp.asarray(adj), jnp.asarray(deg), sizes, n_max, distance2)


@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "coarsen_ff", "coarsen_cr",
                     "use_kernel"),
)
def batched_sgr_step(
    adj,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    use_kernel: bool = False,
):
    """``sgr_step`` over a leading batch axis: (B, …) in, (B, …) out."""
    step = partial(
        sgr_step,
        heuristic=heuristic,
        kind=kind,
        coarsen_ff=coarsen_ff,
        coarsen_cr=coarsen_cr,
        use_kernel=use_kernel,
    )
    return jax.vmap(step)(adj, deg_ext, colors_ext, wl)


@partial(jax.jit, static_argnames=("heuristic", "kind", "use_kernel"))
def _run_batch(adj, deg_ext, sizes, max_iters, *, heuristic, kind, use_kernel):
    B, n_max, _ = adj.shape
    ids = jnp.arange(n_max, dtype=jnp.int32)
    wl0 = jnp.where(ids[None, :] < sizes[:, None], ids[None, :], n_max)
    colors0 = jnp.zeros((B, n_max + 1), dtype=jnp.int32)
    zeros = jnp.zeros((B,), dtype=jnp.int32)

    def cond(state):
        _, _, counts, it, _, _ = state
        return jnp.any(counts > 0) & (it < max_iters)

    def body(state):
        colors_ext, wl, counts, it, iters_b, work_b = state
        live = counts > 0
        colors_ext, wl, counts = batched_sgr_step(
            adj, deg_ext, colors_ext, wl,
            heuristic=heuristic, kind=kind, use_kernel=use_kernel,
        )
        return (colors_ext, wl, counts, it + 1,
                iters_b + live.astype(jnp.int32), work_b + counts)

    state = (colors0, wl0, sizes.astype(jnp.int32), jnp.int32(0), zeros, zeros)
    return lax.while_loop(cond, body, state)


def color_batch_fused(
    graphs: "Iterable[CSRGraph] | GraphBatch",
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    max_iters: int | None = None,
    distance2: bool = False,
) -> list[ColoringResult]:
    """Color B graphs in ONE jitted batched ``while_loop``; one result each.

    The loop runs until the slowest graph converges; finished graphs idle as
    all-sentinel no-op rows (their reported ``iterations`` counts only live
    super-steps).  ``padded_work`` charges every graph the full ``n_max``
    lanes per global step — the capacity cost of batching — while
    ``work_items`` counts its genuinely live worklist entries.

    ``distance2=True`` is the batched D2 path: the packed adjacency is each
    graph's square (see ``GraphBatch.from_graphs``), everything downstream
    is unchanged, and results are bit-identical to per-graph
    ``color_distance2(mode="fused", strategy="precomputed")`` runs.
    """
    if isinstance(graphs, GraphBatch):
        if graphs.distance2 != distance2:
            raise ValueError(
                f"GraphBatch was packed with distance2={graphs.distance2} but "
                f"color_batch_fused was called with distance2={distance2}; "
                f"re-pack with GraphBatch.from_graphs(graphs, distance2=...)"
            )
        batch = graphs
    else:
        batch = GraphBatch.from_graphs(graphs, distance2=distance2)
    algo = "batched_fused_sgr_d2" if distance2 else "batched_fused_sgr"
    if batch.B == 0:
        return []
    if batch.n_max == 0:
        return [ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True, algo)
                for _ in range(batch.B)]
    max_iters = max_iters or batch.n_max + 1
    sizes = jnp.asarray(np.asarray(batch.sizes, dtype=np.int32))
    colors_ext, _, counts, it, iters_b, work_b = _run_batch(
        batch.adj, batch.deg_ext, sizes, jnp.int32(max_iters),
        heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
    )
    colors = np.asarray(colors_ext[:, : batch.n_max])
    counts = np.asarray(counts)
    iters_b = np.asarray(iters_b)
    work_b = np.asarray(work_b)
    steps = int(it)
    out = []
    for b, n in enumerate(batch.sizes):
        # first super-step processes all n vertices; work_b accumulates the
        # live counts of every later step (mirrors _run_fused's accounting)
        out.append(ColoringResult(
            colors[b, :n].copy(),
            int(iters_b[b]),
            int(work_b[b]) + n if n else 0,
            steps * batch.n_max,
            converged=int(counts[b]) == 0,
            algorithm=algo,
        ))
    return out
