"""Sharded ragged coloring engine — the §12 super-step at pod scale (§13).

Multi-device coloring as a first-class engine on the rotated fused
super-step.  A ``PartitionedCSR`` plan (``core/csr.py``) assigns each device
a degree-balanced contiguous vertex range and splits it into *interior*
vertices (whose colors are never read off-device) and *boundary* vertices
(the halo send list), computed once at partition time.  Each super-step is
then one ``shard_map`` program over a 1-D mesh:

* **halo exchange** — every device contributes the colors of its
  (boundary ∩ previous-worklist) vertices: after the materialized bootstrap,
  a color can only change when its vertex is on the worklist, so that set
  covers every remote read that could have gone stale.  One ``all_gather``
  of ``(id, color)`` pairs replaces the pre-§13 engine's TWO full-array
  all-gathers, interior vertices never communicate, and the payload shrinks
  with the worklist.
* **rotated fused super-step per shard** — the unchanged
  ``ragged_superstep`` (one adjacency + one neighbor-color gather serving
  both ConflictResolve and FirstFit, packed color|deg<<16 single-gather
  mode) with degree-tiled dispatch over global log-spaced classes.  Every
  shard speculates against the same exchanged snapshot and writes are
  disjoint, so a sharded step is bit-identical to the single-device tiled
  step by the §12 tiled ≡ untiled argument — sharded colors equal ragged
  colors exactly, on every graph.
* **coordinated adaptive tail** — live counts reduce globally on the host
  loop; when the total hits the tail threshold (or the worklist stalls) the
  survivors are gathered to one device and finished with the same ordered
  serial FirstFit pass the single-device engine uses (LDF; stall tails
  discard the failed speculation and re-greedy the whole graph), then the
  result is scattered back by range assembly.

Work accounting mirrors the fused driver (post-step live totals + the
materialized bootstrap; ``padded_work`` = dispatched lanes × tile width),
so with one device the engine reproduces ``color_data_driven(mode="fused")``
bit-for-bit *including* the accounting — the regression anchor in
``tests/test_sharded.py``.  ``ColoringResult.halo_bytes_per_step`` reports
the received halo bytes per device per super-step averaged over the run
(ids + colors, padded lanes included), the number to compare against the
pre-§13 engine's ``2 × 4 × n`` per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coloring import (
    ColoringResult,
    _graph_device_cache,
    _packed_gather_ok,
    _resolve_classes,
    _stalled,
    compact,
    order_tail,
    provider_tail,
    ragged_superstep,
    resolve_tail_threshold,
)
from repro.core.csr import CSRGraph, DeviceCSR, PartitionedCSR, next_pow2
from repro.core.heuristics import HEURISTICS
from repro.obs.spans import SpanRecorder, jit_span, span
from repro.obs.trace import assemble_trace, empty_trace, resolve_trace_cap

__all__ = ["ShardRows", "color_distributed", "run_sharded_engine"]


def _shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):  # jax >= 0.4.35 top-level export
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


class ShardRows:
    """Per-shard CSR row provider over GLOBAL vertex ids (§13).

    The ``DeviceCSR`` two-level gather rebased to a contiguous range: the
    shard holds its own rows' R/C slices (column ids stay global, so
    gathered tiles index the globally-addressed color view) and maps a
    global worklist id to its local row as ``id - start``.  Ids outside the
    shard — only the sentinel ``n`` in practice — read all-sentinel rows.
    """

    def __init__(self, row_starts, col_padded, deg_loc, start, n: int,
                 n_loc: int, max_width: int):
        self.row_starts = row_starts    # (L+1,) int32 local offsets
        self.col_padded = col_padded    # (Mcap,) int32 GLOBAL column ids
        self.deg_loc = deg_loc          # (L+1,) int32 local degrees
        self.start = start              # scalar: first owned global id
        self.n = int(n)
        self.n_loc = int(n_loc)
        self.max_width = int(max_width)

    def rows(self, ids, width: int | None = None):
        width = self.max_width if width is None else int(width)
        lidx = ids - self.start
        safe = jnp.clip(lidx, 0, self.n_loc - 1)
        starts = self.row_starts[safe]
        deg = self.deg_loc[safe]
        lane = jnp.arange(width, dtype=starts.dtype)[None, :]
        rows = self.col_padded[starts[:, None] + lane]
        valid = (lane < deg[:, None]) & (ids < self.n)[:, None]
        return jnp.where(valid, rows, self.n)


jax.tree_util.register_pytree_node(
    ShardRows,
    lambda s: ((s.row_starts, s.col_padded, s.deg_loc, s.start),
               (s.n, s.n_loc, s.max_width)),
    lambda aux, ch: ShardRows(*ch, *aux),
)


# --------------------------------------------------------------------------
# the sharded super-step (one shard_map program per iteration)
# --------------------------------------------------------------------------

_STEP_CACHE: dict = {}


def _build_step(mesh, *, provider_kind: str, n: int, n_loc: int,
                tile_widths: tuple, heuristic: str, kind: str,
                pack_degrees: bool, pack_halo: bool,
                include_first_hop: bool = True, max_width: int = 1):
    """One jitted shard_map super-step: halo exchange + rotated step + swl.

    ``provider_kind`` selects how the per-shard row provider is assembled
    from the stacked plan arrays: ``"csr"`` (ShardRows over the shard's R/C
    slice) or ``"twohop"`` (a ``TwoHopRows`` whose first hop is the shard's
    dense row slice and whose second hop is replicated — repro.d2).
    ``pack_halo`` ships each halo entry as ONE ``id << 16 | color`` word
    instead of an (id, color) pair — legal whenever both provably fit
    (``n < 2**15``; colors are bounded by n), halving the exchange bytes
    the same way ``pack_degrees`` halves the neighbor gathers (§12).
    """
    if pack_halo:
        # §17 capacity guard: ids >= 2^15 flip the int32 sign bit inside
        # id << 16 and unpack as garbage neighbors — refuse, never corrupt
        from repro.errors import CapacityError
        from repro.ingest import PACKED_HALO_MAX_N, packed_halo_ok

        if not packed_halo_ok(n):
            raise CapacityError(
                f"pack_halo=True with n={n}: vertex ids must stay < "
                f"{PACKED_HALO_MAX_N} to fit the id << 16 | color halo "
                "word (int32); rerun with pack_halo=False")
    K = len(tile_widths)

    def step(prov, start, bmask, deg_ext, view, swl, *wls):
        start_s = start[0]
        bmask_l = bmask[0]
        view_l = view[0]
        swl_l = swl[0]
        wls_l = [w[0] for w in wls]

        # ---- halo exchange: live boundary (id, color) entries -------------
        send_colors = view_l[swl_l]  # sentinel n reads slot n: color 0
        if pack_halo:
            word = lax.all_gather((swl_l << 16) | send_colors, "d", tiled=True)
            all_ids = word >> 16
            all_colors = word & jnp.int32(0xFFFF)
        else:
            all_ids = lax.all_gather(swl_l, "d", tiled=True)
            all_colors = lax.all_gather(send_colors, "d", tiled=True)
        # sentinel lanes write color 0 at slot n — the pinned value, inert
        view_l = view_l.at[all_ids].set(all_colors, mode="drop")
        snapshot = view_l

        if provider_kind == "csr":
            row_starts, col_padded, deg_loc = (a[0] for a in prov)
            provider = ShardRows(row_starts, col_padded, deg_loc, start_s,
                                 n, n_loc, max_width)
        else:
            from repro.d2.coloring import TwoHopRows

            adj_a, adj_b = prov
            provider = TwoHopRows(adj_a[0], adj_b, include_first_hop,
                                  start=start_s, n_colored=n)

        # ---- rotated fused super-step, degree-tiled: every class (and
        # every shard) speculates against the same exchanged snapshot, so
        # the sharded step ≡ the single-device tiled step (§12) -------------
        new_wls, counts = [], []
        for k in range(K):
            view_l, wl_k, cnt_k = ragged_superstep(
                (lambda ids, w=tile_widths[k]: provider.rows(ids, w)),
                deg_ext, view_l, wls_l[k],
                heuristic=heuristic, kind=kind,
                colors_read=snapshot, pack_degrees=pack_degrees,
            )
            new_wls.append(wl_k)
            counts.append(cnt_k)

        # ---- next halo send list: still-live boundary vertices ------------
        live = jnp.concatenate(new_wls) if K > 1 else new_wls[0]
        lidx = live - start_s
        isb = (live < n) & bmask_l[jnp.clip(lidx, 0, n_loc - 1)]
        new_swl, scount = compact(live, isb, sentinel=n)

        out = (view_l[None], new_swl[None], jnp.stack(counts)[None],
               scount[None])
        return out + tuple(w[None] for w in new_wls)

    if provider_kind == "csr":
        prov_specs = (P("d", None), P("d", None), P("d", None))
    else:
        prov_specs = (P("d", None, None), P())
    in_specs = (prov_specs, P("d"), P("d", None), P(), P("d", None),
                P("d", None)) + tuple(P("d", None) for _ in range(K))
    out_specs = (P("d", None), P("d", None), P("d", None), P("d")) + tuple(
        P("d", None) for _ in range(K))
    return jax.jit(_shard_map(step, mesh, in_specs=in_specs,
                              out_specs=out_specs))


def _get_step(mesh, devices, **cfg):
    key = (tuple(id(d) for d in devices),
           tuple(sorted(cfg.items(), key=lambda kv: kv[0])))
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = _build_step(mesh, **cfg)
    return _STEP_CACHE[key]


# --------------------------------------------------------------------------
# host driver: the fused schedule with a shard_map body + coordinated tail
# --------------------------------------------------------------------------

def run_sharded_engine(
    *,
    plan: PartitionedCSR,
    devices,
    provider_kind: str,
    prov_np: tuple,
    deg_ext_np: np.ndarray,
    classes: list,
    tile_widths: list,
    acc_widths: list,
    tail_width: int,
    tail_provider,
    heuristic: str = "degree",
    kind: str = "bitset",
    tail_enabled: bool = True,
    tail_threshold: int = 0,
    max_iters: int,
    algorithm: str,
    pack_degrees: bool = False,
    include_first_hop: bool = True,
    trace=False,
) -> ColoringResult:
    """Drive the sharded super-step to convergence (§13).

    ``classes`` are the GLOBAL degree-class id arrays (wide-first, as in
    ``run_ragged_engine``); they are split per device along the plan's
    ranges, so the union worklist — and therefore every color, live count,
    and tail decision — matches the single-device engine exactly.
    ``prov_np`` holds the stacked per-shard provider arrays
    (``plan.stack_shards`` output for ``"csr"``, ``(stacked first hop,
    replicated second hop)`` for ``"twohop"``).

    With ``trace`` (§16) each super-step records a telemetry row including
    the two sharded-only columns: ``halo_bytes`` (entries received per
    device this step × entry bytes × ndev) and ``imbalance`` (max − min
    per-shard live count).  ``max_color`` is read off the sharded view and
    may transiently include a stale remote entry mid-run; the committed
    final row is exact.  The host loop records on the host, so the
    shard_map programs are untouched either way.
    """
    if heuristic not in HEURISTICS:
        raise ValueError(
            f"unknown heuristic {heuristic!r}; options: {HEURISTICS}")
    n, ndev, L = plan.n, plan.ndev, plan.n_loc
    K = len(classes)
    mesh = Mesh(np.asarray(devices), ("d",))
    sh_vec = NamedSharding(mesh, P("d"))
    sh_row = NamedSharding(mesh, P("d", None))
    rep = NamedSharding(mesh, P())

    # ---- split classes per device (uniform caps, sentinel padding) --------
    owner_of = plan.owners()
    wls_np, caps = [], []
    counts = np.zeros((ndev, K), np.int64)
    for k, cls in enumerate(classes):
        groups = [cls[owner_of[cls] == d] for d in range(ndev)]
        cap = max(max((g.size for g in groups), default=0), 1)
        arr = np.full((ndev, cap), n, np.int32)
        for d, g_ids in enumerate(groups):
            arr[d, : g_ids.size] = g_ids
            counts[d, k] = g_ids.size
        wls_np.append(arr)
        caps.append(cap)

    # ---- device placement -------------------------------------------------
    if provider_kind == "csr":
        prov = tuple(jax.device_put(jnp.asarray(a), sh_row) for a in prov_np)
    else:
        adj_a_np, adj_b_np = prov_np
        prov = (
            jax.device_put(jnp.asarray(adj_a_np),
                           NamedSharding(mesh, P("d", None, None))),
            jax.device_put(jnp.asarray(adj_b_np), rep),
        )
    start_dev = jax.device_put(
        jnp.asarray(plan.starts[:-1].astype(np.int32)), sh_vec)
    bmask_dev = jax.device_put(jnp.asarray(plan.boundary_masks()), sh_row)
    deg_dev = jax.device_put(jnp.asarray(deg_ext_np), rep)
    # bootstrap identity (§12): every real vertex takes color 1 — a constant
    # every device already agrees on, so the first step needs no exchange
    boot = (np.arange(n + 1, dtype=np.int32) < n).astype(np.int32)
    view = jax.device_put(jnp.asarray(np.tile(boot, (ndev, 1))), sh_row)
    wls = [jax.device_put(jnp.asarray(a), sh_row) for a in wls_np]
    swl = jax.device_put(jnp.full((ndev, 1), n, jnp.int32), sh_row)
    scounts = np.zeros(ndev, np.int64)

    cells_per_step = sum(ndev * caps[k] * acc_widths[k] for k in range(K))
    total = int(counts.sum())
    prev = total
    iters = 1  # the materialized bootstrap
    work = 0   # post-step live totals (fused accounting)
    padded = 0
    halo_bytes = 0
    stalled = False
    from repro.ingest import packed_halo_ok

    pack_halo = packed_halo_ok(n)  # id and color both provably fit 15/16 bits
    halo_entry_bytes = 4 if pack_halo else 8
    # ONE cached jitted step per config; the pow2-resliced swl width below
    # retraces it per distinct shape exactly as jit always does
    step = _get_step(
        mesh, devices, provider_kind=provider_kind, n=n, n_loc=L,
        tile_widths=tuple(tile_widths), heuristic=heuristic, kind=kind,
        pack_degrees=pack_degrees, pack_halo=pack_halo,
        include_first_hop=include_first_hop, max_width=tail_width)
    trace_cap = resolve_trace_cap(trace, max_iters)
    rows = []
    if trace_cap:
        # the materialized bootstrap: everyone takes color 1, nothing retires
        rows.append((total, 0, total, 1, 0, 0, 0, 0))
    with span("superstep_loop", mode="sharded", ndev=ndev):
        while total > 0 and iters < max_iters:
            if tail_enabled and total <= tail_threshold:
                break
            if tail_enabled and _stalled(iters, total, prev):
                stalled = True
                break
            prev = total
            cap_s = min(next_pow2(max(int(scounts.max(initial=0)), 1)),
                        int(swl.shape[1]))
            with jit_span("superstep", ("sharded_step", provider_kind, n, L,
                                        ndev, tuple(tile_widths), heuristic,
                                        kind, pack_degrees, pack_halo,
                                        cap_s)):
                out = step(prov, start_dev, bmask_dev, deg_dev, view,
                           swl[:, :cap_s], *wls)
            view, swl, counts_dev, scounts_dev = out[:4]
            wls = list(out[4:])
            counts = np.asarray(counts_dev)
            scounts = np.asarray(scounts_dev)
            # received per device: ndev × cap_s halo entries (padded lanes too)
            step_halo = halo_entry_bytes * ndev * cap_s
            halo_bytes += step_halo
            iters += 1
            new_total = int(counts.sum())
            if trace_cap:
                per_shard = counts.sum(axis=1)
                rows.append((total, total - new_total, new_total,
                             int(jnp.max(view)), cells_per_step, 0,
                             step_halo,
                             int(per_shard.max() - per_shard.min())))
            total = new_total
            work += total
            padded += cells_per_step

    converged = total == 0
    deg_ext_loc = jnp.asarray(deg_ext_np)
    tail_cells = 0
    if total > 0 and iters < max_iters and tail_enabled:
        # coordinated tail: gather survivors to one device, one ordered
        # serial FirstFit pass, scatter back by range assembly
        with span("serial_tail", live=total, stalled=stalled):
            colors_ext = jnp.asarray(_assemble(view, plan))
            if stalled:
                tail_wl = order_tail(jnp.arange(n, dtype=jnp.int32),
                                     deg_ext_loc)
            else:
                flat = np.concatenate(
                    [np.asarray(w).reshape(-1) for w in wls]).astype(np.int32)
                tail_wl = order_tail(jnp.asarray(flat), deg_ext_loc)
            colors_ext = provider_tail(tail_provider, colors_ext, tail_wl,
                                       kind=kind)
        work += n if stalled else total
        tail_cells = int(tail_wl.shape[0]) * tail_width
        padded += tail_cells
        iters += 1
        converged = True
        colors = np.asarray(colors_ext[:n])
        if trace_cap:
            rows.append((total, total, 0, int(colors.max(initial=0)),
                         tail_cells, 1, 0, 0))
    else:
        colors = _assemble(view, plan)[:n]
    result = ColoringResult(
        colors, iters, work + n, padded, converged, algorithm=algorithm,
        halo_bytes_per_step=halo_bytes / max(iters, 1),
    )
    if trace_cap:
        result.trace = assemble_trace(rows, iters, trace_cap,
                                      f"{algorithm}:sharded")
    return result


def _assemble(view, plan: PartitionedCSR) -> np.ndarray:
    """Global ``colors_ext`` from the per-device views (own ranges only)."""
    views = np.asarray(view)
    out = np.zeros(plan.n + 1, np.int32)
    for d in range(plan.ndev):
        s, e = int(plan.starts[d]), int(plan.starts[d + 1])
        out[s:e] = views[d, s:e]
    return out


# --------------------------------------------------------------------------
# distance-1 entry point (repro.api reaches this via engine="sharded")
# --------------------------------------------------------------------------

def color_distributed(
    g: CSRGraph,
    *,
    devices=None,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    buckets: tuple = (),
    tiling="auto",
    tail_serial="auto",
    max_iters: int | None = None,
    trace=False,
) -> ColoringResult:
    """Color ``g`` on every available device with the sharded engine (§13).

    Bit-identical to single-device ``color_data_driven`` (any engine/mode)
    by the snapshot argument above; per-step communication is one halo
    exchange of live boundary colors instead of two full-array all-gathers.
    Runs the full shard_map machinery even on one device (useful for
    in-process testing); the *api* layer is what falls back to ``ragged``
    there.
    """
    if heuristic not in HEURISTICS:
        raise ValueError(
            f"unknown heuristic {heuristic!r}; options: {HEURISTICS}")
    devices = list(devices) if devices is not None else jax.devices()
    ndev = len(devices)
    n = g.n
    if n == 0:
        result = ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True,
                                algorithm=f"sharded_sgr_{ndev}dev")
        if trace:
            result.trace = empty_trace(f"sharded_sgr_{ndev}dev")
        return result
    max_iters = max_iters or n + 1

    def run():
        with span("partition_plan", ndev=ndev):
            plan = _graph_device_cache(
                g, f"plan{ndev}", lambda: PartitionedCSR.from_graph(g, ndev))
            classes, widths = _resolve_classes(g.degrees, buckets, tiling)
        with span("csr_build", engine="sharded"):
            prov_np = _graph_device_cache(
                g, f"shards{ndev}", lambda: plan.stack_shards(g))
            tail_provider = _graph_device_cache(
                g, "dcsr", lambda: DeviceCSR.from_csr(g))
        dmax = max(g.max_degree, 1)
        deg_ext_np = np.concatenate(
            [g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
        tail_enabled, thr = resolve_tail_threshold(tail_serial, n)
        return run_sharded_engine(
            plan=plan, devices=devices, provider_kind="csr", prov_np=prov_np,
            deg_ext_np=deg_ext_np, classes=classes, tile_widths=widths,
            acc_widths=widths, tail_width=dmax, tail_provider=tail_provider,
            heuristic=heuristic, kind=firstfit, tail_enabled=tail_enabled,
            tail_threshold=thr, max_iters=max_iters,
            algorithm=f"sharded_sgr_{ndev}dev",
            pack_degrees=_packed_gather_ok(dmax),
            trace=trace,
        )

    if not trace:
        return run()
    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result
