"""Multi-device speculative-greedy coloring (beyond-paper: pod-scale SGR).

The paper targets one GPU.  To run coloring at pod scale we partition vertices
into contiguous per-device ranges with ``shard_map`` over a 1-D device mesh:

* every device owns its vertex range's colors, worklist and adjacency rows;
* each super-step: ``all_gather`` the color array (neighbors may live on any
  device), FirstFit the local worklist, ``all_gather`` again (conflict
  detection must see post-FirstFit colors — the cross-device analogue of the
  paper's global barrier between kernels), resolve conflicts with the degree
  heuristic, clear losers, compact locally.

Communication is 2 all-gathers of the n-vertex color array per super-step;
super-step counts match the single-device algorithm (the math is identical).
A documented optimization (EXPERIMENTS.md §Perf) replaces the all-gather with
boundary-halo exchange: only colors of vertices with cross-partition edges
(typically <<n for good partitions) need to move.

Padding vertices (to make n divisible by the device count) are isolated
(degree 0): they take color 1 in round one and never conflict.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coloring import ColoringResult
from repro.core.csr import CSRGraph
from repro.core.firstfit import firstfit_bitset
from repro.core.heuristics import conflict_lose_flags

__all__ = ["color_distributed"]


def _shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):  # jax >= 0.4.35 top-level export
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _build_step(mesh, n_pad: int, n_loc: int, heuristic: str):
    def step(adj_loc, deg_ext, colors_loc, wl_loc):
        # ---- exchange colors (pre-FirstFit view) --------------------------
        colors_full = jax.lax.all_gather(colors_loc, "d", tiled=True)
        colors_ext = jnp.concatenate([colors_full, jnp.zeros(1, jnp.int32)])

        offset = jax.lax.axis_index("d").astype(jnp.int32) * n_loc
        lidx = wl_loc - offset  # local row of each worklist vertex
        valid = wl_loc < n_pad
        # sentinel entries scatter out of range (dropped) instead of clipping
        # onto a real row, which would race the valid writes
        sidx = jnp.where(valid, lidx, n_loc)
        rows = adj_loc[jnp.clip(lidx, 0, n_loc - 1)]
        rows = jnp.where(valid[:, None], rows, n_pad)

        # ---- FirstFit (speculative, bitset) -------------------------------
        nc = colors_ext[rows]
        c = firstfit_bitset(nc)
        colors_loc = colors_loc.at[sidx].set(c, mode="drop")

        # ---- global barrier: conflict detection sees post-FF colors -------
        colors_full = jax.lax.all_gather(colors_loc, "d", tiled=True)
        colors_ext = jnp.concatenate([colors_full, jnp.zeros(1, jnp.int32)])
        my_c = colors_ext[wl_loc]
        nc = colors_ext[rows]
        my_d = deg_ext[wl_loc]
        nd = deg_ext[rows]
        lose = conflict_lose_flags(wl_loc, rows, my_c, nc, my_d, nd, heuristic)

        # ---- color clearing + local compaction ----------------------------
        colors_loc = colors_loc.at[jnp.where(lose & valid, sidx, n_loc)].set(
            0, mode="drop"
        )
        pos = jnp.cumsum(lose.astype(jnp.int32)) - 1
        new_wl = jnp.full_like(wl_loc, n_pad)
        new_wl = new_wl.at[jnp.where(lose, pos, wl_loc.shape[0])].set(
            wl_loc, mode="drop"
        )
        return colors_loc, new_wl, jnp.sum(lose.astype(jnp.int32))[None]

    return jax.jit(
        _shard_map(
            step,
            mesh,
            in_specs=(P("d", None), P(), P("d"), P("d")),
            out_specs=(P("d"), P("d"), P("d")),
        )
    )


def color_distributed(
    g: CSRGraph,
    *,
    devices=None,
    heuristic: str = "degree",
    max_iters: int | None = None,
) -> ColoringResult:
    devices = devices if devices is not None else jax.devices()
    ndev = len(devices)
    mesh = Mesh(np.asarray(devices), ("d",))
    n = g.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    n_loc = n_pad // ndev
    max_iters = max_iters or n + 1

    adj_np = g.padded_adjacency()
    # remap the sentinel n -> n_pad and pad rows for the padding vertices
    adj_np = np.where(adj_np == n, n_pad, adj_np)
    if n_pad > n:
        adj_np = np.concatenate(
            [adj_np, np.full((n_pad - n, adj_np.shape[1]), n_pad, np.int32)]
        )
    deg_ext = np.zeros(n_pad + 1, np.int32)
    deg_ext[:n] = g.degrees

    shard_rows = NamedSharding(mesh, P("d", None))
    shard_vec = NamedSharding(mesh, P("d"))
    adj = jax.device_put(jnp.asarray(adj_np), shard_rows)
    deg = jax.device_put(jnp.asarray(deg_ext), NamedSharding(mesh, P()))
    colors = jax.device_put(jnp.zeros(n_pad, jnp.int32), shard_vec)
    wl = jax.device_put(jnp.arange(n_pad, dtype=jnp.int32), shard_vec)

    step = _build_step(mesh, n_pad, n_loc, heuristic)
    count, iters = n_pad, 0
    while count > 0 and iters < max_iters:
        colors, wl, counts = step(adj, deg, colors, wl)
        count = int(jnp.sum(counts))
        iters += 1

    colors_np = np.asarray(colors)[:n]
    return ColoringResult(
        colors_np,
        iters,
        work_items=iters * n_pad,
        padded_work=iters * n_pad,
        converged=count == 0,
        algorithm=f"distributed_sgr_{ndev}dev",
    )
