"""Jones–Plassmann MIS coloring (paper Alg. 3) and the csrcolor multi-hash MIS.

These are the *quality foils*: MIS-based methods are fast (no conflicts, few
memory touches) but assign one fresh color per independent set, so they need
far more colors than greedy — the paper measures csrcolor at 3.9–31x the
serial color count (Fig. 8).  We reproduce both:

* ``color_jp``        — Alg. 3 verbatim: per-round random priorities (hashed,
                        as csrcolor does, instead of stored RNG draws), local
                        strict maxima form the independent set, one color per
                        round.
* ``color_multihash`` — the CUSPARSE csrcolor trick: N hash functions per
                        round; local maxima AND minima of each hash give 2N
                        independent sets (2N colors) per round, trading color
                        count for fewer rounds.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import register
from repro.core.coloring import ColoringResult
from repro.core.csr import CSRGraph

__all__ = ["color_jp", "color_multihash"]


def _hash32(x: jax.Array, salt: int) -> jax.Array:
    """Deterministic avalanche hash (murmur3 finalizer) on int32 ids."""
    h = x.astype(jnp.uint32) * jnp.uint32(0xCC9E2D51) + jnp.uint32(salt & 0xFFFFFFFF)
    h ^= h >> 16
    h *= jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    h *= jnp.uint32(0xC2B2AE35)
    h ^= h >> 16
    return h


def _local_extreme(adj, uncol_ext, pri_ext, ids, mode: str) -> jax.Array:
    """True where id is a strict local max/min among *uncolored* neighbors.

    Priority ties are broken by vertex id (larger id wins for max, smaller for
    min) so adjacent equal-hash vertices can never both be selected.
    """
    n = adj.shape[0]
    rows = adj  # (n, W) full topology — JP is inherently topology-driven
    np_ = pri_ext[rows]
    nu = uncol_ext[rows]
    pv = pri_ext[ids][:, None]
    iv = ids[:, None]
    if mode == "max":
        beats = (pv > np_) | ((pv == np_) & (iv > rows))
    else:
        beats = (pv < np_) | ((pv == np_) & (iv < rows))
    ok = beats | ~nu  # colored or padding neighbors do not block
    return jnp.all(ok, axis=1)


@partial(jax.jit, static_argnames=("nhash", "modes"))
def _mis_round(adj, colors_ext, base_color, round_idx, *, nhash: int, modes):
    n = adj.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    uncol = colors_ext[:n] == 0
    uncol_ext = jnp.concatenate([uncol, jnp.zeros((1,), bool)])
    new_colors = colors_ext[:n]
    assigned = ~uncol
    color = base_color
    for j in range(nhash):
        pri = _hash32(ids + round_idx * jnp.int32(7919), salt=0x9E3779B9 + 131 * j)
        pri_ext = jnp.concatenate([pri, jnp.zeros((1,), jnp.uint32)])
        for mode in modes:
            sel = _local_extreme(adj, uncol_ext, pri_ext, ids, mode)
            sel = sel & uncol & ~assigned
            new_colors = jnp.where(sel, color, new_colors)
            assigned = assigned | sel
            color = color + 1
    colors_ext = colors_ext.at[:n].set(new_colors)
    return colors_ext, jnp.sum(new_colors == 0), color


def _run_mis(g: CSRGraph, nhash: int, modes: tuple, algorithm: str) -> ColoringResult:
    n = g.n
    if n == 0:
        return ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True, algorithm)
    adj = jnp.asarray(g.padded_adjacency())
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)
    remaining, iters = n, 0
    color = jnp.int32(1)
    while remaining > 0 and iters < n + 1:
        colors_ext, rem, color = _mis_round(
            adj, colors_ext, color, jnp.int32(iters), nhash=nhash, modes=modes
        )
        remaining = int(rem)
        iters += 1
    return ColoringResult(
        np.asarray(colors_ext[:n]),
        iters,
        work_items=iters * n,
        padded_work=iters * n,
        converged=remaining == 0,
        algorithm=algorithm,
    )


@register("jp")
def color_jp(g: CSRGraph) -> ColoringResult:
    """Alg. 3 verbatim: one independent set (local maxima), one color/round."""
    return _run_mis(g, nhash=1, modes=("max",), algorithm="jp_mis")


@register("multihash")
def color_multihash(g: CSRGraph, nhash: int = 2) -> ColoringResult:
    """csrcolor analogue: 2*nhash independent sets (colors) per round."""
    return _run_mis(
        g, nhash=nhash, modes=("max", "min"), algorithm=f"multihash_mis_{nhash}"
    )
