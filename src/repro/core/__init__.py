"""Core graph-coloring engine — the paper's contribution in JAX."""
from repro.core.batch import (GraphBatch, SessionBatch, batched_ragged_step,
                              batched_sgr_step, color_batch_fused,
                              color_batch_sharded, open_session_batch)
from repro.core.coloring import ColoringResult, color_data_driven, color_fused
from repro.core.csr import (CSRGraph, DeviceCSR, DeviceGraph, PartitionedCSR,
                            auto_tile_thresholds, csr_from_edges, next_pow2)
from repro.core.distributed import color_distributed
from repro.core.jp import color_jp, color_multihash
from repro.core.serial import color_serial, greedy_serial
from repro.core.threestep import color_threestep
from repro.core.topo import color_topology
from repro.core.validate import is_valid_coloring, num_colors, quality_report

__all__ = [
    "CSRGraph",
    "DeviceCSR",
    "DeviceGraph",
    "GraphBatch",
    "PartitionedCSR",
    "auto_tile_thresholds",
    "csr_from_edges",
    "next_pow2",
    "ColoringResult",
    "color_data_driven",
    "color_distributed",
    "color_fused",
    "color_batch_fused",
    "color_batch_sharded",
    "SessionBatch",
    "open_session_batch",
    "batched_ragged_step",
    "batched_sgr_step",
    "color_topology",
    "color_jp",
    "color_multihash",
    "color_threestep",
    "color_serial",
    "greedy_serial",
    "is_valid_coloring",
    "num_colors",
    "quality_report",
]
