"""Compressed Sparse Row graph container (the paper's R / C arrays).

The paper (§3, Fig. 2) stores the graph in CSR: ``R`` (row offsets, n+1) and
``C`` (column indices, m).  We keep the same two arrays, plus TPU-friendly
derived views:

* ``padded_adjacency(width)`` — a dense ``(n, width)`` int32 view with the
  sentinel ``n`` in padding slots.  Gathers through an extended color array
  ``colors_ext`` of length ``n + 1`` (whose last slot is pinned to color 0)
  make padding lanes inert: color 0 is "uncolored" and is never forbidden and
  never conflicting.  This is the vector-lane analogue of CUDA's masked warp
  lanes.
* ``degree_buckets`` — vertex classes by degree, the data-layout analogue of
  Merrill's thread/warp/CTA load-balancing hierarchy (§3.3 of the paper).
* ``square`` / ``compose_pairs`` / ``two_hop_degree_bound`` — the host-side
  distance-2 machinery (DESIGN.md §11): G² reduces distance-2 coloring to
  distance-1 coloring, so the SGR engine applies unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "CSRGraph",
    "DeviceCSR",
    "DeviceGraph",
    "PartitionedCSR",
    "auto_tile_thresholds",
    "balanced_starts",
    "csr_from_edges",
    "compose_pairs",
    "padded_ragged",
    "next_pow2",
]


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected sparse graph in CSR form (host-side, numpy)."""

    row_offsets: np.ndarray  # (n+1,) int32/int64
    col_indices: np.ndarray  # (m,) int32

    def __post_init__(self):
        assert self.row_offsets.ndim == 1 and self.col_indices.ndim == 1
        assert self.row_offsets[0] == 0
        assert self.row_offsets[-1] == self.col_indices.shape[0]

    # -- basic stats ---------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.row_offsets.shape[0] - 1)

    @property
    def m(self) -> int:
        """Number of directed edges (2x undirected edge count)."""
        return int(self.col_indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max(initial=0))

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    @property
    def degree_std(self) -> float:
        return float(self.degrees.std())

    def neighbors(self, v: int) -> np.ndarray:
        return self.col_indices[self.row_offsets[v] : self.row_offsets[v + 1]]

    # -- dense views ---------------------------------------------------------
    def padded_adjacency(
        self, width: int | None = None, *, allow_truncate: bool = False
    ) -> np.ndarray:
        """Dense ``(n, width)`` adjacency; padding slots hold the sentinel ``n``.

        ``width < max_degree`` would silently drop neighbors and corrupt any
        coloring built on the view, so it raises unless the caller opts in
        with ``allow_truncate=True`` (degree-bucket callers size the width
        from the bucket bound, so legitimate paths never truncate).
        """
        n = self.n
        width = max(self.max_degree, 1) if width is None else int(width)
        if width < self.max_degree and not allow_truncate:
            raise ValueError(
                f"width={width} < max_degree={self.max_degree} would silently "
                f"drop neighbors; pass allow_truncate=True if that is intended"
            )
        return padded_ragged(self.row_offsets, self.col_indices, width, n)

    # -- distance-2 views (DESIGN.md §11) ------------------------------------
    def two_hop_degree_bound(self) -> int:
        """Cheap upper bound on the square graph's max degree (no dedup).

        ``max_v [deg(v) + Σ_{u∈N(v)} deg(u)]`` — computable in O(m) without
        materializing two-hop pairs, so drivers can decide precomputed vs
        on-the-fly strategy *before* paying the O(Σ deg²) build cost.
        """
        if self.m == 0:
            return 0
        deg = self.degrees.astype(np.int64)
        rows = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        nbr_deg_sum = np.bincount(
            rows, weights=deg[self.col_indices], minlength=self.n
        ).astype(np.int64)
        return int((deg + nbr_deg_sum).max())

    def square(self) -> "CSRGraph":
        """The square graph G²: u ~ v iff 0 < dist(u, v) <= 2.

        Distance-2 coloring of G is distance-1 coloring of G², so the whole
        SGR engine (super-step, batching, kernels) applies unchanged.  Costs
        O(Σ_u deg(u)²) host time/memory; callers on huge/skewed graphs should
        consult ``two_hop_degree_bound`` first and fall back to on-the-fly
        two-hop composition (``repro.d2``) when this would blow the budget.
        """
        src1, dst1 = self.edges()
        src2, dst2 = compose_pairs(
            self.row_offsets, self.col_indices, self.row_offsets, self.col_indices
        )
        return csr_from_edges(
            self.n,
            np.concatenate([src1, src2]),
            np.concatenate([dst1, dst2]),
            symmetrize=False,  # dist<=2 is already a symmetric relation
            dedup=True,
        )

    def degree_buckets(self, thresholds: Sequence[int]) -> list[np.ndarray]:
        """Vertex-id arrays per degree class: (0, t0], (t0, t1], ..., (tk-1, inf)."""
        deg = self.degrees
        out, lo = [], 0
        bounds = list(thresholds) + [max(self.max_degree, 1)]
        for hi in bounds:
            ids = np.where((deg > lo) & (deg <= hi))[0].astype(np.int32)
            out.append(ids)
            lo = hi
        # degree-0 vertices go to the first bucket (they take color 1 trivially)
        zero = np.where(deg == 0)[0].astype(np.int32)
        if zero.size:
            out[0] = np.concatenate([zero, out[0]])
        return out

    # -- edge list view (for validation / COO ops) ---------------------------
    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.col_indices.astype(np.int32)


def csr_from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    *,
    symmetrize: bool = True,
    dedup: bool = True,
) -> CSRGraph:
    """Build a clean CSR graph from an edge list.

    Drops self loops; optionally symmetrizes (undirected) and deduplicates.
    Adjacency lists come out sorted, matching the UF-collection convention.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if dedup and src.size:
        key = src * n + dst
        key = np.unique(key)
        src, dst = key // n, key % n
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    row_offsets = np.zeros(n + 1, dtype=np.int64)
    np.add.at(row_offsets, src + 1, 1)
    row_offsets = np.cumsum(row_offsets)
    return CSRGraph(row_offsets.astype(np.int64), dst.astype(np.int32))


def padded_ragged(
    row_offsets: np.ndarray,
    col_indices: np.ndarray,
    width: int,
    sentinel: int,
) -> np.ndarray:
    """Dense ``(n_rows, width)`` fill of a ragged CSR; pads hold ``sentinel``.

    The sentinel is explicit (not the row count) because rectangular
    adjacencies — the bipartite cols→rows / rows→cols halves of ``repro.d2``
    — pad with the *target* side's vertex count.
    """
    n_rows = row_offsets.shape[0] - 1
    m = col_indices.shape[0]
    out = np.full((n_rows, width), sentinel, dtype=np.int32)
    if m == 0:
        return out
    deg = np.diff(row_offsets)
    # fully vectorized ragged fill: position of each CSR entry within its row
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), deg)
    within = np.arange(m, dtype=np.int64) - row_offsets[rows]
    keep = within < width
    out[rows[keep], within[keep]] = col_indices[keep]
    return out


def compose_pairs(
    row_offsets_a: np.ndarray,
    col_indices_a: np.ndarray,
    row_offsets_b: np.ndarray,
    col_indices_b: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """All length-2 paths ``v -A-> u -B-> w`` as raw ``(v, w)`` pairs.

    The host-side two-hop primitive behind both the square graph
    (``A = B = G``) and the bipartite column-conflict relation
    (``A = cols→rows``, ``B = rows→cols``).  Pairs are NOT deduplicated and
    include ``v == w`` round trips; callers clean up via ``csr_from_edges``.
    Fully vectorized: O(#paths) = O(Σ_u deg_A·deg_B) time and memory.
    """
    n_a = row_offsets_a.shape[0] - 1
    deg_a = np.diff(row_offsets_a).astype(np.int64)
    deg_b = np.diff(row_offsets_b).astype(np.int64)
    src_a = np.repeat(np.arange(n_a, dtype=np.int64), deg_a)  # v per A-edge
    mid = col_indices_a.astype(np.int64)                      # u per A-edge
    lens = deg_b[mid]                                         # fan-out per A-edge
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    v = np.repeat(src_a, lens)
    starts = np.repeat(row_offsets_b[:-1].astype(np.int64)[mid], lens)
    ends = np.cumsum(lens)
    within = np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)
    w = col_indices_b[starts + within].astype(np.int64)
    return v, w


def auto_tile_thresholds(
    degrees: np.ndarray,
    *,
    min_width: int = 8,
    min_class_frac: float = 0.05,
    max_classes: int = 6,
) -> tuple[int, ...]:
    """Log-spaced degree-class thresholds derived from the degree histogram.

    Generalizes the hand-tuned two-bucket ``buckets=(16, 128)`` Merrill-style
    load balancing into an automatic tiling: candidate bounds double from
    ``min_width`` up to the max degree, and a bound survives only if the
    degree class it closes holds at least ``min_class_frac`` of the vertices
    (smaller classes are merged into the next wider tile — per-class dispatch
    has a fixed cost that a handful of vertices cannot amortize).  Returns
    ``()`` — a single full-width class — when tiling cannot pay for itself:
    tiny graphs, or histograms so flat that every vertex needs (close to) the
    max-degree tile anyway.
    """
    degrees = np.asarray(degrees)
    n = int(degrees.size)
    dmax = int(degrees.max(initial=0))
    # tiling is a bandwidth play: below a few thousand vertices the whole
    # adjacency fits in cache and the extra per-class dispatches dominate
    if n < 2048 or dmax <= 2 * min_width:
        return ()
    out: list[int] = []
    lo = 0
    t = min_width
    while t < dmax and len(out) < max_classes:
        if int(((degrees > lo) & (degrees <= t)).sum()) >= min_class_frac * n:
            out.append(t)
            lo = t
        t *= 2
    return tuple(out)


class DeviceCSR:
    """Device-resident CSR graph — the ragged engine's native storage.

    Unlike ``DeviceGraph`` (a dense ``(n, Dmax)`` padded table), this keeps
    the paper's actual R/C arrays on device — O(m) memory — and serves
    neighbor *tiles* of any requested width straight from them:

    ``row_starts``  (n+1,) int32 — CSR offsets (R)
    ``col_padded``  (m + pad,) int32 — CSR column ids (C) with ``pad`` extra
                    sentinel slots so a full-width dynamic slice starting at
                    the last row never reads out of bounds
    ``deg_ext``     (n+1,) int32 — degrees with a 0 sentinel slot

    ``gather_rows(ids, width)`` materializes only the ``(w, width)`` tile a
    worklist class actually needs; lanes past each row's degree (and whole
    rows for sentinel ids) read as the sentinel ``n``, which is inert through
    the extended color array (``colors_ext[n] == 0``, §2).
    """

    def __init__(self, row_starts, col_padded, deg_ext, n: int, max_width: int):
        self.row_starts = row_starts
        self.col_padded = col_padded
        self.deg_ext = deg_ext
        self.n = int(n)
        self.max_width = int(max_width)  # widest legal gather (>= max degree)

    @classmethod
    def from_csr(cls, g: "CSRGraph") -> "DeviceCSR":
        import jax.numpy as jnp

        n = g.n
        w = max(g.max_degree, 1)
        col = np.concatenate(
            [g.col_indices.astype(np.int32), np.full(w, n, np.int32)]
        )
        deg = np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
        return cls(
            jnp.asarray(g.row_offsets.astype(np.int32)),
            jnp.asarray(col),
            jnp.asarray(deg),
            n,
            w,
        )

    # provider protocol (core.coloring run_ragged_engine): rows / row1
    def rows(self, ids, width: int | None = None):
        return self.gather_rows(ids, self.max_width if width is None else width)

    def row1(self, v):
        return self.gather_row1(v)

    def gather_rows(self, ids, width: int):
        """Ragged ``(w, width)`` neighbor-id tile for worklist ``ids``.

        ``width`` must cover every gathered vertex's degree (class callers
        size it from their degree bound) — narrower widths would silently
        truncate adjacency, exactly what ``padded_adjacency`` refuses to do.
        """
        import jax.numpy as jnp

        n = self.n
        safe = jnp.clip(ids, 0, max(n - 1, 0))
        starts = self.row_starts[safe]
        deg = self.deg_ext[safe]
        lane = jnp.arange(width, dtype=starts.dtype)[None, :]
        rows = self.col_padded[starts[:, None] + lane]
        valid = (lane < deg[:, None]) & (ids < n)[:, None]
        return jnp.where(valid, rows, n)

    def gather_row1(self, v, width: int | None = None):
        """One vertex's sentinel-padded neighbor row (traced scalar ``v``).

        The serial-tail primitive: a ``(width,)`` dynamic slice of C starting
        at R[v] — O(width) work per vertex, no dense adjacency anywhere.
        """
        import jax.numpy as jnp
        from jax import lax

        width = self.max_width if width is None else int(width)
        n = self.n
        start = self.row_starts[jnp.clip(v, 0, max(n - 1, 0))]
        vals = lax.dynamic_slice(self.col_padded, (start,), (width,))
        lane = jnp.arange(width, dtype=start.dtype)
        deg = self.deg_ext[jnp.clip(v, 0, n)]
        return jnp.where((lane < deg) & (v < n), vals, n)


def _gather_ragged(offsets: np.ndarray, values: np.ndarray, ids) -> np.ndarray:
    """Concatenated ``values[offsets[v]:offsets[v+1]]`` slices for ``ids``."""
    ids = np.asarray(ids, dtype=np.int64)
    starts = offsets[ids].astype(np.int64)
    lens = (offsets[ids + 1] - offsets[ids]).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, values.dtype)
    pos = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(lens) - lens, lens
    )
    return values[np.repeat(starts, lens) + pos]


def balanced_starts(weights: np.ndarray, ndev: int) -> np.ndarray:
    """Contiguous range boundaries balancing ``weights`` over ``ndev`` parts.

    Returns ``starts`` of shape ``(ndev + 1,)`` with ``starts[0] == 0`` and
    ``starts[-1] == len(weights)``: part ``d`` owns ``[starts[d],
    starts[d+1])``.  Cuts sit at the weight-prefix-sum quantiles, so parts
    carry (near-)equal total weight while staying contiguous in id — the
    classic 1-D block partition of distributed coloring (Boman–Bozdağ).
    """
    weights = np.asarray(weights, dtype=np.int64)
    n = int(weights.size)
    ndev = max(int(ndev), 1)
    cum = np.concatenate([[0], np.cumsum(weights)])
    targets = cum[-1] * np.arange(1, ndev, dtype=np.float64) / ndev
    cuts = np.searchsorted(cum, targets, side="left")
    starts = np.concatenate([[0], cuts, [n]]).astype(np.int64)
    return np.maximum.accumulate(np.clip(starts, 0, n))


@dataclasses.dataclass(frozen=True)
class PartitionedCSR:
    """Degree-balanced contiguous partition plan + halo index sets (§13).

    Device ``d`` owns the contiguous vertex range ``[starts[d],
    starts[d+1])``.  ``interior[d]`` / ``boundary[d]`` split that range by
    whether the vertex's color is ever read off-device under the conflict
    relation the plan was built for — 1-hop edges for distance-1
    (``from_graph``), two-hop reach for distance-2
    (``from_graph(boundary_mode="two_hop")``), shared-row column conflicts
    for bipartite partial coloring (``from_bipartite``).  Interior vertices
    never communicate; ``boundary[d]`` doubles as device ``d``'s halo SEND
    list and ``recv[d]`` is the remote vertex set whose colors it reads.
    The engine (``core/distributed.py``) consumes ``starts`` + ``boundary``
    (its all-gather broadcast makes per-pair recv routing unnecessary), so
    those are built eagerly at partition time (O(m) host work); ``recv``
    documents the communication pattern for validation and introspection
    and is computed lazily on first access (O(ndev·m)) — the property
    tests assert it against the edge list.
    """

    n: int
    starts: np.ndarray            # (ndev+1,) int64 range boundaries
    interior: tuple               # per-device global ids, colors stay local
    boundary: tuple               # per-device global ids == halo send lists
    # zero-arg closure building the recv sets on demand (engine never needs
    # them); excluded from equality/repr like any derived cache
    _recv_builder: object = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def recv(self) -> tuple:
        """Per-device remote ids whose colors the device reads (lazy)."""
        cache = getattr(self, "_recv_cache", None)
        if cache is None:
            cache = (self._recv_builder() if self._recv_builder is not None
                     else tuple(np.zeros(0, np.int32)
                                for _ in self.interior))
            object.__setattr__(self, "_recv_cache", cache)
        return cache

    @property
    def ndev(self) -> int:
        return len(self.interior)

    @property
    def lens(self) -> np.ndarray:
        return np.diff(self.starts).astype(np.int64)

    @property
    def n_loc(self) -> int:
        """Uniform per-shard slot count (max range length, >= 1)."""
        return max(int(self.lens.max(initial=0)), 1)

    @property
    def halo_words(self) -> int:
        """Total boundary vertices — one halo round's worst-case payload."""
        return int(sum(b.size for b in self.boundary))

    def owners(self) -> np.ndarray:
        """(n,) owning-device id per vertex."""
        return (
            np.searchsorted(self.starts, np.arange(self.n), side="right") - 1
        ).astype(np.int32)

    def boundary_masks(self) -> np.ndarray:
        """(ndev, n_loc) bool: is local slot ``i`` of device ``d`` boundary."""
        out = np.zeros((self.ndev, self.n_loc), dtype=bool)
        for d, b in enumerate(self.boundary):
            out[d, b - self.starts[d]] = True
        return out

    @classmethod
    def from_graph(
        cls, g: "CSRGraph", ndev: int, *, boundary_mode: str = "edge",
        validate_input: str | None = None,
    ) -> "PartitionedCSR":
        """Partition ``g`` balancing ``degree + 1`` per contiguous range.

        ``boundary_mode="edge"`` marks a vertex boundary when it has a
        cross-partition edge (its color is read one hop away);
        ``"two_hop"`` when its *two-hop* neighborhood crosses (the reader
        set of distance-2 coloring) — a vertex or any of its neighbors has
        a cross-partition edge.

        ``validate_input="strict"|"repair"`` runs ``g`` through the §17
        ingest front door first: an asymmetric CSR silently breaks the
        halo-exchange invariant (a boundary vertex the other side doesn't
        know to send), so sanitize before partitioning.
        """
        if validate_input is not None:
            from repro.ingest import sanitize_csr

            g, _ = sanitize_csr(g, policy=validate_input)
        n = g.n
        starts = balanced_starts(g.degrees.astype(np.int64) + 1, ndev)
        owner = (
            np.searchsorted(starts, np.arange(n), side="right") - 1
        ).astype(np.int32)
        src, dst = g.edges()
        cross = owner[src] != owner[dst]
        has_cross = np.zeros(n, dtype=bool)
        has_cross[src[cross]] = True
        if boundary_mode == "edge":
            is_boundary = has_cross
        elif boundary_mode == "two_hop":
            nbr_cross = np.zeros(n, dtype=np.int64)
            np.add.at(nbr_cross, src, has_cross[dst].astype(np.int64))
            is_boundary = has_cross | (nbr_cross > 0)
        else:
            raise ValueError(
                f"unknown boundary_mode {boundary_mode!r}; options: edge, two_hop"
            )
        interior, boundary = [], []
        for d in range(len(starts) - 1):
            ids = np.arange(starts[d], starts[d + 1], dtype=np.int32)
            boundary.append(ids[is_boundary[ids]])
            interior.append(ids[~is_boundary[ids]])

        def build_recv() -> tuple:
            recv = []
            for d in range(len(starts) - 1):
                mine = owner[src] == d
                out_edges = dst[mine & cross]
                if boundary_mode == "two_hop":
                    # readers two hops away: neighbors of my one-hop reach
                    lo = g.row_offsets[starts[d]]
                    hi = g.row_offsets[starts[d + 1]]
                    reach = np.unique(g.col_indices[lo:hi])
                    two = np.unique(
                        _gather_ragged(g.row_offsets, g.col_indices, reach))
                    out_edges = np.concatenate([out_edges, reach, two])
                uniq = np.unique(out_edges).astype(np.int32)
                in_range = (uniq >= starts[d]) & (uniq < starts[d + 1])
                recv.append(uniq[~in_range])
            return tuple(recv)

        return cls(n, starts, tuple(interior), tuple(boundary), build_recv)

    @classmethod
    def from_bipartite(cls, bg, ndev: int) -> "PartitionedCSR":
        """Partition a ``BipartiteGraph``'s COLUMN side (the colored side).

        Columns conflict when they share a row, so a column is boundary iff
        one of its rows also holds a column owned by another device.
        """
        n = bg.n_cols
        starts = balanced_starts(bg.col_degrees.astype(np.int64) + 1, ndev)
        owner = (
            np.searchsorted(starts, np.arange(n), side="right") - 1
        ).astype(np.int32)
        # a row "spans" when its columns touch more than one partition
        row_src = np.repeat(
            np.arange(bg.n_rows, dtype=np.int64), bg.row_degrees
        )
        col_owner = owner[bg.row_to_col]
        row_min = np.full(bg.n_rows, np.iinfo(np.int32).max, np.int64)
        row_max = np.full(bg.n_rows, -1, np.int64)
        np.minimum.at(row_min, row_src, col_owner)
        np.maximum.at(row_max, row_src, col_owner)
        row_spans = (row_max >= 0) & (row_min != row_max)
        col_src = np.repeat(np.arange(n, dtype=np.int64), bg.col_degrees)
        bad = np.zeros(n, dtype=np.int64)
        np.add.at(bad, col_src, row_spans[bg.col_to_row].astype(np.int64))
        is_boundary = bad > 0
        interior, boundary = [], []
        for d in range(len(starts) - 1):
            ids = np.arange(starts[d], starts[d + 1], dtype=np.int32)
            boundary.append(ids[is_boundary[ids]])
            interior.append(ids[~is_boundary[ids]])

        def build_recv() -> tuple:
            recv = []
            for d in range(len(starts) - 1):
                lo = bg.col_offsets[starts[d]]
                hi = bg.col_offsets[starts[d + 1]]
                my_rows = np.unique(bg.col_to_row[lo:hi])
                reach = np.unique(
                    _gather_ragged(bg.row_offsets, bg.row_to_col, my_rows)
                )
                in_range = (reach >= starts[d]) & (reach < starts[d + 1])
                recv.append(reach[~in_range].astype(np.int32))
            return tuple(recv)

        return cls(n, starts, tuple(interior), tuple(boundary), build_recv)

    # -- stacked per-shard device layouts (consumed by core/distributed) -----
    def stack_shards(self, g: "CSRGraph") -> tuple[np.ndarray, ...]:
        """Per-shard CSR arrays stacked on a leading device axis.

        Returns ``(row_starts (ndev, L+1), col_padded (ndev, Mcap), deg
        (ndev, L+1))`` — the ``DeviceCSR`` layout of each shard's row range,
        with GLOBAL column ids (gathers read the globally-indexed color
        view) and the global sentinel ``n`` in every padding slot.  ``Mcap``
        includes ``max_degree`` slack so a full-width dynamic slice starting
        at the last local row never reads out of bounds.
        """
        assert g.n == self.n, "plan was built for a different graph"
        L = self.n_loc
        wmax = max(g.max_degree, 1)
        m_loc = [
            int(g.row_offsets[self.starts[d + 1]] - g.row_offsets[self.starts[d]])
            for d in range(self.ndev)
        ]
        m_cap = max(max(m_loc), 1) + wmax
        row_starts = np.zeros((self.ndev, L + 1), np.int32)
        col = np.full((self.ndev, m_cap), self.n, np.int32)
        deg = np.zeros((self.ndev, L + 1), np.int32)
        for d in range(self.ndev):
            s, e = int(self.starts[d]), int(self.starts[d + 1])
            ln = e - s
            ro = (g.row_offsets[s : e + 1] - g.row_offsets[s]).astype(np.int32)
            row_starts[d, : ln + 1] = ro
            row_starts[d, ln + 1 :] = ro[-1] if ln else 0
            col[d, : m_loc[d]] = g.col_indices[
                g.row_offsets[s] : g.row_offsets[e]
            ]
            deg[d, :ln] = g.degrees[s:e]
        return row_starts, col, deg

    def stack_rows(self, rows: np.ndarray, fill: int) -> np.ndarray:
        """Slice a dense per-vertex ``(n, W)`` table into ``(ndev, L, W)``.

        Shard ``d`` gets its own row range; slots past the range length are
        filled with ``fill`` (the hop target's sentinel) so padding lanes
        stay inert — used to shard the first hop of ``TwoHopRows``.
        """
        L = self.n_loc
        out = np.full((self.ndev, L, rows.shape[1]), fill, rows.dtype)
        for d in range(self.ndev):
            s, e = int(self.starts[d]), int(self.starts[d + 1])
            out[d, : e - s] = rows[s:e]
        return out


class DeviceGraph:
    """Device-side padded-adjacency graph used by the JAX coloring kernels.

    ``adj``      (n, D) int32, sentinel = n in padding lanes
    ``degrees``  (n+1,) int32, sentinel slot holds 0
    """

    def __init__(self, adj, degrees, n: int):
        self.adj = adj
        self.degrees = degrees
        self.n = int(n)
        self.D = int(adj.shape[1])

    @classmethod
    def from_csr(cls, g: CSRGraph, width: int | None = None) -> "DeviceGraph":
        import jax.numpy as jnp

        adj = jnp.asarray(g.padded_adjacency(width))
        deg = jnp.asarray(
            np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
        )
        return cls(adj, deg, g.n)
