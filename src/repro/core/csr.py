"""Compressed Sparse Row graph container (the paper's R / C arrays).

The paper (§3, Fig. 2) stores the graph in CSR: ``R`` (row offsets, n+1) and
``C`` (column indices, m).  We keep the same two arrays, plus TPU-friendly
derived views:

* ``padded_adjacency(width)`` — a dense ``(n, width)`` int32 view with the
  sentinel ``n`` in padding slots.  Gathers through an extended color array
  ``colors_ext`` of length ``n + 1`` (whose last slot is pinned to color 0)
  make padding lanes inert: color 0 is "uncolored" and is never forbidden and
  never conflicting.  This is the vector-lane analogue of CUDA's masked warp
  lanes.
* ``degree_buckets`` — vertex classes by degree, the data-layout analogue of
  Merrill's thread/warp/CTA load-balancing hierarchy (§3.3 of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "CSRGraph",
    "DeviceGraph",
    "csr_from_edges",
    "next_pow2",
]


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected sparse graph in CSR form (host-side, numpy)."""

    row_offsets: np.ndarray  # (n+1,) int32/int64
    col_indices: np.ndarray  # (m,) int32

    def __post_init__(self):
        assert self.row_offsets.ndim == 1 and self.col_indices.ndim == 1
        assert self.row_offsets[0] == 0
        assert self.row_offsets[-1] == self.col_indices.shape[0]

    # -- basic stats ---------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.row_offsets.shape[0] - 1)

    @property
    def m(self) -> int:
        """Number of directed edges (2x undirected edge count)."""
        return int(self.col_indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max(initial=0))

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    @property
    def degree_std(self) -> float:
        return float(self.degrees.std())

    def neighbors(self, v: int) -> np.ndarray:
        return self.col_indices[self.row_offsets[v] : self.row_offsets[v + 1]]

    # -- dense views ---------------------------------------------------------
    def padded_adjacency(self, width: int | None = None) -> np.ndarray:
        """Dense ``(n, width)`` adjacency; padding slots hold the sentinel ``n``."""
        n = self.n
        width = max(self.max_degree, 1) if width is None else int(width)
        adj = np.full((n, width), n, dtype=np.int32)
        if self.m == 0:
            return adj
        deg = self.degrees
        # fully vectorized ragged fill: position of each CSR entry within its row
        rows = np.repeat(np.arange(n, dtype=np.int64), deg)
        within = np.arange(self.m, dtype=np.int64) - self.row_offsets[rows]
        keep = within < width
        adj[rows[keep], within[keep]] = self.col_indices[keep]
        return adj

    def degree_buckets(self, thresholds: Sequence[int]) -> list[np.ndarray]:
        """Vertex-id arrays per degree class: (0, t0], (t0, t1], ..., (tk-1, inf)."""
        deg = self.degrees
        out, lo = [], 0
        bounds = list(thresholds) + [max(self.max_degree, 1)]
        for hi in bounds:
            ids = np.where((deg > lo) & (deg <= hi))[0].astype(np.int32)
            out.append(ids)
            lo = hi
        # degree-0 vertices go to the first bucket (they take color 1 trivially)
        zero = np.where(deg == 0)[0].astype(np.int32)
        if zero.size:
            out[0] = np.concatenate([zero, out[0]])
        return out

    # -- edge list view (for validation / COO ops) ---------------------------
    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.col_indices.astype(np.int32)


def csr_from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    *,
    symmetrize: bool = True,
    dedup: bool = True,
) -> CSRGraph:
    """Build a clean CSR graph from an edge list.

    Drops self loops; optionally symmetrizes (undirected) and deduplicates.
    Adjacency lists come out sorted, matching the UF-collection convention.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if dedup and src.size:
        key = src * n + dst
        key = np.unique(key)
        src, dst = key // n, key % n
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    row_offsets = np.zeros(n + 1, dtype=np.int64)
    np.add.at(row_offsets, src + 1, 1)
    row_offsets = np.cumsum(row_offsets)
    return CSRGraph(row_offsets.astype(np.int64), dst.astype(np.int32))


class DeviceGraph:
    """Device-side padded-adjacency graph used by the JAX coloring kernels.

    ``adj``      (n, D) int32, sentinel = n in padding lanes
    ``degrees``  (n+1,) int32, sentinel slot holds 0
    """

    def __init__(self, adj, degrees, n: int):
        self.adj = adj
        self.degrees = degrees
        self.n = int(n)
        self.D = int(adj.shape[1])

    @classmethod
    def from_csr(cls, g: CSRGraph, width: int | None = None) -> "DeviceGraph":
        import jax.numpy as jnp

        adj = jnp.asarray(g.padded_adjacency(width))
        deg = jnp.asarray(
            np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
        )
        return cls(adj, deg, g.n)
