"""Vectorized FirstFit variants (paper Alg. 4) over padded neighbor colors.

All variants take ``neigh_colors`` of shape ``(w, W)`` int32 — the gathered
colors of up to ``W`` neighbors per worklist vertex, 0 meaning
"no neighbor / uncolored" — and return the smallest permissible color in
``[1, W+1]`` per row.  Greedy guarantees a free color exists in that range
(W neighbors can forbid at most W of the W+1 candidates).

Variants (see DESIGN.md §3 for the CUDA→TPU mapping):

* ``scan``   — the paper's baseline colorMask: scatter forbidden counts into a
               per-vertex (W+2)-wide mask, then scan for the first zero.  This
               is the memory-traffic-heavy variant the bitset replaces.
* ``sort``   — sort neighbor colors and walk the first gap (an alternative
               low-memory baseline; O(W log W) compute, O(w·W) memory).
* ``bitset`` — the paper's §3.2 contribution: forbidden colors packed into
               uint32 words; first permissible color via find-first-set.  TPU
               has no ``__ffs`` intrinsic, so ffs = popcount(lsb−1) with the
               two's-complement lsb trick — both single VPU ops.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["firstfit_scan", "firstfit_sort", "firstfit_bitset", "FF_FUNCS", "ffs_u32"]


def firstfit_scan(neigh_colors: jax.Array) -> jax.Array:
    """colorMask analogue: per-row forbidden counts + first-zero scan."""
    w, W = neigh_colors.shape
    C = W + 1  # candidate colors 1..C
    cols = jnp.where((neigh_colors >= 1) & (neigh_colors <= C), neigh_colors, 0)
    mask = jnp.zeros((w, C + 1), dtype=jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[:, None], (w, W))
    mask = mask.at[rows, cols].add(1)  # column 0 is a trash slot
    permissible = mask[:, 1:] == 0  # (w, C)
    return jnp.argmax(permissible, axis=1).astype(jnp.int32) + 1


def firstfit_sort(neigh_colors: jax.Array) -> jax.Array:
    """Sort + first-gap walk: f advances past each sorted color it meets."""
    s = jnp.sort(neigh_colors, axis=1)
    w, W = s.shape

    def body(d, f):
        return jnp.where(s[:, d] == f, f + 1, f)

    f = lax.fori_loop(0, W, body, jnp.ones((w,), dtype=jnp.int32))
    return f


def _forbidden_words(neigh_colors: jax.Array, nwords: int) -> jax.Array:
    """Pack forbidden colors 1..32*nwords into uint32 bit words (bit c-1)."""
    idx = neigh_colors.astype(jnp.int32) - 1  # -1 for "no color"
    valid = idx >= 0
    word_of = jnp.where(valid, idx >> 5, -1)
    bit = (jnp.where(valid, idx, 0) & 31).astype(jnp.uint32)
    bits = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))
    words = []
    for wd in range(nwords):
        contrib = jnp.where(word_of == wd, bits, jnp.uint32(0))
        words.append(
            lax.reduce(contrib, jnp.uint32(0), lax.bitwise_or, dimensions=(1,))
        )
    return jnp.stack(words, axis=1)  # (w, nwords)


def ffs_u32(x: jax.Array) -> jax.Array:
    """Find-first-set per uint32 element: index of lowest 1 bit, 32 if x==0.

    TPU adaptation of CUDA ``__ffs``: lsb = x & (~x + 1); index = popcount(lsb-1).
    """
    lsb = x & (~x + jnp.uint32(1))
    tz = lax.population_count(lsb - jnp.uint32(1))
    return jnp.where(x == 0, jnp.uint32(32), tz).astype(jnp.int32)


def firstfit_bitset(neigh_colors: jax.Array) -> jax.Array:
    """The paper's bitset FirstFit: bit words + find-first-set."""
    w, W = neigh_colors.shape
    nbits = W + 1
    nwords = (nbits + 31) // 32
    words = _forbidden_words(neigh_colors, nwords)
    # forbid phantom candidates beyond W+1 so ffs never exceeds the greedy bound
    tail = nwords * 32 - nbits
    if tail:
        pad_mask = jnp.uint32(((1 << tail) - 1) << (32 - tail))
        words = words.at[:, nwords - 1].set(words[:, nwords - 1] | pad_mask)
    free = ~words
    tz = ffs_u32(free)  # (w, nwords), 32 where word full
    has = free != 0
    first_w = jnp.argmax(has, axis=1).astype(jnp.int32)
    tz_sel = jnp.take_along_axis(tz, first_w[:, None], axis=1)[:, 0]
    return first_w * 32 + tz_sel + 1


FF_FUNCS = {
    "scan": firstfit_scan,
    "sort": firstfit_sort,
    "bitset": firstfit_bitset,
}
