"""Data-driven speculative-greedy GPU coloring (paper Alg. 7) in JAX.

The paper's contribution, adapted to the TPU/XLA execution model (DESIGN.md §3):

* worklist double-buffering          -> functional carry swap
* atomic push -> CUB prefix sum      -> ``jnp.cumsum`` compaction (identical math)
* color clearing on conflict          -> kept verbatim (correctness-critical here too)
* kernel fusion + global barrier      -> each super-step is ONE jitted XLA
                                         computation; the loop carry is the barrier
* thread coarsening                   -> sequential chunks per super-step (fewer
                                         concurrent speculations -> fewer conflicts)
* Merrill load balancing              -> degree classes, each processed at its own
                                         tile width

Two execution ENGINES (DESIGN.md §12):

* ``ragged`` (default) — the CSR-native rotated super-step: ONE adjacency
  gather and ONE neighbor-color gather per iteration serve BOTH conflict
  detection and FirstFit; degree-tiled dispatch sizes each worklist class's
  gather to its own tile width (O(edges) traffic, not O(n·Δmax)); adaptive
  tail-serialization collapses slow-shrinking worklist cascades into one
  sequential-on-device FirstFit pass that is conflict-free by construction.
* ``padded`` — the same schedule dispatched through the original dense
  ``(n, Δmax)`` padded-adjacency table.  Padding lanes are sentinel-inert, so
  ``padded`` and ``ragged`` produce bit-identical colors — the engines differ
  only in memory layout and bandwidth (tested).
* ``classic`` — the pre-§12 two-phase super-step (FirstFit kernel, then a
  separate ConflictResolve kernel re-gathering the tiles), kept as the
  paper-faithful baseline and for A/B benchmarking.

Two execution modes, orthogonal to the engine:

* ``workefficient`` (default) — host loop; each class's worklist buffer is
  re-sliced to the next power of two of its live count each super-step.
* ``fused`` — a single ``lax.while_loop`` over full-capacity buffers: the
  speculative phase is one device program (plus at most one serial-tail
  dispatch), what you deploy on TPU where re-dispatch is expensive.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.api import register
from repro.core.csr import CSRGraph, DeviceCSR, auto_tile_thresholds, next_pow2
from repro.core.firstfit import FF_FUNCS
from repro.core.heuristics import conflict_lose_flags, conflict_lose_lanes
from repro.obs.spans import SpanRecorder, jit_span, span
from repro.obs.trace import (
    assemble_trace,
    empty_trace,
    resolve_trace_cap,
    ring_init,
    ring_rows,
)

__all__ = [
    "ColoringResult",
    "DenseRows",
    "color_data_driven",
    "color_fused",
    "fused_result",
    "order_tail",
    "provider_tail",
    "ragged_superstep",
    "run_fused_loop",
    "run_ragged_engine",
    "run_workefficient_loop",
    "resolve_tail_threshold",
    "serial_tail_step",
]

# Row providers travel INTO module-level jitted engine functions as pytrees,
# so jit compilations are keyed on (provider type, aux config, array shapes)
# and cached across color() calls — never on per-call Python closures.
jax.tree_util.register_pytree_node(
    DeviceCSR,
    lambda d: ((d.row_starts, d.col_padded, d.deg_ext), (d.n, d.max_width)),
    lambda aux, ch: DeviceCSR(*ch, *aux),
)

# Adaptive tail-serialization: the worklist "stalls" when a super-step
# retires less than 1 - STALL_NUM/STALL_DEN of it.  Cascading graphs (grids,
# circuits, roads) shrink by ~0.1-1%/step for tens to hundreds of steps —
# the stall detector hands those to the serial tail after ~3 steps, where
# one sequential pass crosses the whole frontier.  Integer math so host and
# device drivers decide identically (int32-safe far past this repo's suite
# sizes).
STALL_NUM, STALL_DEN = 9, 10


def _packed_gather_ok(dmax: int, color_bound: int | None = None) -> bool:
    """§17 capacity predicate for the color|deg<<16 packed gather (lazy
    import — ``repro.ingest`` imports ``core.csr`` through the package)."""
    from repro.ingest import packed_gather_ok

    return packed_gather_ok(dmax, color_bound)


@dataclasses.dataclass
class ColoringResult:
    colors: np.ndarray
    iterations: int
    work_items: int          # worklist entries actually live across super-steps
    padded_work: int         # gather cells dispatched: Σ lanes × tile width
    converged: bool
    algorithm: str = "data_driven_sgr"
    # sharded engine only (§13): bytes of boundary colors a device receives
    # per super-step, averaged over the run; 0 on single-device engines
    halo_bytes_per_step: float = 0.0
    # per-degree-class gather-cell accounting (§15): ``(width, cells)`` pairs
    # for every class that dispatched work (the serial tail contributes a
    # final full-width entry).  Partitions ``padded_work`` — the roofline
    # model (benchmarks/roofline.py) turns it into bytes moved per class.
    class_cells: tuple = ()
    # per-super-step telemetry (§16): a ``repro.obs.RunTrace`` when the run
    # was traced (``trace=True``), else None.  ``trace`` is a STATIC knob —
    # untraced runs compile the identical program and stay bit-identical.
    trace: object = None
    # §17 robustness ledger: every deviation from the clean fast path —
    # ingest repairs applied to the input, guarantee-ladder escalations
    # taken to reach a valid coloring — as JSON-safe dicts with a "stage"
    # key.  Empty on every healthy run; the CI regression gate fails on
    # unexpected entries in BENCH records.
    degradations: tuple = ()

    @property
    def num_colors(self) -> int:
        return int(self.colors.max(initial=0))


# --------------------------------------------------------------------------
# phase helpers (shared with topo.py / threestep.py / distributed.py)
# --------------------------------------------------------------------------

def gather_rows(adj: jax.Array, ids: jax.Array, sentinel: int | None = None) -> jax.Array:
    """Gather padded adjacency rows; sentinel ids yield all-sentinel rows.

    ``sentinel`` is the fill value for masked rows and defaults to the row
    count (square adjacency).  Rectangular compositions — the bipartite
    cols→rows hop, whose *output* ids live on the other side (repro.d2) —
    pass the target side's sentinel explicitly.
    """
    n = adj.shape[0]
    fill = n if sentinel is None else sentinel
    rows = adj[jnp.clip(ids, 0, n - 1)]
    return jnp.where((ids < n)[:, None], rows, fill)


def ff_apply(adj, colors_ext, ids, kind: str, use_kernel: bool = False,
             rows=None):
    """FirstFit the worklist chunk ``ids`` and write colors (sentinel-safe)."""
    n = adj.shape[0]
    rows = gather_rows(adj, ids) if rows is None else rows
    nc = colors_ext[rows]
    if use_kernel:
        from repro.kernels.firstfit.ops import firstfit_bitset_tpu

        c = firstfit_bitset_tpu(nc)
    else:
        c = FF_FUNCS[kind](nc)
    c = jnp.where(ids < n, c, 0).astype(colors_ext.dtype)
    return colors_ext.at[ids].set(c)


def cr_flags(adj, deg_ext, colors_ext, ids, heuristic: str,
             use_kernel: bool = False, rows=None):
    """Conflict flags for the worklist chunk ``ids`` (True = loses, recolor)."""
    rows = gather_rows(adj, ids) if rows is None else rows
    my_c = colors_ext[ids]
    nc = colors_ext[rows]
    my_d = deg_ext[ids]
    nd = deg_ext[rows]
    if use_kernel:
        from repro.kernels.conflict.ops import conflict_tpu

        return conflict_tpu(ids, rows, my_c, nc, my_d, nd, heuristic)
    return conflict_lose_flags(ids, rows, my_c, nc, my_d, nd, heuristic)


def compact(ids: jax.Array, flags: jax.Array, sentinel: int):
    """Prefix-sum worklist compaction (the paper's CUB scan, §3.1)."""
    cap = ids.shape[0]
    pos = jnp.cumsum(flags.astype(jnp.int32)) - 1
    out = jnp.full((cap,), sentinel, dtype=ids.dtype)
    out = out.at[jnp.where(flags, pos, cap)].set(ids, mode="drop")
    return out, jnp.sum(flags.astype(jnp.int32))


def _chunk_bounds(cap: int, nchunks: int):
    nchunks = max(1, min(nchunks, cap))
    size = math.ceil(cap / nchunks)
    return [(i * size, min((i + 1) * size, cap)) for i in range(nchunks)
            if i * size < cap]


# --------------------------------------------------------------------------
# classic super-step: FirstFit -> ConflictResolve(+clear) -> compaction
# --------------------------------------------------------------------------

@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "coarsen_ff", "coarsen_cr",
                     "use_kernel", "reuse_rows"),
)
def sgr_step(
    adj,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    use_kernel: bool = False,
    reuse_rows: bool = False,
):
    n = adj.shape[0]
    cap = wl.shape[0]

    # §Perf iteration: FirstFit and ConflictResolve gather the same adjacency
    # rows; with aligned (un)chunking the gather can be done once per step.
    rows_all = gather_rows(adj, wl) if (
        reuse_rows and coarsen_ff == 1 and coarsen_cr == 1) else None

    # ---- FirstFit phase (coarsened: later chunks see earlier chunk colors) --
    for lo, hi in _chunk_bounds(cap, coarsen_ff):
        colors_ext = ff_apply(adj, colors_ext, wl[lo:hi], kind, use_kernel,
                              rows=rows_all)

    # ---- ConflictResolve + color clearing (paper §3.1) ----------------------
    lose_parts = []
    for lo, hi in _chunk_bounds(cap, coarsen_cr):
        ids = wl[lo:hi]
        lose = cr_flags(adj, deg_ext, colors_ext, ids, heuristic, use_kernel,
                        rows=rows_all)
        colors_ext = colors_ext.at[ids].set(
            jnp.where(lose, 0, colors_ext[ids])
        )
        lose_parts.append(lose)
    lose = jnp.concatenate(lose_parts) if len(lose_parts) > 1 else lose_parts[0]

    # ---- worklist compaction (double buffering = functional swap) -----------
    new_wl, new_count = compact(wl, lose, sentinel=n)
    return colors_ext, new_wl, new_count


# --------------------------------------------------------------------------
# the rotated (fused) super-step — ONE gather serves both phases (§12)
# --------------------------------------------------------------------------
# Key observation: a worklist vertex FirstFits a color that is, by
# construction, distinct from every color visible in its gathered tile — so
# fresh conflicts can only involve OTHER worklist vertices recolored in the
# same step.  Rotating the loop (verify the previous step's speculation, then
# immediately recolor the losers from the SAME tile) therefore needs exactly
# one adjacency gather and one neighbor-color gather per iteration, where the
# classic two-phase step pays both twice.  Every vertex this step recolors is
# re-verified next step; termination (nobody recolored) certifies validity.

def ragged_superstep(rows_fn, deg_ext, colors_ext, wl, *,
                     heuristic: str = "degree", kind: str = "bitset",
                     use_kernel: bool = False, coarsen: int = 1,
                     colors_read=None, pack_degrees: bool = False,
                     provider=None, width: int | None = None):
    """One rotated super-step: ConflictResolve + FirstFit + compaction.

    ``rows_fn(ids) -> (w, W)`` provides the sentinel-padded neighbor tile —
    a ``DeviceCSR`` class gather, a dense padded-row gather, or a composed
    two-hop gather (repro.d2); the engine is generic over the row provider.
    ``coarsen`` chunks the worklist so later chunks observe earlier chunks'
    recolorings (the thread-coarsening knob, fewer concurrent speculations).

    ``colors_read`` is the snapshot the FIRST chunk reads (later chunks read
    the accumulating state).  Degree-tiled drivers pass the iteration-start
    snapshot so every class speculates against the same state — which makes
    a tiled super-step bit-identical to the single-class one (classes
    partition the worklist and their writes are disjoint).

    ``pack_degrees`` fuses the neighbor-color and neighbor-degree gathers
    into ONE gather of ``color | degree << 16`` words — degrees are static
    and an O(n) repack per step is far cheaper than a second (w, W) scattered
    gather.  Callers enable it when both fields provably fit 15 bits (colors
    are bounded by the gather width + 1).  Packed or not, the arithmetic is
    exact, so results are bit-identical either way.

    ``use_kernel="csr"`` (backend="pallas-csr", DESIGN.md §18) routes the
    step through the CSR-resident fused kernel when ``provider`` is a
    ``DeviceCSR`` and the packed word fits (``pack_degrees``): the kernel
    gathers neighbors straight from R/C in VMEM — no ``rows_fn`` call and no
    materialized ``(w, W)`` tile.  Configurations the CSR kernel can't serve
    (dense providers, chunked worklists, packed overflow) fall back to the
    gathered kernel — bit-identical by the §15 argument.
    """
    n = colors_ext.shape[0] - 1
    cap = wl.shape[0]
    read = colors_ext if colors_read is None else colors_read
    chunk_bounds = _chunk_bounds(cap, coarsen)
    # the packed word array must track earlier chunks' writes, so a chunked
    # step would repack O(n) per chunk — fall back to separate gathers there
    pack_degrees = pack_degrees and len(chunk_bounds) == 1
    use_csr = (use_kernel == "csr" and pack_degrees
               and isinstance(provider, DeviceCSR))
    need_parts = []
    for lo, hi in chunk_bounds:
        ids = wl[lo:hi]
        if use_csr:
            from repro.kernels.superstep.csr_kernel import superstep_csr_tpu

            packed = read + (deg_ext << 16)
            new_c, need = superstep_csr_tpu(
                provider.row_starts, provider.col_padded, packed, ids,
                provider.max_width if width is None else width, heuristic)
            valid = ids < n
            need = need & valid
            new_c = jnp.where(valid, new_c, 0).astype(colors_ext.dtype)
            colors_ext = colors_ext.at[ids].set(new_c)
            read = colors_ext
            need_parts.append(need)
            continue
        rows = rows_fn(ids)
        my_c = read[ids]
        my_d = deg_ext[ids]
        if pack_degrees and not use_kernel:
            tile = (read + (deg_ext << 16))[rows]
            nc = tile & jnp.int32(0xFFFF)
            nd = tile >> 16
        else:
            nc = read[rows]
            nd = deg_ext[rows]
        if use_kernel:
            from repro.kernels.superstep.ops import superstep_tpu

            new_c, need = superstep_tpu(ids, rows, my_c, nc, my_d, nd,
                                        heuristic)
        else:
            same, lose_lane = conflict_lose_lanes(ids, rows, my_c, nc, my_d,
                                                  nd, heuristic)
            need = jnp.any(lose_lane, axis=1) | (my_c == 0)
            # lanes I beat are provably recoloring too — refit as if cleared
            # (the classic engine's clear-then-refit dynamics, in one pass)
            ff_nc = jnp.where(same & ~lose_lane, 0, nc)
            new_c = jnp.where(need, FF_FUNCS[kind](ff_nc), my_c)
        valid = ids < n
        need = need & valid
        new_c = jnp.where(valid, new_c, 0).astype(colors_ext.dtype)
        colors_ext = colors_ext.at[ids].set(new_c)
        read = colors_ext  # later chunks observe earlier chunks' writes
        need_parts.append(need)
    need = jnp.concatenate(need_parts) if len(need_parts) > 1 else need_parts[0]
    new_wl, new_count = compact(wl, need, sentinel=n)
    return colors_ext, new_wl, new_count


def serial_tail_step(row1_fn, colors_ext, wl, kind: str = "bitset"):
    """Sequential-on-device FirstFit over ``wl`` — conflict-free by construction.

    A ``fori_loop`` walks the worklist one vertex at a time and re-FirstFits
    it against the *current* state — the canonical sequential-greedy choice,
    which both guarantees zero conflicts on every edge incident to the
    worklist (later vertices observe earlier updates) and sheds the inflated
    colors speculation may have piled up before the engine bailed out: the
    whole cascade tail costs ONE super-step.  ``row1_fn(v) -> (W,)`` is the
    single-vertex row provider (``DeviceCSR.gather_row1``, a dense row, or a
    composed two-hop row).

    The worklist's colors are cleared up front, so each refit sees only the
    colors of settled (non-worklist) vertices and of already-processed tail
    entries — pure sequential greedy with the winners pinned.  Clearing also
    makes self/duplicate lanes in composed two-hop rows trivially inert.
    """
    n = colors_ext.shape[0] - 1
    colors_ext = colors_ext.at[wl].set(0)  # sentinel entries write slot n: 0

    def body(i, colors_ext):
        v = wl[i]
        nc = colors_ext[row1_fn(v)]
        ff = FF_FUNCS[kind](nc[None, :])[0]
        new_c = jnp.where(v < n, ff, 0)
        return colors_ext.at[v].set(new_c.astype(colors_ext.dtype))

    return lax.fori_loop(0, wl.shape[0], body, colors_ext)


def order_tail(wl, deg_ext):
    """Canonical serial-tail order: degree-descending, ties id-ascending.

    Largest-degree-first is the classic greedy quality ordering and matches
    the engine's conflict heuristic; sentinels sort last.  One shared
    device-side implementation so the host, fused, and batched drivers
    produce the exact same sequence (bit-identical colors).
    """
    n = deg_ext.shape[0] - 1
    ids = jnp.sort(wl)                       # id-ascending, sentinels last
    key = jnp.where(ids < n, -deg_ext[ids], jnp.iinfo(jnp.int32).max)
    return ids[jnp.argsort(key, stable=True)]


def resolve_tail_threshold(tail_serial, n: int) -> tuple[bool, int]:
    """(enabled, live-count threshold) from the ``tail_serial`` option.

    ``"auto"`` picks a count below which one sequential pass beats the
    expected remaining super-step dispatches; ``None``/``0`` disables both
    the threshold and the stall detector (pure speculative, pre-§12
    semantics); an int is an explicit threshold.
    """
    if tail_serial in (None, 0, False):
        return False, 0
    if tail_serial == "auto":
        return True, int(min(1024, max(32, n // 64)))
    return True, max(1, int(tail_serial))


def _stalled(iters, total, prev) -> bool:
    """Worklist stall: the last step retired < 1/STALL_DEN of the worklist.

    ``iters >= 3`` skips the bootstrap step (everyone is uncolored, so the
    first rotated step never shrinks the worklist by construction) AND the
    first conflict wave (which retires only the conflict-component winners —
    a large worklist regardless of topology).  From the third step on, a
    near-unit shrink ratio is the signature of a cascading grid/circuit
    graph whose frontier the serial tail crosses in one pass.
    """
    return (iters >= 3) & (total * STALL_DEN >= STALL_NUM * prev)


def _class_cells(acc_widths, cells_k, tail_width: int, tail_cells: int):
    """Assemble ``ColoringResult.class_cells``: ``(width, cells)`` per class.

    Zero-cell classes are dropped (a class that never dispatched moved no
    bytes); a serial-tail pass contributes one final full-width entry.  The
    remaining entries always partition ``padded_work`` exactly — the
    invariant the roofline unit tests assert.
    """
    out = [(int(w), int(c)) for w, c in zip(acc_widths, cells_k) if c]
    if tail_cells:
        out.append((int(tail_width), int(tail_cells)))
    return tuple(out)


# --------------------------------------------------------------------------
# row providers (pytrees) + module-level jitted engine entry points
# --------------------------------------------------------------------------

class DenseRows:
    """Dense padded-adjacency row provider (the ``padded`` engine layout).

    ``rows``/``row1`` mirror the ``DeviceCSR`` provider protocol so the same
    engine drivers run over either storage; ``width`` requests are ignored —
    a dense table always gathers its full (Δmax) width, which is exactly the
    bandwidth difference the engines A/B.
    """

    def __init__(self, adj, sentinel: int | None = None):
        self.adj = adj
        self.sentinel = int(adj.shape[0]) if sentinel is None else int(sentinel)

    def rows(self, ids, width: int | None = None):
        return gather_rows(self.adj, ids, self.sentinel)

    def row1(self, v):
        n = self.adj.shape[0]
        r = self.adj[jnp.clip(v, 0, n - 1)]
        return jnp.where(v < n, r, self.sentinel)


jax.tree_util.register_pytree_node(
    DenseRows,
    lambda d: ((d.adj,), (d.sentinel,)),
    lambda aux, ch: DenseRows(*ch, *aux),
)


@partial(jax.jit, static_argnames=("kind",))
def provider_tail(provider, colors_ext, wl, *, kind="bitset"):
    """``serial_tail_step`` over a pytree row provider (cached compilation)."""
    return serial_tail_step(provider.row1, colors_ext, wl, kind)


def _dispatch_tail(provider, colors_ext, wl, *, kind, use_kernel, width):
    """Route the serial tail: on-device CSR kernel vs the fori_loop driver.

    ``use_kernel="csr"`` with a ``DeviceCSR`` provider runs the §18 grid=1
    sequential kernel (one dispatch, live aliased color state); every other
    configuration keeps the ``serial_tail_step`` fori_loop.  Both compute
    the same sequential greedy — every FirstFit ``kind`` returns the
    smallest free color, so the kernel is kind-agnostic and bit-identical.
    """
    if use_kernel == "csr" and isinstance(provider, DeviceCSR):
        from repro.kernels.superstep.csr_kernel import serial_tail_csr_tpu

        return serial_tail_csr_tpu(
            provider.row_starts, provider.col_padded, provider.deg_ext,
            colors_ext, wl, width)
    return provider_tail(provider, colors_ext, wl, kind=kind)


def _tiled_superstep(provider, deg_ext, colors_ext, wls, *, widths, heuristic,
                     kind, use_kernel, chunks, pack_degrees=False):
    """One degree-tiled super-step: every class sub-step in one computation.

    Classes gather at their own tile widths but all speculate against the
    iteration-start snapshot (writes are disjoint), so the result is
    bit-identical to a single full-width step over the union worklist.
    """
    snapshot = colors_ext
    K = len(wls)
    new_wls, counts = [], []
    for k in range(K):
        colors_ext, wl_k, cnt_k = ragged_superstep(
            lambda ids, w=widths[k]: provider.rows(ids, w),
            deg_ext, colors_ext, wls[k],
            heuristic=heuristic, kind=kind, use_kernel=use_kernel,
            coarsen=chunks[k],
            colors_read=None if K == 1 else snapshot,
            pack_degrees=pack_degrees,
            provider=provider, width=widths[k],
        )
        new_wls.append(wl_k)
        counts.append(cnt_k)
    return colors_ext, tuple(new_wls), tuple(counts)


provider_tiled_superstep = partial(
    jax.jit, static_argnames=("widths", "heuristic", "kind", "use_kernel",
                              "chunks", "pack_degrees")
)(_tiled_superstep)


# --------------------------------------------------------------------------
# the ragged engine driver (degree-tiled dispatch + adaptive tail)
# --------------------------------------------------------------------------

def run_ragged_engine(
    *,
    n: int,
    provider,
    deg_ext,
    classes: list,
    tile_widths: list,
    acc_widths: list,
    tail_width: int,
    mode: str = "workefficient",
    heuristic: str = "degree",
    kind: str = "bitset",
    use_kernel: bool = False,
    coarsen: int = 1,
    coarsen_lanes: int | None = None,
    tail_enabled: bool = True,
    tail_threshold: int = 0,
    max_iters: int,
    algorithm: str = "data_driven_sgr",
    pack_degrees: bool = False,
    colors_init=None,
    stall_serializes_all: bool = True,
    class_counts=None,
    trace=False,
) -> ColoringResult:
    """Drive the rotated super-step to convergence over degree-tiled classes.

    ``classes`` partitions the vertices (wide-first order); class ``k``'s
    worklist gathers ``provider.rows(ids, tile_widths[k])`` tiles, and
    ``padded_work`` charges ``lanes × acc_widths[k]`` gather cells.  When the
    total live count drops to ``tail_threshold`` — or the worklist *stalls*
    (a post-bootstrap step retires under 1/STALL_DEN of it, the signature of
    a cascading grid/circuit graph) — the remaining entries are handed to ONE
    ``serial_tail_step`` over the provider's full-width rows.  ``mode`` picks
    the host-loop (``workefficient``) or single-device-program (``fused``)
    realization of the *same* schedule — colors are bit-identical.

    ``colors_init`` warm-starts the engine (§14 incremental recoloring): a
    pre-colored ``(n + 1,)`` extended array whose non-worklist entries are
    FROZEN snapshot context — ``classes`` then need not partition all
    vertices, only the live frontier, and the work accounting charges that
    frontier (not n).  ``stall_serializes_all=False`` keeps the stall tail's
    scope to the live worklist (the cold default discards the speculation
    and re-greedies the whole graph, which would turn a frontier-sized
    recoloring into an O(n) one).  ``class_counts`` gives each class's TRUE
    live count when its worklist buffer carries trailing sentinel padding
    (callers pad to a power of two so jit cache keys repeat across calls);
    sentinel lanes are inert everywhere, so only the accounting and the
    tail/stall thresholds need the honest numbers.

    ``trace`` (§16) records one telemetry row per super-step into a bounded
    ring (``True`` = default capacity, an int = explicit capacity) and
    attaches the assembled ``repro.obs.RunTrace`` to the result.  The knob
    is static: ``trace=False`` dispatches the exact pre-§16 programs, so
    untraced runs stay bit-identical and pay nothing.
    """
    if pack_degrees and not _packed_gather_ok(tail_width):
        # §17 capacity guard: the packed color|deg<<16 word would overflow
        # int32 past deg 2^15 — silent color corruption, so refuse loudly
        from repro.errors import CapacityError
        from repro.ingest import PACKED_GATHER_MAX_DEG

        raise CapacityError(
            f"pack_degrees=True with tail_width={tail_width}: degrees must "
            f"stay < {PACKED_GATHER_MAX_DEG} to fit the packed gather word "
            "(color | deg << 16, int32); rerun with pack_degrees=False")
    caps0 = [int(c.shape[0]) for c in classes]
    counts_init = (caps0 if class_counts is None
                   else [int(c) for c in class_counts])
    boot_iters = 0
    if colors_init is not None:
        colors_ext = jnp.asarray(colors_init, dtype=jnp.int32)
    else:
        colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)
        # Bootstrap identity: with an unchunked worklist the first rotated
        # step FirstFits every vertex against an all-zero tile — everyone
        # takes color 1 and the worklist is unchanged.  Materialize that
        # constant instead of dispatching a full-width gather for it.  (Never
        # valid on a warm start: tiles read frozen colors, not zeros.)
        skip_bootstrap = coarsen <= 1 and (
            coarsen_lanes is None or coarsen_lanes >= max(caps0, default=1))
        if skip_bootstrap and max_iters >= 1:
            colors_ext = jnp.where(
                jnp.arange(n + 1, dtype=jnp.int32) < n, 1, 0
            ).astype(jnp.int32)
            boot_iters = 1

    trace_cap = resolve_trace_cap(trace, max_iters)
    trace_label = f"{algorithm}:{mode}"
    if mode == "fused":
        return _run_ragged_fused(
            n, provider, deg_ext, classes, tile_widths, acc_widths,
            tail_width, colors_ext, boot_iters, heuristic, kind, use_kernel,
            coarsen, coarsen_lanes, tail_enabled, tail_threshold, max_iters,
            algorithm, pack_degrees, counts_init, stall_serializes_all,
            trace_cap=trace_cap,
        )
    if mode != "workefficient":
        raise ValueError(f"unknown mode {mode!r}")

    K = len(classes)
    caps = caps0
    wls = [jnp.asarray(c) for c in classes]
    counts = list(counts_init)
    iters = boot_iters
    work = n if boot_iters else 0
    padded = 0
    cells_k = [0] * K  # per-class gather cells (partitions ``padded``)
    total = sum(counts)
    prev = total
    stalled = False
    rows = []  # (§16) one telemetry row per super-step when tracing
    if trace_cap and boot_iters:
        rows.append((n, 0, n, 1, 0, 0, 0, 0))
    with span("superstep_loop", mode=mode):
        while total > 0 and iters < max_iters:
            if tail_enabled and total <= tail_threshold:
                break
            if tail_enabled and _stalled(iters, total, prev):
                stalled = True
                break
            prev = total
            sliced, chunk_l = [], []
            step_cells = 0
            for k in range(K):
                cap = min(next_pow2(max(counts[k], 1)), caps[k])
                sliced.append(wls[k][:cap])
                chunk_l.append(max(1, math.ceil(cap / coarsen_lanes))
                               if coarsen_lanes else coarsen)
                work += counts[k]
                if counts[k]:
                    padded += cap * acc_widths[k]
                    cells_k[k] += cap * acc_widths[k]
                    step_cells += cap * acc_widths[k]
            shapes = tuple(int(s.shape[0]) for s in sliced)
            with jit_span("superstep", ("tiled", type(provider).__name__,
                                        shapes, tuple(tile_widths), heuristic,
                                        kind, use_kernel, tuple(chunk_l),
                                        pack_degrees, n)):
                colors_ext, new_wls, cnts = provider_tiled_superstep(
                    provider, deg_ext, colors_ext, tuple(sliced),
                    widths=tuple(tile_widths), heuristic=heuristic, kind=kind,
                    use_kernel=use_kernel, chunks=tuple(chunk_l),
                    pack_degrees=pack_degrees,
                )
            wls = list(new_wls)
            counts = [int(c) for c in cnts]
            iters += 1
            new_total = sum(counts)
            if trace_cap:
                rows.append((total, total - new_total, new_total,
                             int(jnp.max(colors_ext)), step_cells, 0, 0, 0))
            total = new_total
    converged = total == 0
    tail_cells = 0
    if total > 0 and iters < max_iters and tail_enabled:
        if stalled and stall_serializes_all:
            # speculation failed to make progress — discard it and run one
            # clean largest-degree-first sequential greedy over the graph
            tail_np = np.arange(n, dtype=np.int32)
        else:
            live = np.concatenate(
                [np.asarray(wls[k][:counts[k]]) for k in range(K) if counts[k]]
            )
            tail_np = np.full(min(next_pow2(total), n), n, np.int32)
            tail_np[:total] = live
        with span("serial_tail", live=total, stalled=stalled):
            tail_wl = order_tail(jnp.asarray(tail_np), deg_ext)
            colors_ext = _dispatch_tail(provider, colors_ext, tail_wl,
                                        kind=kind, use_kernel=use_kernel,
                                        width=tail_width)
        work += n if stalled and stall_serializes_all else total
        tail_cells = int(tail_wl.shape[0]) * tail_width
        padded += tail_cells
        iters += 1
        converged = True
        if trace_cap:
            # the tail drains the LIVE worklist (total entries); a
            # stall-serialization additionally re-greedies settled vertices,
            # which shows up in ``cells``/work, not in worklist membership
            rows.append((total, total, 0, int(jnp.max(colors_ext)),
                         tail_cells, 1, 0, 0))
    result = ColoringResult(
        np.asarray(colors_ext[:n]), iters, work, padded, converged,
        algorithm=algorithm,
        class_cells=_class_cells(acc_widths, cells_k, tail_width, tail_cells),
    )
    if trace_cap:
        result.trace = assemble_trace(rows, iters, trace_cap, trace_label)
    return result


@partial(jax.jit, static_argnames=("tile_widths", "heuristic", "kind",
                                   "use_kernel", "chunks", "tail_enabled",
                                   "max_iters", "boot_iters", "pack_degrees",
                                   "trace_cap", "cells_per_step"))
def _fused_spec_loop(provider, deg_ext, colors_ext, wls, counts, thr, *,
                     tile_widths, heuristic, kind, use_kernel, chunks,
                     tail_enabled, max_iters, boot_iters=0,
                     pack_degrees=False, prev0=None, trace_cap=0,
                     cells_per_step=0):
    """The speculative phase as one ``lax.while_loop`` device program.

    ``trace_cap > 0`` (§16, a STATIC knob) threads a pre-allocated
    ``(trace_cap, NF)`` int32 trace ring through the carry and records one
    row per super-step at ``step % trace_cap``; with the default 0 the
    carry and the compiled program are exactly the pre-§16 ones, so the
    untraced path stays bit-identical and pays nothing.
    """
    n = colors_ext.shape[0] - 1
    K = len(wls)

    def total_of(counts):
        return sum(counts, jnp.int32(0))

    def cond(state):
        counts, it, prev = state[2], state[3], state[5]
        total = total_of(counts)
        go = (total > 0) & (it < max_iters)
        if tail_enabled:
            go &= (total > thr) & ~_stalled(it, total, prev)
        return go

    def body(state):
        colors_ext, wls, counts, it, work = state[:5]
        prev = total_of(counts)
        colors_ext, new_wls, new_counts = _tiled_superstep(
            provider, deg_ext, colors_ext, wls,
            widths=tile_widths, heuristic=heuristic, kind=kind,
            use_kernel=use_kernel, chunks=chunks, pack_degrees=pack_degrees,
        )
        total = total_of(new_counts)
        out = (colors_ext, new_wls, new_counts, it + 1, work + total, prev)
        if trace_cap:
            z = jnp.int32(0)
            row = jnp.stack([prev, prev - total, total, jnp.max(colors_ext),
                             jnp.int32(cells_per_step), z, z, z])
            idx = lax.rem(it - boot_iters, jnp.int32(trace_cap))
            out = out + (state[6].at[idx].set(row),)
        return out

    state = (colors_ext, wls, counts, jnp.int32(boot_iters), jnp.int32(0),
             jnp.int32(n if prev0 is None else prev0))
    if trace_cap:
        state = state + (ring_init(trace_cap),)
    return lax.while_loop(cond, body, state)


def _run_ragged_fused(
    n, provider, deg_ext, classes, tile_widths, acc_widths, tail_width,
    colors_ext, boot_iters, heuristic, kind, use_kernel, coarsen,
    coarsen_lanes, tail_enabled, tail_threshold, max_iters, algorithm,
    pack_degrees=False, counts_init=None, stall_serializes_all=True,
    trace_cap=0,
):
    K = len(classes)
    caps = [int(c.shape[0]) for c in classes]
    # cold runs partition all n vertices with exact-length worklists, so
    # init_total == n there; warm starts (§14) pass the true live counts of
    # their sentinel-padded frontier buffers and charge those instead
    counts_init = caps if counts_init is None else counts_init
    init_total = sum(counts_init)
    chunks = [coarsen] * K
    if coarsen_lanes:
        chunks = [max(1, math.ceil(c / coarsen_lanes)) for c in caps]
    wls0 = tuple(jnp.asarray(c) for c in classes)
    counts0 = tuple(jnp.int32(c) for c in counts_init)
    cells_per_step = sum(c * w for c, w in zip(caps, acc_widths))
    loop_key = ("fused_spec", type(provider).__name__, tuple(caps),
                tuple(tile_widths), heuristic, kind, use_kernel,
                tuple(chunks), tail_enabled, max_iters, boot_iters,
                pack_degrees, n, trace_cap)
    with span("superstep_loop", mode="fused"), jit_span("fused_spec_loop",
                                                        loop_key):
        out = _fused_spec_loop(
            provider, deg_ext, colors_ext, wls0, counts0,
            jnp.int32(tail_threshold),
            tile_widths=tuple(tile_widths), heuristic=heuristic, kind=kind,
            use_kernel=use_kernel, chunks=tuple(chunks),
            tail_enabled=tail_enabled, max_iters=max_iters,
            boot_iters=boot_iters, pack_degrees=pack_degrees,
            prev0=None if init_total == n else jnp.int32(init_total),
            trace_cap=trace_cap, cells_per_step=cells_per_step,
        )
    colors_ext, wls, counts, it, work, prev = out[:6]
    total = int(sum(int(c) for c in counts))
    iters = int(it)
    work_items = int(work) + init_total
    spec_steps = iters - boot_iters
    cells_k = [spec_steps * c * w for c, w in zip(caps, acc_widths)]
    padded = sum(cells_k)
    converged = total == 0
    tail_cells = 0
    rows = []
    if trace_cap:
        if boot_iters:
            rows.append((n, 0, n, 1, 0, 0, 0, 0))
        rows.extend(tuple(int(v) for v in r)
                    for r in ring_rows(np.asarray(out[6]), spec_steps))
    if total > 0 and iters < max_iters and tail_enabled:
        stalled = total > tail_threshold and bool(
            _stalled(iters, total, int(prev)))
        with span("serial_tail", live=total, stalled=stalled):
            if stalled and stall_serializes_all:
                tail_wl = order_tail(jnp.arange(n, dtype=jnp.int32), deg_ext)
            else:
                combined = jnp.concatenate(list(wls)) if K > 1 else wls[0]
                tail_wl = order_tail(combined, deg_ext)
            colors_ext = _dispatch_tail(provider, colors_ext, tail_wl,
                                        kind=kind, use_kernel=use_kernel,
                                        width=tail_width)
        work_items += n if stalled and stall_serializes_all else total
        tail_cells = int(tail_wl.shape[0]) * tail_width
        padded += tail_cells
        iters += 1
        converged = True
        if trace_cap:
            rows.append((total, total, 0, int(jnp.max(colors_ext)),
                         tail_cells, 1, 0, 0))
    result = ColoringResult(
        np.asarray(colors_ext[:n]), iters, work_items, padded, converged,
        algorithm=algorithm,
        class_cells=_class_cells(acc_widths, cells_k, tail_width, tail_cells),
    )
    if trace_cap:
        result.trace = assemble_trace(rows, iters, trace_cap,
                                      f"{algorithm}:fused")
    return result


# --------------------------------------------------------------------------
# generic drivers for the classic step (shared with topo.py / repro.d2)
# --------------------------------------------------------------------------
# The two driver loops are generic over the super-step: ``step(colors_ext,
# wl) -> (colors_ext, wl, count)``.  The classic engine instantiates them
# with ``sgr_step``; legacy distance-2 callers reuse them with the two-hop
# super-step instead of copying the scaffolding.

def run_fused_loop(step, colors_ext, wl0, count0, max_iters: int,
                   trace_cap: int = 0, cells_per_step: int = 0):
    """The whole coloring as ONE jitted ``lax.while_loop`` device program.

    Returns ``(colors_ext, wl, count, iters, work)`` where ``work`` is the
    sum of post-step live counts (the first full-capacity step is charged by
    the caller, matching the paper's work accounting).  With ``trace_cap >
    0`` (§16) a ``(trace_cap, NF)`` trace ring rides the carry — one row per
    step at ``step % trace_cap`` — and is returned as a sixth element; the
    default 0 compiles the pre-§16 five-element program unchanged.
    """

    @partial(jax.jit, static_argnames=())
    def run(colors_ext, wl, count):
        def cond(state):
            count, it = state[2], state[3]
            return (count > 0) & (it < max_iters)

        def body(state):
            colors_ext, wl, count, it, work = state[:5]
            prev = count
            colors_ext, wl, count = step(colors_ext, wl)
            out = (colors_ext, wl, count, it + 1, work + count)
            if trace_cap:
                z = jnp.int32(0)
                row = jnp.stack([prev, prev - count, count,
                                 jnp.max(colors_ext),
                                 jnp.int32(cells_per_step), z, z, z])
                idx = lax.rem(it, jnp.int32(trace_cap))
                out = out + (state[5].at[idx].set(row),)
            return out

        state = (colors_ext, wl, count, jnp.int32(0), jnp.int32(0))
        if trace_cap:
            state = state + (ring_init(trace_cap),)
        return lax.while_loop(cond, body, state)

    return run(colors_ext, wl0, jnp.int32(count0))


def fused_result(colors_ext, n: int, count, it, work, width: int = 1,
                 algorithm: str = "data_driven_sgr") -> ColoringResult:
    """Shared result assembly for fused drivers (paper work accounting).

    Every super-step dispatches full capacity, so ``padded_work`` is
    ``iters * n * width`` gather cells and the first step's n live items are
    charged on top of the post-step counts accumulated in ``work``.
    """
    iters = int(it)
    return ColoringResult(
        np.asarray(colors_ext[:n]),
        iters,
        int(work) + n,
        iters * n * width,
        converged=int(count) == 0,
        algorithm=algorithm,
    )


def run_workefficient_loop(step, colors_ext, wl0, count0: int, max_iters: int):
    """Host loop re-slicing the worklist to the next pow2 of the live count.

    Single-class variant of the paper's work-efficiency argument (the
    class-tiled loop lives in ``run_ragged_engine``).  Returns
    ``(colors_ext, iters, work, padded, converged)``; ``padded`` counts
    dispatched lanes (multiply by the tile width for gather cells).
    """
    wl, count = wl0, int(count0)
    iters = work = padded = 0
    while count > 0 and iters < max_iters:
        cap = min(next_pow2(count), wl.shape[0])
        colors_ext, wl, cnt = step(colors_ext, wl[:cap])
        work += count
        padded += cap
        count = int(cnt)
        iters += 1
    return colors_ext, iters, work, padded, count == 0


def _prepare(g: CSRGraph, buckets):
    """Device arrays + per-bucket (ids, sliced adjacency) covering each class."""
    adj_np = g.padded_adjacency()
    deg_ext = jnp.asarray(
        np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    )
    if buckets:
        classes = g.degree_buckets(buckets)
        widths = []
        bounds = list(buckets) + [max(g.max_degree, 1)]
        for hi in bounds:
            widths.append(min(max(hi, 1), adj_np.shape[1]))
        # process large-degree classes first (aligns with the degree heuristic)
        order = np.argsort([-w for w in widths], kind="stable")
        classes = [classes[i] for i in order]
        widths = [widths[i] for i in order]
    else:
        classes = [np.arange(g.n, dtype=np.int32)]
        widths = [adj_np.shape[1]]
    adjs = [jnp.asarray(adj_np[:, :w]) for w in widths]
    return adjs, deg_ext, classes


def _resolve_classes(degrees: np.ndarray, buckets, tiling):
    """(classes, widths) for the degree-tiled dispatch, wide-first order.

    Explicit ``buckets`` win; otherwise ``tiling`` is ``"auto"`` (log-spaced
    thresholds from the degree histogram), an explicit threshold tuple, or
    ``None``/``()`` for a single full-width class.  Takes the raw degree
    histogram of the GATHERED side (the original graph's, G²'s, or a
    conflict graph's — shared with ``repro.d2``); degree-0 vertices join the
    narrowest class, empty classes are dropped.
    """
    degrees = np.asarray(degrees)
    n = int(degrees.size)
    dmax = max(int(degrees.max(initial=0)), 1)
    if buckets:
        thresholds = tuple(buckets)
    elif tiling == "auto":
        thresholds = auto_tile_thresholds(degrees)
    elif not tiling:
        thresholds = ()
    else:
        thresholds = tuple(tiling)
    if not thresholds:
        return [np.arange(n, dtype=np.int32)], [dmax]
    bounds = list(thresholds) + [dmax]
    widths = [min(max(b, 1), dmax) for b in bounds]
    classes, lo = [], 0
    for hi in bounds:
        classes.append(
            np.where((degrees > lo) & (degrees <= hi))[0].astype(np.int32))
        lo = hi
    zero = np.where(degrees == 0)[0].astype(np.int32)
    if zero.size:  # degree-0 vertices take color 1 trivially: narrowest class
        classes[0] = np.concatenate([zero, classes[0]])
    order = np.argsort([-w for w in widths], kind="stable")
    pairs = [(classes[i], widths[i]) for i in order if classes[i].size]
    if not pairs:
        return [np.arange(n, dtype=np.int32)], [dmax]
    return [p[0] for p in pairs], [p[1] for p in pairs]


def _graph_device_cache(g, key: str, build):
    """Memoize device-side views on the (frozen) host graph object.

    CSRGraph is immutable, so its device transfers (CSR arrays, dense
    adjacency, extended degrees) are pure functions of the object — cache
    them on the instance so repeated ``color()`` calls skip the host→device
    uploads.  ``object.__setattr__`` bypasses the frozen-dataclass guard.
    """
    cache = getattr(g, "_device_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(g, "_device_cache", cache)
    if key not in cache:
        cache[key] = build()
    return cache[key]


@register("data_driven")
def color_data_driven(
    g: CSRGraph,
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    coarsen_lanes: int | None = None,
    buckets: tuple[int, ...] = (),
    mode: str = "workefficient",
    max_iters: int | None = None,
    reuse_rows: bool = False,
    engine: str = "ragged",
    tiling="auto",
    tail_serial="auto",
    devices=None,
    backend: str | None = None,
    trace=False,
) -> ColoringResult:
    """Color ``g`` with the paper's optimized data-driven SGR algorithm.

    ``backend`` picks the super-step implementation (DESIGN.md §15):
    ``"pallas"`` routes every degree-class tile through the fused Pallas
    kernel (``kernels/superstep``; ``interpret=True`` off-TPU), ``"jax"``
    forces the pure-JAX formulation, ``"auto"`` picks pallas on TPU only,
    and ``None`` defers to the legacy ``use_kernel`` knob.  Colors are
    bit-identical across backends (tested in ``tests/test_differential.py``);
    the multi-device sharded engine always runs pure-JAX (automatic
    fallback — its ``shard_map`` body cannot host the kernel).

    ``engine`` picks the execution engine (see the module docstring):
    ``ragged`` (CSR-native rotated super-step, the default), ``padded``
    (same schedule over the dense padded-adjacency table — bit-identical
    colors), ``classic`` (the two-phase baseline), or ``sharded`` (the §13
    multi-device engine over ``devices`` — defaults to every available
    device, falls back to ``ragged`` when only one is present; colors are
    bit-identical either way, and ``mode`` is pinned to the fused
    schedule/accounting so results never depend on the device count).
    ``tiling`` controls the degree-tiled
    dispatch (``"auto"``, explicit thresholds, or ``None``) and
    ``tail_serial`` the adaptive tail-serialization (``"auto"``, an
    explicit live-count threshold, or ``None`` to disable).

    ``coarsen_lanes`` models the paper's thread-coarsening launch config
    (nSM x max_blocks x 128 threads): the speculative phase is chunked so at
    most ``coarsen_lanes`` vertices speculate concurrently; later chunks
    observe earlier chunks' colors, exactly like CUDA blocks scheduled in
    waves.  Overrides ``coarsen_ff`` when set.

    ``trace`` (§16) records per-super-step telemetry and host phase spans
    into ``result.trace`` (a ``repro.obs.RunTrace``).  Static knob: the
    default ``False`` dispatches the identical device programs, so untraced
    results stay bit-identical and free of overhead.
    """
    from repro.kernels.dispatch import kernel_mode, resolve_backend

    n = g.n
    if n == 0:
        resolve_backend(backend, use_kernel)  # validate even on the no-op
        result = ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True)
        if trace:
            result.trace = empty_trace("data_driven_sgr")
        return result
    max_iters = max_iters or n + 1

    def run(engine=engine, mode=mode, use_kernel=use_kernel):
        if engine == "classic":
            # the classic engine's two-phase kernels take dense tiles only;
            # pallas-csr degrades to the gathered kernel (bit-identical)
            use_kernel = resolve_backend(backend, use_kernel) in (
                "pallas", "pallas-csr")
            return _color_classic(
                g, heuristic, firstfit, use_kernel, coarsen_ff, coarsen_cr,
                coarsen_lanes, buckets, mode, max_iters, reuse_rows,
                trace_cap=resolve_trace_cap(trace, max_iters),
            )
        if engine == "sharded":
            # validate BEFORE the one-device fallback so the accepted option
            # surface never depends on how many devices happen to be present
            if use_kernel:
                raise ValueError(
                    "engine='sharded' does not support use_kernel=True")
            if coarsen_ff != 1 or coarsen_cr != 1 or coarsen_lanes:
                raise ValueError(
                    "engine='sharded' runs the uncoarsened (coarsen=1) "
                    "schedule; coarsen_ff/coarsen_cr/coarsen_lanes are not "
                    "supported")
            devs = list(devices) if devices is not None else jax.devices()
            if len(devs) > 1:
                # §15 fallback: the shard_map body stays pure-JAX; a pallas
                # request degrades to wall-clock only (colors bit-identical)
                resolve_backend(backend)
                from repro.core.distributed import color_distributed

                return color_distributed(
                    g, devices=devs, heuristic=heuristic, firstfit=firstfit,
                    buckets=buckets, tiling=tiling, tail_serial=tail_serial,
                    max_iters=max_iters, trace=trace,
                )
            # one device: the sharded schedule IS the ragged fused one — pin
            # mode so colors AND accounting are device-count-independent
            engine, mode = "ragged", "fused"
        use_kernel = kernel_mode(resolve_backend(backend, use_kernel))
        if engine not in ("ragged", "padded"):
            raise ValueError(
                f"unknown engine {engine!r}; options: ragged, padded, "
                f"classic, sharded"
            )

        with span("partition_plan"):
            classes, widths = _resolve_classes(g.degrees, buckets, tiling)
        dmax = max(g.max_degree, 1)
        with span("csr_build", engine=engine):
            deg_ext = _graph_device_cache(g, "deg_ext", lambda: jnp.asarray(
                np.concatenate(
                    [g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
            ))
            if engine == "ragged":
                provider = _graph_device_cache(
                    g, "dcsr", lambda: DeviceCSR.from_csr(g))
                tile_widths = widths
                acc_widths = widths
            else:
                provider = _graph_device_cache(g, "dense", lambda: DenseRows(
                    jnp.asarray(g.padded_adjacency())))
                tile_widths = [None] * len(widths)
                acc_widths = [dmax] * len(widths)
        tail_enabled, thr = resolve_tail_threshold(tail_serial, n)
        return run_ragged_engine(
            n=n,
            provider=provider,
            deg_ext=deg_ext,
            classes=classes,
            tile_widths=tile_widths,
            acc_widths=acc_widths,
            tail_width=dmax,
            mode=mode,
            heuristic=heuristic,
            kind=firstfit,
            use_kernel=use_kernel,
            coarsen=max(int(coarsen_ff), int(coarsen_cr)),
            coarsen_lanes=coarsen_lanes,
            tail_enabled=tail_enabled,
            tail_threshold=thr,
            max_iters=max_iters,
            pack_degrees=_packed_gather_ok(dmax),
            trace=trace,
        )

    if not trace:
        return run()
    # trace=True opens its own span recorder so result.trace.spans carries
    # the phase breakdown even without a user recorder; an outer recorder
    # (repro.obs.recorder()) still observes every span — recorders nest
    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result


def _color_classic(
    g, heuristic, firstfit, use_kernel, coarsen_ff, coarsen_cr,
    coarsen_lanes, buckets, mode, max_iters, reuse_rows, trace_cap=0,
):
    """The pre-§12 two-phase engine (FirstFit kernel + ConflictResolve kernel)."""
    n = g.n
    adjs, deg_ext, classes = _prepare(g, buckets)
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)

    if mode == "fused":
        assert not buckets, "classic fused mode runs single-class (full-width) only"
        return _run_fused(
            g, adjs[0], deg_ext, colors_ext, heuristic, firstfit, coarsen_ff,
            coarsen_cr, use_kernel, max_iters, reuse_rows, trace_cap,
        )
    if mode != "workefficient":
        raise ValueError(f"unknown mode {mode!r}")

    widths = [int(a.shape[1]) for a in adjs]
    # per-class worklists (class membership is static: degrees never change)
    wls = [jnp.asarray(ids) for ids in classes]
    counts = [int(ids.shape[0]) for ids in classes]
    iters = work = padded = 0
    rows = []
    with span("superstep_loop", mode=mode):
        while sum(counts) > 0 and iters < max_iters:
            live_in = sum(counts)
            step_cells = 0
            new_wls, new_counts = [], []
            for k, (wl, count, adj_k) in enumerate(zip(wls, counts, adjs)):
                if count == 0:
                    new_wls.append(wl[:1])
                    new_counts.append(0)
                    continue
                cap = min(next_pow2(count), wl.shape[0])
                if coarsen_lanes:
                    coarsen_ff = max(1, math.ceil(cap / coarsen_lanes))
                colors_ext, wl_out, cnt = sgr_step(
                    adj_k,
                    deg_ext,
                    colors_ext,
                    wl[:cap],
                    heuristic=heuristic,
                    kind=firstfit,
                    coarsen_ff=coarsen_ff,
                    coarsen_cr=coarsen_cr,
                    use_kernel=use_kernel,
                    reuse_rows=reuse_rows,
                )
                work += count
                padded += cap * widths[k]
                step_cells += cap * widths[k]
                new_wls.append(wl_out)
                new_counts.append(int(cnt))
            wls, counts = new_wls, new_counts
            iters += 1
            if trace_cap:
                new_total = sum(counts)
                rows.append((live_in, live_in - new_total, new_total,
                             int(jnp.max(colors_ext)), step_cells, 0, 0, 0))

    colors = np.asarray(colors_ext[:n])
    result = ColoringResult(colors, iters, work, padded,
                            converged=sum(counts) == 0)
    if trace_cap:
        result.trace = assemble_trace(rows, iters, trace_cap,
                                      "classic:workefficient")
    return result


@register("fused")
def color_fused(g: CSRGraph, **opts) -> ColoringResult:
    """``data_driven`` with the whole coloring as one device program."""
    opts.pop("mode", None)
    return color_data_driven(g, mode="fused", **opts)


def _run_fused(
    g, adj, deg_ext, colors_ext, heuristic, kind, coarsen_ff, coarsen_cr,
    use_kernel, max_iters, reuse_rows=False, trace_cap=0,
):
    n = g.n
    step = partial(
        sgr_step,
        adj,
        deg_ext,
        heuristic=heuristic,
        kind=kind,
        coarsen_ff=coarsen_ff,
        coarsen_cr=coarsen_cr,
        use_kernel=use_kernel,
        reuse_rows=reuse_rows,
    )
    wl0 = jnp.arange(n, dtype=jnp.int32)
    width = int(adj.shape[1])
    with span("superstep_loop", mode="fused"):
        out = run_fused_loop(
            step, colors_ext, wl0, n, max_iters,
            trace_cap=trace_cap, cells_per_step=n * width,
        )
    colors_ext, _, count, it, work = out[:5]
    result = fused_result(colors_ext, n, count, it, work, width=width)
    if trace_cap:
        rows = ring_rows(np.asarray(out[5]), int(it))
        result.trace = assemble_trace(rows, int(it), trace_cap,
                                      "classic:fused")
    return result
