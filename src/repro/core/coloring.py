"""Data-driven speculative-greedy GPU coloring (paper Alg. 7) in JAX.

The paper's contribution, adapted to the TPU/XLA execution model (DESIGN.md §3):

* worklist double-buffering          -> functional carry swap
* atomic push -> CUB prefix sum      -> ``jnp.cumsum`` compaction (identical math)
* color clearing on conflict          -> kept verbatim (correctness-critical here too)
* kernel fusion + global barrier      -> each super-step is ONE jitted XLA
                                         computation; the loop carry is the barrier
* thread coarsening                   -> ``coarsen_ff`` / ``coarsen_cr`` sequential
                                         chunks per super-step (fewer concurrent
                                         speculations -> fewer conflicts)
* Merrill load balancing              -> degree buckets, each processed at its own
                                         padded width (``buckets=(16, 128)``)

Two execution modes:

* ``workefficient`` (default) — host loop; the worklist buffer is re-sliced to
  the next power of two of the live count each super-step, so compute tracks
  the worklist size (the paper's work-efficiency argument) at the cost of at
  most log2(n) compilation cache entries.
* ``fused`` — a single ``lax.while_loop`` over full-capacity buffers: the whole
  coloring is one device program (what you deploy on TPU where lanes are wide
  and re-dispatch is expensive).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.api import register
from repro.core.csr import CSRGraph, next_pow2
from repro.core.firstfit import FF_FUNCS
from repro.core.heuristics import conflict_lose_flags

__all__ = [
    "ColoringResult",
    "color_data_driven",
    "color_fused",
    "fused_result",
    "run_fused_loop",
    "run_workefficient_loop",
]


@dataclasses.dataclass
class ColoringResult:
    colors: np.ndarray
    iterations: int
    work_items: int          # worklist entries actually live across super-steps
    padded_work: int         # lanes dispatched (>= work_items; capacity waste)
    converged: bool
    algorithm: str = "data_driven_sgr"

    @property
    def num_colors(self) -> int:
        return int(self.colors.max(initial=0))


# --------------------------------------------------------------------------
# phase helpers (shared with topo.py / threestep.py / distributed.py)
# --------------------------------------------------------------------------

def gather_rows(adj: jax.Array, ids: jax.Array, sentinel: int | None = None) -> jax.Array:
    """Gather padded adjacency rows; sentinel ids yield all-sentinel rows.

    ``sentinel`` is the fill value for masked rows and defaults to the row
    count (square adjacency).  Rectangular compositions — the bipartite
    cols→rows hop, whose *output* ids live on the other side (repro.d2) —
    pass the target side's sentinel explicitly.
    """
    n = adj.shape[0]
    fill = n if sentinel is None else sentinel
    rows = adj[jnp.clip(ids, 0, n - 1)]
    return jnp.where((ids < n)[:, None], rows, fill)


def ff_apply(adj, colors_ext, ids, kind: str, use_kernel: bool = False,
             rows=None):
    """FirstFit the worklist chunk ``ids`` and write colors (sentinel-safe)."""
    n = adj.shape[0]
    rows = gather_rows(adj, ids) if rows is None else rows
    nc = colors_ext[rows]
    if use_kernel:
        from repro.kernels.firstfit.ops import firstfit_bitset_tpu

        c = firstfit_bitset_tpu(nc)
    else:
        c = FF_FUNCS[kind](nc)
    c = jnp.where(ids < n, c, 0).astype(colors_ext.dtype)
    return colors_ext.at[ids].set(c)


def cr_flags(adj, deg_ext, colors_ext, ids, heuristic: str,
             use_kernel: bool = False, rows=None):
    """Conflict flags for the worklist chunk ``ids`` (True = loses, recolor)."""
    rows = gather_rows(adj, ids) if rows is None else rows
    my_c = colors_ext[ids]
    nc = colors_ext[rows]
    my_d = deg_ext[ids]
    nd = deg_ext[rows]
    if use_kernel:
        from repro.kernels.conflict.ops import conflict_tpu

        return conflict_tpu(ids, rows, my_c, nc, my_d, nd, heuristic)
    return conflict_lose_flags(ids, rows, my_c, nc, my_d, nd, heuristic)


def compact(ids: jax.Array, flags: jax.Array, sentinel: int):
    """Prefix-sum worklist compaction (the paper's CUB scan, §3.1)."""
    cap = ids.shape[0]
    pos = jnp.cumsum(flags.astype(jnp.int32)) - 1
    out = jnp.full((cap,), sentinel, dtype=ids.dtype)
    out = out.at[jnp.where(flags, pos, cap)].set(ids, mode="drop")
    return out, jnp.sum(flags.astype(jnp.int32))


def _chunk_bounds(cap: int, nchunks: int):
    nchunks = max(1, min(nchunks, cap))
    size = math.ceil(cap / nchunks)
    return [(i * size, min((i + 1) * size, cap)) for i in range(nchunks)
            if i * size < cap]


# --------------------------------------------------------------------------
# one super-step: FirstFit -> ConflictResolve(+clear) -> compaction
# --------------------------------------------------------------------------

@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "coarsen_ff", "coarsen_cr",
                     "use_kernel", "reuse_rows"),
)
def sgr_step(
    adj,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    use_kernel: bool = False,
    reuse_rows: bool = False,
):
    n = adj.shape[0]
    cap = wl.shape[0]

    # §Perf iteration: FirstFit and ConflictResolve gather the same adjacency
    # rows; with aligned (un)chunking the gather can be done once per step.
    rows_all = gather_rows(adj, wl) if (
        reuse_rows and coarsen_ff == 1 and coarsen_cr == 1) else None

    # ---- FirstFit phase (coarsened: later chunks see earlier chunk colors) --
    for lo, hi in _chunk_bounds(cap, coarsen_ff):
        colors_ext = ff_apply(adj, colors_ext, wl[lo:hi], kind, use_kernel,
                              rows=rows_all)

    # ---- ConflictResolve + color clearing (paper §3.1) ----------------------
    lose_parts = []
    for lo, hi in _chunk_bounds(cap, coarsen_cr):
        ids = wl[lo:hi]
        lose = cr_flags(adj, deg_ext, colors_ext, ids, heuristic, use_kernel,
                        rows=rows_all)
        colors_ext = colors_ext.at[ids].set(
            jnp.where(lose, 0, colors_ext[ids])
        )
        lose_parts.append(lose)
    lose = jnp.concatenate(lose_parts) if len(lose_parts) > 1 else lose_parts[0]

    # ---- worklist compaction (double buffering = functional swap) -----------
    new_wl, new_count = compact(wl, lose, sentinel=n)
    return colors_ext, new_wl, new_count


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------
# The two driver loops are generic over the super-step: ``step(colors_ext,
# wl) -> (colors_ext, wl, count)``.  ``color_data_driven`` instantiates them
# with ``sgr_step``; the distance-2 engine (repro.d2) reuses them with its
# two-hop super-step instead of copying the scaffolding.

def run_fused_loop(step, colors_ext, wl0, count0, max_iters: int):
    """The whole coloring as ONE jitted ``lax.while_loop`` device program.

    Returns ``(colors_ext, wl, count, iters, work)`` where ``work`` is the
    sum of post-step live counts (the first full-capacity step is charged by
    the caller, matching the paper's work accounting).
    """

    @partial(jax.jit, static_argnames=())
    def run(colors_ext, wl, count):
        def cond(state):
            _, _, count, it, _ = state
            return (count > 0) & (it < max_iters)

        def body(state):
            colors_ext, wl, count, it, work = state
            colors_ext, wl, count = step(colors_ext, wl)
            return colors_ext, wl, count, it + 1, work + count

        state = (colors_ext, wl, count, jnp.int32(0), jnp.int32(0))
        return lax.while_loop(cond, body, state)

    return run(colors_ext, wl0, jnp.int32(count0))


def fused_result(colors_ext, n: int, count, it, work,
                 algorithm: str = "data_driven_sgr") -> ColoringResult:
    """Shared result assembly for fused drivers (paper work accounting).

    Every super-step dispatches full capacity, so ``padded_work`` is
    ``iters * n`` and the first step's n live items are charged on top of
    the post-step counts accumulated in ``work``.
    """
    iters = int(it)
    return ColoringResult(
        np.asarray(colors_ext[:n]),
        iters,
        int(work) + n,
        iters * n,
        converged=int(count) == 0,
        algorithm=algorithm,
    )


def run_workefficient_loop(step, colors_ext, wl0, count0: int, max_iters: int):
    """Host loop re-slicing the worklist to the next pow2 of the live count.

    Single-class variant of the paper's work-efficiency argument (the
    bucketed multi-class loop lives in ``color_data_driven``).  Returns
    ``(colors_ext, iters, work, padded, converged)``.
    """
    wl, count = wl0, int(count0)
    iters = work = padded = 0
    while count > 0 and iters < max_iters:
        cap = min(next_pow2(count), wl.shape[0])
        colors_ext, wl, cnt = step(colors_ext, wl[:cap])
        work += count
        padded += cap
        count = int(cnt)
        iters += 1
    return colors_ext, iters, work, padded, count == 0


def _prepare(g: CSRGraph, buckets):
    """Device arrays + per-bucket (ids, sliced adjacency) covering each class."""
    adj_np = g.padded_adjacency()
    deg_ext = jnp.asarray(
        np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    )
    if buckets:
        classes = g.degree_buckets(buckets)
        widths = []
        bounds = list(buckets) + [max(g.max_degree, 1)]
        for hi in bounds:
            widths.append(min(max(hi, 1), adj_np.shape[1]))
        # process large-degree classes first (aligns with the degree heuristic)
        order = np.argsort([-w for w in widths], kind="stable")
        classes = [classes[i] for i in order]
        widths = [widths[i] for i in order]
    else:
        classes = [np.arange(g.n, dtype=np.int32)]
        widths = [adj_np.shape[1]]
    adjs = [jnp.asarray(adj_np[:, :w]) for w in widths]
    return adjs, deg_ext, classes


@register("data_driven")
def color_data_driven(
    g: CSRGraph,
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    coarsen_ff: int = 1,
    coarsen_cr: int = 1,
    coarsen_lanes: int | None = None,
    buckets: tuple[int, ...] = (),
    mode: str = "workefficient",
    max_iters: int | None = None,
    reuse_rows: bool = False,
) -> ColoringResult:
    """Color ``g`` with the paper's optimized data-driven SGR algorithm.

    ``coarsen_lanes`` models the paper's thread-coarsening launch config
    (nSM x max_blocks x 128 threads): the FirstFit phase is chunked so at most
    ``coarsen_lanes`` vertices speculate concurrently; later chunks observe
    earlier chunks' colors, exactly like CUDA blocks scheduled in waves.
    Overrides ``coarsen_ff`` when set.
    """
    n = g.n
    if n == 0:
        return ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True)
    max_iters = max_iters or n + 1
    adjs, deg_ext, classes = _prepare(g, buckets)
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)

    if mode == "fused":
        assert not buckets, "fused mode runs single-class (full-width) only"
        return _run_fused(
            g, adjs[0], deg_ext, colors_ext, heuristic, firstfit, coarsen_ff,
            coarsen_cr, use_kernel, max_iters,
        )
    if mode != "workefficient":
        raise ValueError(f"unknown mode {mode!r}")

    # per-class worklists (class membership is static: degrees never change)
    wls = [jnp.asarray(ids) for ids in classes]
    counts = [int(ids.shape[0]) for ids in classes]
    iters = work = padded = 0
    while sum(counts) > 0 and iters < max_iters:
        new_wls, new_counts = [], []
        for k, (wl, count, adj_k) in enumerate(zip(wls, counts, adjs)):
            if count == 0:
                new_wls.append(wl[:1])
                new_counts.append(0)
                continue
            cap = min(next_pow2(count), wl.shape[0])
            if coarsen_lanes:
                coarsen_ff = max(1, math.ceil(cap / coarsen_lanes))
            colors_ext, wl_out, cnt = sgr_step(
                adj_k,
                deg_ext,
                colors_ext,
                wl[:cap],
                heuristic=heuristic,
                kind=firstfit,
                coarsen_ff=coarsen_ff,
                coarsen_cr=coarsen_cr,
                use_kernel=use_kernel,
                reuse_rows=reuse_rows,
            )
            work += count
            padded += cap
            new_wls.append(wl_out)
            new_counts.append(int(cnt))
        wls, counts = new_wls, new_counts
        iters += 1

    colors = np.asarray(colors_ext[:n])
    return ColoringResult(colors, iters, work, padded, converged=sum(counts) == 0)


@register("fused")
def color_fused(g: CSRGraph, **opts) -> ColoringResult:
    """``data_driven`` with the whole coloring as one device program."""
    opts.pop("mode", None)
    return color_data_driven(g, mode="fused", **opts)


def _run_fused(
    g, adj, deg_ext, colors_ext, heuristic, kind, coarsen_ff, coarsen_cr,
    use_kernel, max_iters,
):
    n = g.n
    step = partial(
        sgr_step,
        adj,
        deg_ext,
        heuristic=heuristic,
        kind=kind,
        coarsen_ff=coarsen_ff,
        coarsen_cr=coarsen_cr,
        use_kernel=use_kernel,
    )
    wl0 = jnp.arange(n, dtype=jnp.int32)
    colors_ext, _, count, it, work = run_fused_loop(
        step, colors_ext, wl0, n, max_iters
    )
    return fused_result(colors_ext, n, count, it, work)
