"""Topology-driven SGR coloring (paper Alg. 6) — the work-INEFFICIENT mapping.

Every super-step dispatches lanes for *all* n vertices; lanes whose vertex is
already colored do no useful work (masked out), exactly modeling the idle
CUDA threads of the topology-driven mapping.  A ``colored`` bitmask avoids
re-resolving finalized vertices (Alg. 6 l.11).  Used as the Fig. 3 baseline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import register
from repro.core.coloring import ColoringResult, cr_flags
from repro.core.csr import CSRGraph
from repro.core.firstfit import FF_FUNCS

__all__ = ["color_topology"]


@partial(jax.jit, static_argnames=("heuristic", "kind"))
def _topo_step(adj, deg_ext, colors_ext, colored, *, heuristic, kind):
    n = adj.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    uncolored = colors_ext[:n] == 0

    # FirstFit for every vertex (idle lanes compute but do not write)
    nc = colors_ext[adj]
    c = FF_FUNCS[kind](nc)
    colors_ext = colors_ext.at[:n].set(jnp.where(uncolored, c, colors_ext[:n]))

    # ConflictResolve for every not-yet-finalized vertex + color clearing
    lose = cr_flags(adj, deg_ext, colors_ext, ids, heuristic) & ~colored
    colors_ext = colors_ext.at[:n].set(jnp.where(lose, 0, colors_ext[:n]))
    colored = ~lose & (colors_ext[:n] > 0)
    return colors_ext, colored, jnp.sum(~colored)


@register("topology")
def color_topology(
    g: CSRGraph,
    *,
    heuristic: str = "id",
    firstfit: str = "bitset",
    max_iters: int | None = None,
) -> ColoringResult:
    n = g.n
    if n == 0:
        return ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True, "topology_sgr")
    max_iters = max_iters or n + 1
    adj = jnp.asarray(g.padded_adjacency())
    deg_ext = jnp.asarray(
        np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    )
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)
    colored = jnp.zeros((n,), dtype=bool)
    iters = 0
    remaining = n
    while remaining > 0 and iters < max_iters:
        colors_ext, colored, rem = _topo_step(
            adj, deg_ext, colors_ext, colored, heuristic=heuristic, kind=firstfit
        )
        remaining = int(rem)
        iters += 1
    return ColoringResult(
        np.asarray(colors_ext[:n]),
        iters,
        work_items=iters * n,   # topology-driven: all lanes, every step
        padded_work=iters * n,
        converged=remaining == 0,
        algorithm="topology_sgr",
    )
