"""Conflict-resolve policies (paper Alg. 5 and §3.2 heuristic).

Given a speculative coloring, a conflict is an edge whose endpoints share a
color; exactly one endpoint must "lose" (be cleared and re-queued).  The loser
rule is the paper's key quality/convergence lever:

* ``id``     — baseline (Alg. 2 l.14 / Alg. 5 l.3): the *smaller id* loses.
* ``degree`` — §3.2 heuristic: the *smaller degree* loses (large-degree
               vertices are more likely to cause future conflicts, so they
               keep their color); ties → the smaller id keeps (larger loses).

Both rules are total orders over vertices, so every conflicting pair has
exactly one loser and the maximum-priority vertex of any conflict component
never loses — guaranteeing progress each iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conflict_lose_flags", "HEURISTICS"]

HEURISTICS = ("id", "degree")


def conflict_lose_flags(
    ids: jax.Array,          # (w,)   worklist vertex ids (sentinel n allowed)
    neigh_ids: jax.Array,    # (w, W) padded neighbor ids (sentinel n in pads)
    my_colors: jax.Array,    # (w,)   colors of ids (0 for sentinel)
    neigh_colors: jax.Array, # (w, W) colors of neighbors (0 in pads)
    my_deg: jax.Array,       # (w,)
    neigh_deg: jax.Array,    # (w, W)
    heuristic: str,
) -> jax.Array:
    """True where the worklist vertex loses a conflict and must recolor."""
    same = (neigh_colors == my_colors[:, None]) & (my_colors[:, None] > 0)
    if heuristic == "id":
        lose_lane = same & (ids[:, None] < neigh_ids)
    elif heuristic == "degree":
        dv = my_deg[:, None]
        lose_lane = same & (
            (neigh_deg > dv) | ((neigh_deg == dv) & (neigh_ids < ids[:, None]))
        )
    else:
        raise ValueError(f"unknown heuristic {heuristic!r}; options: {HEURISTICS}")
    return jnp.any(lose_lane, axis=1)
