"""Conflict-resolve policies (paper Alg. 5 and §3.2 heuristic).

Given a speculative coloring, a conflict is an edge whose endpoints share a
color; exactly one endpoint must "lose" (be cleared and re-queued).  The loser
rule is the paper's key quality/convergence lever:

* ``id``     — baseline (Alg. 2 l.14 / Alg. 5 l.3): the *smaller id* loses.
* ``degree`` — §3.2 heuristic: the *smaller degree* loses (large-degree
               vertices are more likely to cause future conflicts, so they
               keep their color); ties → the smaller id keeps (larger loses).

Both rules are total orders over vertices, so every conflicting pair has
exactly one loser and the maximum-priority vertex of any conflict component
never loses — guaranteeing progress each iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conflict_lose_flags", "conflict_lose_lanes", "HEURISTICS"]

HEURISTICS = ("id", "degree")


def conflict_lose_lanes(
    ids: jax.Array,          # (w,)   worklist vertex ids (sentinel n allowed)
    neigh_ids: jax.Array,    # (w, W) padded neighbor ids (sentinel n in pads)
    my_colors: jax.Array,    # (w,)   colors of ids (0 for sentinel)
    neigh_colors: jax.Array, # (w, W) colors of neighbors (0 in pads)
    my_deg: jax.Array,       # (w,)
    neigh_deg: jax.Array,    # (w, W)
    heuristic: str,
) -> tuple[jax.Array, jax.Array]:
    """Per-lane conflict masks ``(same, lose_lane)``.

    ``same`` marks lanes whose neighbor shares my (nonzero) color;
    ``lose_lane`` the subset whose neighbor *beats* me under the loser rule.
    Because the rule is a strict total order, ``same & ~lose_lane`` lanes are
    neighbors **I** beat — provably losers this step — which the rotated
    super-step treats as already-cleared when it refits (DESIGN.md §12).
    """
    same = (neigh_colors == my_colors[:, None]) & (my_colors[:, None] > 0)
    if heuristic == "id":
        lose_lane = same & (ids[:, None] < neigh_ids)
    elif heuristic == "degree":
        dv = my_deg[:, None]
        lose_lane = same & (
            (neigh_deg > dv) | ((neigh_deg == dv) & (neigh_ids < ids[:, None]))
        )
    else:
        raise ValueError(f"unknown heuristic {heuristic!r}; options: {HEURISTICS}")
    return same, lose_lane


def conflict_lose_flags(
    ids: jax.Array,
    neigh_ids: jax.Array,
    my_colors: jax.Array,
    neigh_colors: jax.Array,
    my_deg: jax.Array,
    neigh_deg: jax.Array,
    heuristic: str,
) -> jax.Array:
    """True where the worklist vertex loses a conflict and must recolor."""
    _, lose_lane = conflict_lose_lanes(
        ids, neigh_ids, my_colors, neigh_colors, my_deg, neigh_deg, heuristic
    )
    return jnp.any(lose_lane, axis=1)
