"""3-step GM analogue (Grosset et al., the paper's motivation baseline).

The original: (1) partition the graph, (2) color + detect conflicts on the
GPU for a few rounds, (3) ship remaining conflicts back to the CPU and fix
them *serially*.  The paper shows this is often slower than pure serial
because of the host round-trip and the serialized tail.

We reproduce the structure: ``device_rounds`` of speculative device coloring,
then a host-side serial fix-up of everything still uncolored.  The serial-tail
fraction is reported so benchmarks can show why the design loses.
"""
from __future__ import annotations

import numpy as np

from repro.api import register
from repro.core.coloring import ColoringResult
from repro.core.csr import CSRGraph
from repro.core.topo import _topo_step

import jax.numpy as jnp

__all__ = ["color_threestep"]


def _serial_fixup(g: CSRGraph, colors: np.ndarray) -> np.ndarray:
    """Greedy-color the uncolored vertices on the host (step 3)."""
    colors = np.concatenate([colors.astype(np.int32), np.zeros(1, np.int32)])
    color_mask = np.full(g.max_degree + 2, -1, dtype=np.int64)
    R, C = g.row_offsets, g.col_indices
    for v in np.nonzero(colors[: g.n] == 0)[0]:
        neigh = C[R[v] : R[v + 1]]
        color_mask[colors[neigh]] = v
        limit = neigh.shape[0] + 2
        free = np.nonzero(color_mask[1:limit] != v)[0]
        colors[v] = free[0] + 1
    return colors[: g.n]


@register("threestep")
def color_threestep(
    g: CSRGraph,
    *,
    device_rounds: int = 2,
    firstfit: str = "scan",
) -> ColoringResult:
    n = g.n
    if n == 0:
        return ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True, "threestep_gm")
    adj = jnp.asarray(g.padded_adjacency())
    deg_ext = jnp.asarray(
        np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    )
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)
    colored = jnp.zeros((n,), dtype=bool)
    iters = 0
    for _ in range(device_rounds):
        colors_ext, colored, rem = _topo_step(
            adj, deg_ext, colors_ext, colored, heuristic="id", kind=firstfit
        )
        iters += 1
        if int(rem) == 0:
            break
    colors = np.asarray(colors_ext[:n])
    serial_tail = int((colors == 0).sum())
    colors = _serial_fixup(g, colors)
    res = ColoringResult(
        colors,
        iters,
        work_items=iters * n + serial_tail,
        padded_work=iters * n + serial_tail,
        converged=True,
        algorithm="threestep_gm",
    )
    res.serial_tail = serial_tail  # fraction fixed serially on host
    return res
