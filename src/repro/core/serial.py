"""Sequential greedy coloring (Algorithm 1) — the quality/runtime oracle.

This is the CUSP ``Serial`` baseline of the paper's evaluation: First-Fit in
vertex order, using the vertex-stamped ``colorMask`` trick so each vertex costs
O(deg(v)) without clearing the mask.  Also supports Largest-Degree-First
ordering (the LF heuristic mentioned in §2).
"""
from __future__ import annotations

import numpy as np

from repro.api import register
from repro.core.csr import CSRGraph

__all__ = ["greedy_serial", "color_serial"]


def greedy_serial(g: CSRGraph, order: str | np.ndarray = "natural") -> np.ndarray:
    """Color ``g`` greedily; returns int32 colors in [1, max_degree+1]."""
    n = g.n
    colors = np.zeros(n + 1, dtype=np.int32)  # slot n = sentinel (color 0)
    # colorMask[c] == v  means color c is forbidden for the current vertex v.
    color_mask = np.full(g.max_degree + 2, -1, dtype=np.int64)
    if isinstance(order, str):
        if order == "natural":
            verts = range(n)
        elif order == "largest_degree_first":
            verts = np.argsort(-g.degrees, kind="stable")
        else:
            raise ValueError(f"unknown order {order!r}")
    else:
        verts = order
    R, C = g.row_offsets, g.col_indices
    for v in verts:
        neigh = C[R[v] : R[v + 1]]
        nc = colors[neigh]
        color_mask[nc] = v  # stamps color 0 too; we search from 1 so it is inert
        # smallest i >= 1 with color_mask[i] != v ; bounded by deg(v)+1
        limit = neigh.shape[0] + 2
        free = np.nonzero(color_mask[1:limit] != v)[0]
        colors[v] = free[0] + 1
    return colors[:n]


@register("serial")
def color_serial(g: CSRGraph, *, order: str | np.ndarray = "natural"):
    """``greedy_serial`` under the shared ``ColoringResult`` contract."""
    from repro.core.coloring import ColoringResult

    colors = greedy_serial(g, order)
    return ColoringResult(
        colors,
        iterations=1,           # one sequential sweep
        work_items=g.n,
        padded_work=g.n,
        converged=True,
        algorithm="serial_greedy",
    )
