"""Coloring validity and quality metrics (exact, host-side)."""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph
from repro.obs.spans import span

__all__ = ["is_valid_coloring", "num_colors", "quality_report"]


def is_valid_coloring(g: CSRGraph, colors: np.ndarray) -> bool:
    """True iff every vertex is colored (>0) and no edge is monochromatic."""
    with span("validate", n=g.n):
        colors = np.asarray(colors)
        if colors.shape[0] < g.n or (colors[: g.n] <= 0).any():
            return False
        src, dst = g.edges()
        return not bool((colors[src] == colors[dst]).any())


def num_colors(colors: np.ndarray) -> int:
    colors = np.asarray(colors)
    return int(colors.max(initial=0))


def quality_report(g: CSRGraph, colors: np.ndarray) -> dict:
    colors = np.asarray(colors)
    counts = np.bincount(colors[colors > 0])
    return {
        "valid": is_valid_coloring(g, colors),
        "num_colors": num_colors(colors),
        "greedy_bound": g.max_degree + 1,
        "largest_class": int(counts.max(initial=0)),
        "mean_class": float(counts[1:].mean()) if counts.size > 1 else 0.0,
    }
