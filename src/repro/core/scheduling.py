"""Chromatic scheduling: turn a coloring into conflict-free parallel phases.

This is the paper's motivating use case ("coloring is used to identify
subtasks that can be carried out simultaneously", §1) made into a framework
feature:

* ``phases``            — vertex groups per color: tasks in one phase touch no
                          shared edge and may run concurrently.
* ``schedule_quality``  — average parallelism the schedule exposes (the reason
                          fewer colors matter: parallelism = n / #colors).
* ``all_to_all_rounds`` — edge-color the all-to-all device communication graph
                          with the coloring engine: each round is a set of
                          disjoint (src, dst) transfers, the classical
                          collective-scheduling application.  Used by the MoE
                          expert-dispatch example.
"""
from __future__ import annotations

import numpy as np

from repro.core.coloring import color_data_driven
from repro.core.csr import CSRGraph, csr_from_edges
from repro.core.validate import num_colors

__all__ = ["phases", "schedule_quality", "all_to_all_rounds"]


def phases(colors: np.ndarray) -> list[np.ndarray]:
    colors = np.asarray(colors)
    return [
        np.nonzero(colors == c)[0].astype(np.int32)
        for c in range(1, num_colors(colors) + 1)
    ]


def schedule_quality(colors: np.ndarray) -> dict:
    ph = phases(colors)
    sizes = np.array([p.size for p in ph]) if ph else np.zeros(1)
    return {
        "phases": len(ph),
        "mean_parallelism": float(sizes.mean()),
        "min_parallelism": int(sizes.min(initial=0)),
        "critical_path": len(ph),
    }


def all_to_all_rounds(n_devices: int, **color_kwargs) -> list[list[tuple[int, int]]]:
    """Schedule a full all-to-all among ``n_devices`` into conflict-free rounds.

    Transfers (i, j), i != j, conflict iff they share an endpoint (each link
    endpoint sends/receives once per round).  We build the line graph of the
    complete directed communication graph and color it with the paper's
    engine; color classes are the rounds.  Optimal is n_devices - 1 rounds
    (round-robin); greedy coloring typically lands within ~2x, and the example
    compares both.
    """
    pairs = [(i, j) for i in range(n_devices) for j in range(n_devices) if i != j]
    index = {p: k for k, p in enumerate(pairs)}
    src_list, dst_list = [], []
    for (i, j), k in index.items():
        for (a, b), l in index.items():
            if l <= k:
                continue
            # conflict: same sender or same receiver in one round
            if a == i or b == j:
                src_list.append(k)
                dst_list.append(l)
    line_graph = csr_from_edges(len(pairs), np.array(src_list), np.array(dst_list))
    res = color_data_driven(line_graph, heuristic="degree")
    rounds: list[list[tuple[int, int]]] = [[] for _ in range(res.num_colors)]
    for p, c in zip(pairs, res.colors):
        rounds[c - 1].append(p)
    return rounds
