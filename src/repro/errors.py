"""Unified error surface (DESIGN.md §19).

Every refusal the repo can produce derives from ``ReproError``, so a
serving layer can map *any* failure to a structured response with one
``except ReproError`` clause and ``exc.payload()`` — no string matching:

* ``IngestError``         — the §17 validating-ingest front door refused a
  malformed CSR (defined in ``repro.ingest``; carries the structured
  ``IngestReport``).  Re-exported here.
* ``CapacityError``       — a packed-word / pack-budget refusal: an engine
  was explicitly asked for a packed fast path whose operands cannot fit
  the bit budget (``repro.ingest.packed_gather_ok`` and friends are the
  budgets themselves).
* ``NonConvergenceError`` — a speculative run exhausted ``max_iters``
  without converging and the caller opted out of the §17 guarantee
  ladder (``on_fail="raise"``).
* ``Overloaded``          — the serving layer's structured backpressure
  signal: the bounded request queue is full and the request was REJECTED
  at admission rather than queued without bound (carries
  ``queue_depth`` / ``limit`` / ``retry_after``).
* ``SessionEvicted``      — a pooled session was evicted (LRU, no durable
  spill) and its state is gone; the caller must re-open it.

Compatibility: the pre-§19 raise sites used bare ``ValueError`` /
``RuntimeError``, so the typed classes multiply-inherit from the legacy
bases — existing ``except ValueError`` / ``except RuntimeError`` clauses
(and tests) keep working unchanged.
"""
from __future__ import annotations

__all__ = [
    "ReproError",
    "IngestError",
    "CapacityError",
    "NonConvergenceError",
    "Overloaded",
    "SessionEvicted",
]


class ReproError(Exception):
    """Base of every structured error the repro engines raise.

    ``payload()`` renders the exception as a JSON-safe dict — the shape the
    serving layer returns for a failed request.  Subclasses contribute
    extra fields via ``_fields()``.
    """

    def _fields(self) -> dict:
        return {}

    def payload(self) -> dict:
        out = {"error": type(self).__name__, "message": str(self)}
        out.update(self._fields())
        return out


class CapacityError(ReproError, ValueError):
    """An explicitly-requested packed fast path cannot hold its operands.

    The §12/§13 packed-word formats have hard bit budgets
    (``repro.ingest.PACKED_GATHER_MAX_DEG`` / ``PACKED_HALO_MAX_N``); the
    engines REFUSE an explicit packed request past budget rather than
    silently corrupting colors.
    """


class NonConvergenceError(ReproError, RuntimeError, ValueError):
    """A speculative run exhausted its iteration budget without converging
    and the caller asked for a refusal (``on_fail="raise"``) instead of
    the §17 guarantee ladder.  Inherits both legacy bases: the dynamic
    engine used to raise ``RuntimeError`` here, the bipartite compressor
    ``ValueError``.
    """


class Overloaded(ReproError):
    """Admission-control rejection: the bounded request queue is full.

    The serving layer's backpressure contract (DESIGN.md §19): a queue at
    its limit rejects *immediately* with this structured error instead of
    growing without bound.  ``retry_after`` is a coarse hint (seconds)
    derived from the service's recent drain rate.
    """

    def __init__(self, message: str, *, queue_depth: int, limit: int,
                 retry_after: float = 0.0):
        super().__init__(message)
        self.queue_depth = int(queue_depth)
        self.limit = int(limit)
        self.retry_after = float(retry_after)

    def _fields(self) -> dict:
        return {"queue_depth": self.queue_depth, "limit": self.limit,
                "retry_after": self.retry_after}


class SessionEvicted(ReproError, LookupError):
    """The addressed pooled session was LRU-evicted without durable spill.

    Its in-memory state is gone and there is no journal to resurrect it
    from; the client must re-open the session (services opened with a
    ``spill_dir`` restore evicted sessions transparently instead of
    raising this).
    """

    def __init__(self, message: str, *, session_id=None):
        super().__init__(message)
        self.session_id = session_id

    def _fields(self) -> dict:
        return {"session_id": self.session_id}


def __getattr__(name):
    # IngestError lives with its IngestReport in repro.ingest (which imports
    # this module); re-export lazily to keep the surface unified without a
    # circular import
    if name == "IngestError":
        from repro.ingest import IngestError

        return IngestError
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
