"""Observability layer: trace rings, phase spans, exporters (DESIGN.md §16).

Three pieces, layered:

* ``obs.trace``  — on-device trace rings + the ``RunTrace`` record every
  engine attaches as ``ColoringResult.trace`` when called with
  ``trace=True`` (a STATIC knob: ``trace=False`` compiles the identical
  XLA program and stays bit-identical/zero-cost).
* ``obs.spans``  — host-side monotonic-clock phase spans with
  compile-vs-execute attribution per jit cache key.
* ``obs.export`` / ``obs.report`` — Chrome-trace (Perfetto-loadable)
  JSON export and the shared text reporter
  (``python -m repro.obs.report``).
"""
from .export import chrome_trace, export_chrome_trace
from .report import format_metrics, format_result, format_spans, format_trace
from .spans import SpanEvent, SpanRecorder, jit_span, recorder, span
from .trace import (
    DEFAULT_TRACE_CAP,
    NF,
    TRACE_FIELDS,
    HostRing,
    RunTrace,
    assemble_trace,
    empty_trace,
    resolve_trace_cap,
    ring_init,
    ring_rows,
)

__all__ = [
    "TRACE_FIELDS",
    "NF",
    "DEFAULT_TRACE_CAP",
    "HostRing",
    "RunTrace",
    "assemble_trace",
    "empty_trace",
    "resolve_trace_cap",
    "ring_init",
    "ring_rows",
    "SpanEvent",
    "SpanRecorder",
    "recorder",
    "span",
    "jit_span",
    "chrome_trace",
    "export_chrome_trace",
    "format_result",
    "format_trace",
    "format_spans",
    "format_metrics",
]
