"""Host-side phase spans with compile-vs-execute attribution (DESIGN.md §16).

The engines wrap their host-visible phases — CSR build, partition
planning, the super-step loop, the serial tail, delta mutation /
compaction, validation — in ``span("name")`` context managers.  A span is
a *no-op* unless a recorder is active: the engines pay one truthiness
check per phase, nothing else, so uninstrumented callers are unaffected.

To collect, open a recorder around any engine call::

    from repro.obs import recorder
    with recorder() as spans:
        result = color(g, algorithm="fused")
    # spans.events -> [SpanEvent(name="csr_build", ...), ...]

Engines that run with ``trace=True`` open their own recorder internally
and attach the captured events to ``ColoringResult.trace.spans``; an
outer user recorder still sees every span (recorders nest — each event is
delivered to the whole active stack).

Compile-vs-execute attribution: jitted dispatches are wrapped in
``jit_span(name, key)`` where ``key`` is the engine's jit cache key (the
static-argument + shape tuple that decides retracing).  The first time a
key is seen in the process the span is tagged ``cat="compile"`` —
matching XLA's behavior of tracing+compiling on first call — and
``cat="execute"`` afterwards.  That is how ``repro.obs.report`` splits a
session's wall time into compile and steady-state execute, the
distinction PR 5's churn work hinged on.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager

__all__ = [
    "SpanEvent",
    "SpanRecorder",
    "recorder",
    "span",
    "jit_span",
    "recording",
    "jit_key_seen",
]

# stack of active recorders; module-level list so `span` can bail with a
# single truthiness test when nobody is listening
_ACTIVE: list = []

# process-global registry of jit cache keys already dispatched once; mirrors
# the lifetime of jax's own compilation cache (per-process)
_SEEN_JIT_KEYS: set = set()


@dataclasses.dataclass
class SpanEvent:
    """One closed phase span (monotonic clock, seconds)."""

    name: str
    start: float
    duration: float
    cat: str = "phase"      # "phase" | "compile" | "execute"
    meta: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "start": self.start,
                "duration": self.duration, "cat": self.cat,
                "meta": dict(self.meta)}


class SpanRecorder:
    """Accumulates every ``SpanEvent`` closed while it is on the stack."""

    def __init__(self):
        self.events: list = []

    def __enter__(self):
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc):
        _ACTIVE.remove(self)
        return False

    def total(self, name: str | None = None, cat: str | None = None) -> float:
        """Summed duration of matching spans (seconds)."""
        return sum(e.duration for e in self.events
                   if (name is None or e.name == name)
                   and (cat is None or e.cat == cat))

    def by_name(self) -> dict:
        out: dict = {}
        for e in self.events:
            agg = out.setdefault(e.name, {"count": 0, "seconds": 0.0,
                                          "compile_seconds": 0.0})
            agg["count"] += 1
            agg["seconds"] += e.duration
            if e.cat == "compile":
                agg["compile_seconds"] += e.duration
        return out


def recorder() -> SpanRecorder:
    """A fresh recorder; use as ``with recorder() as r: ...``."""
    return SpanRecorder()


def recording() -> bool:
    """True when at least one recorder is active (spans are being kept)."""
    return bool(_ACTIVE)


@contextmanager
def span(name: str, cat: str = "phase", **meta):
    """Time a phase; no-op (one list truthiness check) without a recorder."""
    if not _ACTIVE:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        ev = SpanEvent(name, t0, time.perf_counter() - t0, cat, meta)
        for rec in _ACTIVE:
            rec.events.append(ev)


def jit_key_seen(key) -> bool:
    """Register ``key``; True when it was already dispatched this process.

    The key should be the tuple of statics + shapes that decides whether
    jax retraces — first sighting ≙ trace+compile, later ≙ cached execute.
    """
    if key in _SEEN_JIT_KEYS:
        return True
    _SEEN_JIT_KEYS.add(key)
    return False


@contextmanager
def jit_span(name: str, key, **meta):
    """``span`` for a jitted dispatch, tagged compile/execute by cache key."""
    if not _ACTIVE:
        # the registry must advance even while nobody records, otherwise the
        # first *recorded* dispatch of a warm key would be mislabeled compile
        jit_key_seen(key)
        yield
        return
    cat = "execute" if jit_key_seen(key) else "compile"
    with span(name, cat=cat, **meta):
        yield
