"""On-device trace rings and the host-side ``RunTrace`` record (DESIGN.md §16).

The paper's whole argument (§V of the source paper) is made from
*per-iteration* evidence — worklist shrinkage, conflict counts, tail
behavior across super-steps — yet ``ColoringResult`` historically reported
only end-of-run aggregates.  This module defines the step-level telemetry
substrate every engine records into:

* **Trace ring** — a pre-allocated ``(cap, NF)`` int32 buffer.  Fused
  (``lax.while_loop``) drivers thread it through the loop carry and write
  one row per super-step at ``step % cap`` (a *ring*: bounded memory no
  matter how many steps run, the last ``cap`` rows are retained); host-loop
  drivers append rows to a ``HostRing`` with the same drop-oldest
  semantics.  Tracing is a STATIC knob — ``trace=False`` callers compile
  the exact same XLA program as before the ring existed (no extra carry,
  no extra ops), which is the zero-overhead-when-off argument §16 makes.

* **``RunTrace``** — the host-side record attached as
  ``ColoringResult.trace``: the retained rows in step order, the total
  step count, engine/algorithm labels, and any phase spans captured while
  the engine ran.  ``check()`` verifies the structural invariants the
  trace tests rely on (see below).

Row schema (``TRACE_FIELDS``, one int64 per field after host assembly):

``live``        worklist entries entering the step (the bootstrap row
                carries the initial worklist; a tail row the surviving
                live worklist it drains — NOT the inflated full-graph
                charge a stall-serialization pays).
``retired``     entries that left the worklist this step (``live -
                conflicts``; a vertex never re-enters a worklist, so the
                per-run retired sum equals the initial worklist size).
``conflicts``   entries detected as needing recolor (the next worklist).
``max_color``   maximum color in use after the step.
``cells``       gather cells dispatched this step (``Σ lanes × tile
                width``; partitions the run's dispatch accounting).
``tail``        1 on the serial-tail step, else 0.
``halo_bytes``  bytes of boundary colors a device received this step
                (sharded engine; 0 on single-device engines).
``imbalance``   max-minus-min per-shard live count (sharded; 0 otherwise).

Invariants (asserted by ``RunTrace.check`` and ``tests/test_obs.py``):

* ``retired + conflicts == live`` on every non-tail row; tail rows retire
  their whole worklist (``conflicts == 0``).
* worklist continuity: ``conflicts[i] == live[i + 1]``.
* with no ring drops and a converged run, ``Σ retired == live[0]``.
* ``Σ cells == ColoringResult.padded_work`` on the single-graph engines
  (the batched engine additionally charges frozen-capacity steps to
  ``padded_work``, so there the trace sum is a lower bound).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

__all__ = [
    "TRACE_FIELDS",
    "NF",
    "DEFAULT_TRACE_CAP",
    "HostRing",
    "RunTrace",
    "resolve_trace_cap",
    "ring_init",
    "ring_rows",
    "assemble_trace",
    "empty_trace",
]

TRACE_FIELDS = ("live", "retired", "conflicts", "max_color", "cells",
                "tail", "halo_bytes", "imbalance")
NF = len(TRACE_FIELDS)
DEFAULT_TRACE_CAP = 512

_LIVE, _RETIRED, _CONFLICTS, _MAXC, _CELLS, _TAIL = range(6)


def resolve_trace_cap(trace, max_iters: int | None = None) -> int:
    """Ring capacity from the ``trace`` knob: 0 = off.

    ``trace`` is ``False``/``True`` (default capacity) or a positive int
    (explicit capacity).  ``max_iters`` bounds the ring — no point holding
    more rows than the engine can ever take steps.
    """
    if trace is False or trace is None:
        return 0
    if trace is True:
        cap = DEFAULT_TRACE_CAP
    else:
        cap = int(trace)
        if cap <= 0:
            return 0
    if max_iters is not None:
        # +2 leaves room for the bootstrap and tail rows the host appends
        cap = min(cap, int(max_iters) + 2)
    return max(cap, 1)


def ring_init(cap: int):
    """A fresh device-side trace ring: ``(cap, NF)`` int32 zeros."""
    import jax.numpy as jnp

    return jnp.zeros((cap, NF), dtype=jnp.int32)


def ring_rows(buf: np.ndarray, steps: int) -> np.ndarray:
    """Retained rows of a device ring in step order.

    ``steps`` rows were written at positions ``s % cap``; the retained
    window is the last ``min(steps, cap)`` of them.
    """
    buf = np.asarray(buf)
    cap = buf.shape[0]
    steps = int(steps)
    if steps <= 0:
        return buf[:0]
    first = max(0, steps - cap)
    idx = [s % cap for s in range(first, steps)]
    return buf[idx]


class HostRing:
    """Drop-oldest row accumulator for host-loop drivers.

    Mirrors the device ring's retention semantics (keep the most recent
    ``cap`` rows, count everything) so host- and device-driven engines
    assemble identical ``RunTrace`` records.
    """

    def __init__(self, cap: int):
        self.cap = int(cap)
        self._rows: deque = deque(maxlen=self.cap)
        self.recorded = 0

    def append(self, live, retired, conflicts, max_color, cells, tail=0,
               halo_bytes=0, imbalance=0) -> None:
        self._rows.append((int(live), int(retired), int(conflicts),
                           int(max_color), int(cells), int(tail),
                           int(halo_bytes), int(imbalance)))
        self.recorded += 1

    def rows(self) -> np.ndarray:
        if not self._rows:
            return np.zeros((0, NF), dtype=np.int64)
        return np.asarray(self._rows, dtype=np.int64)


@dataclasses.dataclass
class RunTrace:
    """Per-super-step telemetry of one engine run (``ColoringResult.trace``)."""

    steps: np.ndarray                 # (S, NF) int64, step order
    iterations: int                   # rows recorded (>= S when ring wrapped)
    engine: str = ""
    cap: int = DEFAULT_TRACE_CAP
    spans: list = dataclasses.field(default_factory=list)  # SpanEvent list
    schema: int = 1

    @property
    def fields(self) -> tuple:
        return TRACE_FIELDS

    @property
    def dropped(self) -> int:
        """Rows the ring overwrote (0 unless the run outran the capacity)."""
        return self.iterations - int(self.steps.shape[0])

    def series(self, field: str) -> np.ndarray:
        return self.steps[:, TRACE_FIELDS.index(field)]

    @property
    def tail_step(self) -> int:
        """Absolute step index of the serial-tail row, or -1 when no tail ran."""
        tails = np.flatnonzero(self.steps[:, _TAIL])
        if tails.size == 0:
            return -1
        return int(tails[0]) + self.dropped

    def check(self, result=None) -> list:
        """Structural-invariant violations (empty list = trace is coherent)."""
        bad: list = []
        s = self.steps.astype(np.int64)
        if s.shape[0] == 0:
            if self.iterations != 0:
                bad.append(f"{self.iterations} steps recorded but no rows kept")
            return bad
        if np.any(s[:, (_LIVE, _RETIRED, _CONFLICTS, _CELLS)] < 0):
            bad.append("negative live/retired/conflicts/cells entry")
        tail = s[:, _TAIL]
        if np.any((tail != 0) & (tail != 1)):
            bad.append("tail flag not in {0, 1}")
        if np.any(s[tail == 1, _CONFLICTS] != 0):
            bad.append("tail row with conflicts != 0")
        if np.any(s[:, _RETIRED] + s[:, _CONFLICTS] != s[:, _LIVE]):
            bad.append("retired + conflicts != live on some row")
        if np.any(s[:-1, _CONFLICTS] != s[1:, _LIVE]):
            bad.append("worklist continuity broken: conflicts[i] != live[i+1]")
        if self.dropped == 0 and s[-1, _CONFLICTS] == 0:
            if int(s[:, _RETIRED].sum()) != int(s[0, _LIVE]):
                bad.append(
                    f"retired sum {int(s[:, _RETIRED].sum())} != initial "
                    f"worklist {int(s[0, _LIVE])}")
        if result is not None and self.dropped == 0:
            cells = int(s[:, _CELLS].sum())
            padded = int(getattr(result, "padded_work", cells))
            if cells > padded:
                bad.append(f"cells sum {cells} > padded_work {padded}")
        return bad

    def to_dict(self) -> dict:
        return {
            "schema": self.schema,
            "engine": self.engine,
            "fields": list(TRACE_FIELDS),
            "iterations": int(self.iterations),
            "dropped": int(self.dropped),
            "tail_step": self.tail_step,
            "steps": self.steps.astype(int).tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RunTrace":
        steps = np.asarray(d.get("steps", []), dtype=np.int64)
        if steps.size == 0:
            steps = np.zeros((0, NF), dtype=np.int64)
        return cls(steps=steps, iterations=int(d.get("iterations", 0)),
                   engine=d.get("engine", ""), schema=int(d.get("schema", 1)))

    def summary(self, max_points: int = 64) -> dict:
        """The compact BENCH schema-6 record: headline counters + series.

        Series longer than ``max_points`` are truncated from the front
        (the interesting dynamics — tail trigger, convergence — live at
        the end); ``series_from`` records the first retained step.
        """
        s = self.steps
        start = max(0, s.shape[0] - max_points)
        out = {
            "supersteps": int(self.iterations),
            "tail_step": self.tail_step,
            "series_from": start + self.dropped,
            "live": s[start:, _LIVE].astype(int).tolist(),
            "retired": s[start:, _RETIRED].astype(int).tolist(),
            "conflicts": s[start:, _CONFLICTS].astype(int).tolist(),
            "max_color": s[start:, _MAXC].astype(int).tolist(),
            "cells": s[start:, _CELLS].astype(int).tolist(),
        }
        halo = self.series("halo_bytes")
        if s.shape[0] and halo.any():
            out["halo_bytes"] = halo[start:].astype(int).tolist()
            out["imbalance"] = (
                self.series("imbalance")[start:].astype(int).tolist())
        return out


def empty_trace(engine: str = "") -> RunTrace:
    """The trace of a zero-step run (empty graphs, no-op recolors)."""
    return RunTrace(steps=np.zeros((0, NF), dtype=np.int64), iterations=0,
                    engine=engine)


def assemble_trace(rows, recorded: int, cap: int, engine: str) -> RunTrace:
    """``RunTrace`` from in-order row tuples, keeping the last ``cap``.

    ``recorded`` counts every step the engine took (>= len(rows) when a
    device ring already wrapped); host-side retention then drops the oldest
    surplus so the kept window is contiguous and ends at the final step.
    """
    rows = [tuple(int(v) for v in r) for r in rows]
    kept = rows[-cap:] if cap else rows
    steps = (np.asarray(kept, dtype=np.int64) if kept
             else np.zeros((0, NF), dtype=np.int64))
    return RunTrace(steps=steps, iterations=int(recorded), engine=engine,
                    cap=int(cap))
