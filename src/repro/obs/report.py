"""Text reporting over traces, spans, and session metrics (DESIGN.md §16).

The one reporting path shared by ``examples/`` and ``benchmarks/``:

* ``format_result``   — one-line engine-run summary from a ``ColoringResult``
* ``format_trace``    — per-super-step table from a ``RunTrace``
* ``format_spans``    — phase table with the compile-vs-execute split
* ``format_metrics``  — aligned key/value block (``session.metrics()``)

and a CLI that re-reports from files instead of rerunning anything::

    python -m repro.obs.report trace.json          # Chrome-trace export
    python -m repro.obs.report BENCH_coloring.json # BENCH schema >= 6 doc
"""
from __future__ import annotations

import json
import sys

from .spans import SpanRecorder
from .trace import RunTrace

__all__ = [
    "format_result",
    "format_trace",
    "format_spans",
    "format_metrics",
    "main",
]


def format_result(label: str, result) -> str:
    """One-line run summary; appends trace headline when one is attached."""
    parts = [f"{label}: colors={result.num_colors}",
             f"iters={result.iterations}",
             f"work={result.work_items}",
             f"padded={result.padded_work}"]
    if not result.converged:
        parts.append("NOT-CONVERGED")
    trace = getattr(result, "trace", None)
    if isinstance(trace, RunTrace):
        tail = trace.tail_step
        parts.append(f"tail@{tail}" if tail >= 0 else "no-tail")
    return "  ".join(parts)


def format_trace(trace: RunTrace, last: int | None = None) -> str:
    """Per-super-step table (most recent ``last`` rows when given)."""
    header = (f"{'step':>5} {'live':>9} {'retired':>9} {'confl':>9} "
              f"{'maxc':>5} {'cells':>11} {'halo_B':>9} {'imbal':>7}  flag")
    lines = [f"trace[{trace.engine}]: {trace.iterations} steps "
             f"({trace.dropped} dropped from ring, cap={trace.cap})", header]
    rows = trace.steps
    first_abs = trace.dropped
    if last is not None and rows.shape[0] > last:
        first_abs += rows.shape[0] - last
        rows = rows[-last:]
    for i, row in enumerate(rows):
        live, retired, confl, maxc, cells, tail, halo, imb = (
            int(v) for v in row)
        flag = "tail" if tail else ""
        if first_abs + i == 0 and not tail:
            flag = "boot" if cells == 0 else flag
        lines.append(f"{first_abs + i:>5} {live:>9} {retired:>9} "
                     f"{confl:>9} {maxc:>5} {cells:>11} {halo:>9} "
                     f"{imb:>7}  {flag}")
    return "\n".join(lines)


def format_spans(spans) -> str:
    """Phase table; ``spans`` is a recorder or a list of ``SpanEvent``."""
    events = spans.events if isinstance(spans, SpanRecorder) else list(spans)
    if not events:
        return "spans: (none recorded)"
    rec = SpanRecorder()
    rec.events = events
    lines = [f"{'phase':<22} {'count':>5} {'total_ms':>10} {'compile_ms':>11}"]
    for name, agg in sorted(rec.by_name().items(),
                            key=lambda kv: -kv[1]["seconds"]):
        lines.append(f"{name:<22} {agg['count']:>5} "
                     f"{agg['seconds'] * 1e3:>10.2f} "
                     f"{agg['compile_seconds'] * 1e3:>11.2f}")
    return "\n".join(lines)


def format_metrics(metrics: dict, title: str = "") -> str:
    """Aligned key/value block for cumulative counters."""
    lines = [title] if title else []
    width = max((len(k) for k in metrics), default=0)
    for k, v in metrics.items():
        if isinstance(v, float):
            v = f"{v:.4g}"
        lines.append(f"  {k:<{width}} : {v}")
    return "\n".join(lines)


def _report_chrome(doc: dict, last: int | None) -> str:
    out = []
    for label, tdict in sorted(doc["otherData"].get("repro", {}).items()):
        out.append(format_trace(RunTrace.from_dict(tdict), last=last))
        out.append("")
    return "\n".join(out).rstrip()


def _report_bench(doc: dict, last: int | None) -> str:
    out = [f"BENCH schema {doc.get('schema')} "
           f"backend={doc.get('backend', '?')} "
           f"engine={doc.get('engine', '?')}"]
    for alg, per_graph in sorted(doc.get("algorithms", {}).items()):
        for name, rec in sorted(per_graph.items()):
            t = rec.get("trace")
            label = f"{alg}/{name}"
            if not t:
                continue  # untraced algorithms carry no section (schema 6)
            out.append(
                f"{label}: supersteps={t['supersteps']} "
                f"tail_step={t['tail_step']} "
                f"final_max_color={t['max_color'][-1] if t['max_color'] else 0}")
            n = len(t["live"])
            show = range(n if last is None else max(0, n - last), n)
            for i in show:
                out.append(
                    f"  step {t['series_from'] + i:>4}: "
                    f"live={t['live'][i]:>8} retired={t['retired'][i]:>8} "
                    f"conflicts={t['conflicts'][i]:>8} "
                    f"maxc={t['max_color'][i]:>4} cells={t['cells'][i]}")
    for name, rec in sorted(doc.get("dynamic", {}).items()):
        label = f"dynamic/{name}"
        rounds = rec.get("rounds_detail")
        if not rounds:
            out.append(f"{label}: no per-round detail")
            continue
        out.append(f"{label}: {len(rounds)} churn rounds, "
                   f"jit misses={rec.get('jit', {}).get('misses', '?')} "
                   f"hits={rec.get('jit', {}).get('hits', '?')}")
        for r in rounds:
            out.append(f"  round {r['round']}: frontier={r['frontier']:>7} "
                       f"work={r['work']:>8} supersteps={r['supersteps']} "
                       f"tail_step={r['tail_step']} "
                       f"cache_hit={r['cache_hit']}")
    return "\n".join(out)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    last = None
    if "--last" in argv:
        i = argv.index("--last")
        last = int(argv[i + 1])
        del argv[i:i + 2]
    if len(argv) != 1:
        print("usage: python -m repro.obs.report [--last N] "
              "<chrome_trace.json | BENCH_*.json>", file=sys.stderr)
        return 2
    with open(argv[0]) as fh:
        doc = json.load(fh)
    if "traceEvents" in doc:
        print(_report_chrome(doc, last))
    elif "algorithms" in doc or "dynamic" in doc:
        print(_report_bench(doc, last))
    else:
        print("unrecognized document (want a repro chrome-trace export "
              "or a BENCH schema>=6 doc)", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
