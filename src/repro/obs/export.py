"""Chrome-trace-format export of ``RunTrace`` records (DESIGN.md §16).

Produces the Trace Event Format JSON that chrome://tracing and Perfetto
(https://ui.perfetto.dev) load directly:

* phase spans → ``"ph": "X"`` complete events (one track per run),
* per-super-step series → ``"ph": "C"`` counter events (worklist
  live/retired/conflicts, max color, dispatch cells, halo bytes).

Timestamps are microseconds.  Span events use their real monotonic-clock
offsets; step counters are placed inside the run's super-step-loop span
when one was captured (spread uniformly across its duration — the jitted
loop gives the host no per-step clock), else on a synthetic 1 ms/step
axis.  Each exported run gets its own pid so multiple runs (e.g. every
record of a bench document) land as separate named process tracks in one
file.

The full ``RunTrace`` dicts ride along under ``otherData.repro`` so
``python -m repro.obs.report FILE`` can reconstruct text reports from an
exported file without rerunning anything.
"""
from __future__ import annotations

import json

from .trace import RunTrace

__all__ = ["chrome_trace", "export_chrome_trace"]

_STEP_US = 1000.0  # synthetic per-step spacing when no loop span exists


def _coerce(run) -> RunTrace | None:
    trace = getattr(run, "trace", run)
    return trace if isinstance(trace, RunTrace) else None


def chrome_trace(runs) -> dict:
    """Build the Trace Event Format document.

    ``runs`` is a ``RunTrace``, a ``ColoringResult`` carrying one, or a
    ``{label: RunTrace | ColoringResult}`` mapping (one pid per label).
    """
    if not isinstance(runs, dict):
        runs = {"run": runs}
    events: list = []
    other: dict = {}
    for pid, (label, run) in enumerate(sorted(runs.items())):
        trace = _coerce(run)
        if trace is None:
            continue
        other[label] = trace.to_dict()
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"repro:{label}"}})
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": trace.engine or "engine"}})

        spans = list(trace.spans)
        t0 = min((e.start for e in spans), default=0.0)
        loop = next((e for e in spans if e.name == "superstep_loop"), None)
        for e in spans:
            events.append({
                "name": e.name, "cat": e.cat, "ph": "X", "pid": pid,
                "tid": 0, "ts": (e.start - t0) * 1e6,
                "dur": max(e.duration * 1e6, 0.01),
                "args": {k: v for k, v in e.meta.items()},
            })

        steps = trace.steps
        n_rows = int(steps.shape[0])
        if n_rows:
            if loop is not None and loop.duration > 0:
                base = (loop.start - t0) * 1e6
                dt = loop.duration * 1e6 / n_rows
            else:
                base, dt = 0.0, _STEP_US
            fields = trace.fields
            for i in range(n_rows):
                ts = base + i * dt
                row = dict(zip(fields, (int(v) for v in steps[i])))
                events.append({"name": "worklist", "ph": "C", "pid": pid,
                               "tid": 0, "ts": ts,
                               "args": {"live": row["live"],
                                        "retired": row["retired"],
                                        "conflicts": row["conflicts"]}})
                events.append({"name": "colors", "ph": "C", "pid": pid,
                               "tid": 0, "ts": ts,
                               "args": {"max_color": row["max_color"]}})
                events.append({"name": "dispatch_cells", "ph": "C",
                               "pid": pid, "tid": 0, "ts": ts,
                               "args": {"cells": row["cells"]}})
                if row["halo_bytes"] or row["imbalance"]:
                    events.append({"name": "halo", "ph": "C", "pid": pid,
                                   "tid": 0, "ts": ts,
                                   "args": {"halo_bytes": row["halo_bytes"],
                                            "imbalance": row["imbalance"]}})
                if row["tail"]:
                    events.append({"name": "serial_tail_step", "ph": "I",
                                   "pid": pid, "tid": 0, "ts": ts,
                                   "s": "p"})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"repro": other, "schema": 1},
    }


def export_chrome_trace(path: str, runs) -> dict:
    """Write the Chrome-trace JSON for ``runs`` to ``path``; returns the doc."""
    doc = chrome_trace(runs)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return doc
