"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries go through a low-rank bottleneck (q_lora); keys/values are compressed
into a per-token latent ``c_kv`` (kv_lora wide) plus one shared decoupled RoPE
key (qk_rope_dim).  Scoring width = qk_nope + qk_rope per head.

Two execution paths:
* ``mla_attention``        — train/prefill: decompress K/V per head and run
                              standard chunked attention.
* ``mla_decode_absorbed``  — decode: the famous MLA inference trick.  The
                              per-head up-projections are *absorbed* into the
                              query / output sides, so attention scores and
                              context are computed directly against the
                              (B, T, kv_lora + rope) compressed cache — the
                              cache stays 576-wide regardless of 128 heads,
                              which is what makes decode_32k / long caches fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention, dense_init, norm_apply, norm_init, rope_apply

__all__ = ["mla_init", "mla_project_qkv", "mla_attention", "mla_decode_absorbed"]


def mla_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (d, cfg.q_lora), dtype),
        "q_norm": norm_init(cfg.q_lora, "rmsnorm", dtype),
        "wq_b": dense_init(ks[1], (cfg.q_lora, H * (dn + dr)), dtype),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora + dr), dtype),
        "kv_norm": norm_init(cfg.kv_lora, "rmsnorm", dtype),
        "wk_b": dense_init(ks[3], (cfg.kv_lora, H * dn), dtype),
        "wv_b": dense_init(ks[4], (cfg.kv_lora, H * dv), dtype),
        "wo": dense_init(ks[5], (H * dv, d), dtype),
    }


def mla_project_qkv(p, x, positions, cfg):
    """Shared projections. Returns (q_nope, q_rope, c_kv, k_rope)."""
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
    q = norm_apply(p["q_norm"], q, "rmsnorm")
    q = jnp.einsum("bsr,re->bse", q, p["wq_b"].astype(x.dtype))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = ckv[..., : cfg.kv_lora], ckv[..., cfg.kv_lora :]
    c_kv = norm_apply(p["kv_norm"], c_kv, "rmsnorm")
    k_rope = rope_apply(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, x, positions, cfg, *, k_pos=None):
    """Train/prefill path: decompress and run standard attention."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = mla_project_qkv(p, x, positions, cfg)

    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["wk_b"].astype(x.dtype))
    k_nope = k_nope.reshape(B, S, H, dn)
    v = jnp.einsum("bsr,re->bse", c_kv, p["wv_b"].astype(x.dtype))
    v = v.reshape(B, S, H, dv)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr))],
                        axis=-1)
    out = attention(
        q, k, v,
        q_pos=positions,
        k_pos=positions if k_pos is None else k_pos,
        causal=cfg.causal,
        window=cfg.window,
        q_chunk=cfg.attn_q_chunk,
        scale=(dn + dr) ** -0.5,
        chunk_remat=cfg.attn_chunk_remat,
    )
    out = out.reshape(B, S, H * dv)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype)), (c_kv, k_rope)


def mla_decode_absorbed(p, x, pos, cache_ckv, cache_krope, k_pos, cfg):
    """Decode path against the compressed cache (absorption trick).

    x (B, 1, d); cache_ckv (B, T, kv_lora); cache_krope (B, T, dr).
    Returns (out (B, 1, d), new c_kv row, new k_rope row).
    """
    B = x.shape[0]
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    R = cfg.kv_lora
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = mla_project_qkv(p, x, positions, cfg)

    # write the new token into the cache view used for scoring
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, c_kv_new, (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(cache_krope, k_rope_new, (0, pos, 0))

    # absorb wk_b into the query: q_lat (B, H, R)
    wk_b = p["wk_b"].astype(x.dtype).reshape(R, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b)

    scores = jnp.einsum("bhr,btr->bht", q_lat, cache_ckv)
    scores = scores + jnp.einsum("bhd,btd->bht", q_rope[:, 0], cache_krope)
    scores = scores.astype(jnp.float32) * (dn + dr) ** -0.5
    valid = (k_pos >= 0) & (k_pos <= pos)
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    ctx = jnp.einsum("bht,btr->bhr", probs, cache_ckv)       # latent context
    wv_b = p["wv_b"].astype(x.dtype).reshape(R, H, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx, wv_b).reshape(B, 1, H * dv)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_ckv, cache_krope
