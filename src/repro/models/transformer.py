"""Composable decoder/encoder stack covering all assigned families.

The model is planned as *segments*: a homogeneous run of layers executed with
``lax.scan`` over stacked params (compile time independent of depth — critical
for 512-way SPMD lowering on this host), plus "plain" layers for structural
exceptions (DeepSeek's dense layer 0, RecurrentGemma's trailing partial
period).  Hybrid patterns scan over whole periods (e.g. (rec, rec, attn)).

Modes: ``train``/``forward`` (full sequence, no cache), ``prefill`` (full
sequence, emits per-layer caches), ``decode`` (one token, consumes caches).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import rwkv6
from repro.models.layers import (
    attention,
    dense_init,
    linear,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    rope_apply,
    _head_rmsnorm,
)
from repro.models.mla import mla_attention, mla_decode_absorbed, mla_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_apply, rglru_decode_step, rglru_init

__all__ = ["plan_segments", "init_params", "apply_stack", "Segment", "init_cache"]


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str                 # "scan" | "plain"
    specs: tuple[tuple[str, str], ...]   # per-layer (block, ffn) within a period
    count: int                # scan length (periods) or 1 for plain


def layer_specs(cfg: ModelConfig) -> list[tuple[str, str]]:
    specs = []
    for kind, ffn in zip(cfg.layer_kinds(), cfg.ffn_kinds()):
        if cfg.family == "rwkv":
            specs.append(("rwkv", "none"))
        elif kind == "rec":
            specs.append(("rec", "dense"))
        else:
            specs.append(("mla" if cfg.mla else "attn", ffn))
    return specs


def plan_segments(cfg: ModelConfig) -> list[Segment]:
    specs = layer_specs(cfg)
    segments: list[Segment] = []
    start = cfg.first_dense_layers
    for i in range(start):
        segments.append(Segment("plain", (specs[i],), 1))
    period = max(len(cfg.pattern), 1)
    rest = specs[start:]
    n_full = len(rest) // period
    if n_full:
        segments.append(Segment("scan", tuple(rest[:period]), n_full))
    for s in rest[n_full * period:]:
        segments.append(Segment("plain", (s,), 1))
    return segments


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg, dtype):
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, Hq * Dh), dtype),
        "wk": dense_init(ks[1], (d, Hkv * Dh), dtype),
        "wv": dense_init(ks[2], (d, Hkv * Dh), dtype),
        "wo": dense_init(ks[3], (Hq * Dh, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _block_init(key, cfg, spec):
    block, ffn = spec
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": norm_init(d, cfg.norm, dtype)}
    if block == "attn":
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    elif block == "mla":
        p["attn"] = mla_init(ks[0], cfg, dtype)
    elif block == "rec":
        p["rec"] = rglru_init(ks[0], cfg, dtype)
    elif block == "rwkv":
        p["rwkv"] = rwkv6.rwkv_init(ks[0], cfg, dtype)
        p["ln2"] = norm_init(d, cfg.norm, dtype)
        return p
    if ffn != "none":
        p["ln2"] = norm_init(d, cfg.norm, dtype)
        if ffn == "moe":
            p["ffn"] = moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype)
    return p


# ---------------------------------------------------------------------------
# per-block cache init (zeros; decode dry-run lowers against these shapes)
# ---------------------------------------------------------------------------

def _block_cache(cfg, spec, B, T, dtype):
    block, _ = spec
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    if block == "attn":
        Tc = min(T, cfg.window) if cfg.window else T
        return {
            "k": jnp.zeros((B, Tc, Hkv, Dh), dtype),
            "v": jnp.zeros((B, Tc, Hkv, Dh), dtype),
        }
    if block == "mla":
        return {
            "ckv": jnp.zeros((B, T, cfg.kv_lora), dtype),
            "krope": jnp.zeros((B, T, cfg.qk_rope_dim), dtype),
        }
    if block == "rec":
        return {
            "h": jnp.zeros((B, cfg.d_rnn), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_rnn), dtype),
        }
    if block == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_dim
        K = cfg.rwkv_head_dim
        return {
            "state": jnp.zeros((B, H, K, K), jnp.float32),
            "sa": jnp.zeros((B, cfg.d_model), dtype),
            "sc": jnp.zeros((B, cfg.d_model), dtype),
        }
    raise ValueError(block)


def init_cache(cfg: ModelConfig, B: int, T: int):
    dtype = jnp.dtype(cfg.act_dtype)
    caches = []
    for seg in plan_segments(cfg):
        period = {
            f"sub{i}": _block_cache(cfg, spec, B, T, dtype)
            for i, spec in enumerate(seg.specs)
        }
        if seg.kind == "scan":
            period = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (seg.count,) + x.shape), period
            )
        caches.append(period)
    return caches


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------

def _ring_kpos(pos, Wd):
    s = jnp.arange(Wd, dtype=jnp.int32)
    return pos - ((pos - s) % Wd)


def _attn_qkv(p, x, positions, cfg):
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["wq"]).reshape(B, S, Hq, Dh)
    k = linear(x, p["wk"]).reshape(B, S, Hkv, Dh)
    v = linear(x, p["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = _head_rmsnorm(q, p["q_norm"])
        k = _head_rmsnorm(k, p["k_norm"])
    q = rope_apply(q, positions, cfg.rope_theta)
    k = rope_apply(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_block(p, x, cfg, positions, mode, cache, pos):
    B, S, _ = x.shape
    if mode != "decode":
        q, k, v = _attn_qkv(p, x, positions, cfg)
        out = attention(
            q, k, v,
            q_pos=positions, k_pos=positions,
            causal=cfg.causal, window=cfg.window, q_chunk=cfg.attn_q_chunk,
            chunk_remat=cfg.attn_chunk_remat,
        )
        y = linear(out.reshape(B, S, -1), p["wo"])
        new_cache = None
        if mode == "prefill":
            if cfg.window and cfg.window < S:          # ring buffer: last Wd keys
                Wd = cfg.window
                sel = np.arange(S - Wd, S)
                ring_k = jnp.zeros_like(cache["k"]).at[:, sel % Wd].set(k[:, sel])
                ring_v = jnp.zeros_like(cache["v"]).at[:, sel % Wd].set(v[:, sel])
                new_cache = {"k": ring_k, "v": ring_v}
            else:
                Tc = cache["k"].shape[1]
                new_cache = {
                    "k": lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                    "v": lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
                }
        return y, new_cache

    # ---- decode: one token at position ``pos`` ----------------------------
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _attn_qkv(p, x, positions, cfg)
    Tc = cache["k"].shape[1]
    if cfg.window and Tc == cfg.window:
        slot = pos % Tc
        k_pos = _ring_kpos(pos, Tc)
    else:
        slot = pos
        k_pos = jnp.arange(Tc, dtype=jnp.int32)
    ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                  (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                  (0, slot, 0, 0))
    out = attention(
        q, ck, cv,
        q_pos=positions, k_pos=k_pos,
        causal=True, window=None, q_chunk=cfg.attn_q_chunk,
    )
    y = linear(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": ck, "v": cv}


def _mla_block(p, x, cfg, positions, mode, cache, pos):
    if mode != "decode":
        y, (c_kv, k_rope) = mla_attention(p, x, positions, cfg)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "ckv": lax.dynamic_update_slice(
                    cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0)),
                "krope": lax.dynamic_update_slice(
                    cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0)),
            }
        return y, new_cache
    T = cache["ckv"].shape[1]
    k_pos = jnp.arange(T, dtype=jnp.int32)
    y, ckv, krope = mla_decode_absorbed(
        p, x, pos, cache["ckv"], cache["krope"], k_pos, cfg
    )
    return y, {"ckv": ckv, "krope": krope}


def _block_apply(p, x, spec, cfg, positions, mode, cache, pos):
    """Returns (x, new_cache, (lb_loss, z_loss))."""
    block, ffn = spec
    aux = (jnp.float32(0), jnp.float32(0))
    h = norm_apply(p["ln1"], x, cfg.norm)
    if block == "attn":
        y, new_cache = _attn_block(p["attn"], h, cfg, positions, mode, cache, pos)
    elif block == "mla":
        y, new_cache = _mla_block(p["attn"], h, cfg, positions, mode, cache, pos)
    elif block == "rec":
        if mode == "decode":
            y, hst, conv = rglru_decode_step(
                p["rec"], h, cache["h"], cache["conv"])
            new_cache = {"h": hst, "conv": conv}
        else:
            y, (hst, conv) = rglru_apply(p["rec"], h)
            new_cache = (
                {"h": hst, "conv": conv.astype(cache["conv"].dtype)}
                if mode == "prefill" else None
            )
    elif block == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_dim
        if mode == "decode":
            y, sa, state = rwkv6.rwkv_time_mix_step(
                p["rwkv"], h, H, cache["sa"], cache["state"])
            new_cache = {"state": state, "sa": sa}
        else:
            y, (sa, state) = rwkv6.rwkv_time_mix(p["rwkv"], h, H)
            new_cache = {"state": state, "sa": sa} if mode == "prefill" else None
        x = x + y
        h2 = norm_apply(p["ln2"], x, cfg.norm)
        if mode == "decode":
            y2, sc = rwkv6.rwkv_channel_mix_step(p["rwkv"], h2, cache["sc"])
            new_cache["sc"] = sc
        else:
            y2, sc = rwkv6.rwkv_channel_mix(p["rwkv"], h2)
            if mode == "prefill":
                new_cache["sc"] = sc
        if new_cache is None:
            new_cache = jnp.float32(0)  # placeholder: uniform scan pytree
        return x + y2, new_cache, aux
    else:
        raise ValueError(block)
    x = x + y

    if ffn != "none":
        h = norm_apply(p["ln2"], x, cfg.norm)
        if ffn == "moe":
            y, moe_aux = moe_apply(p["ffn"], h, cfg)
            aux = (moe_aux["moe_lb_loss"], moe_aux["moe_z_loss"])
        else:
            y = mlp_apply(p["ffn"], h, cfg.act)
        x = x + y
    if new_cache is None:   # placeholder keeps the scan pytree uniform
        new_cache = jnp.float32(0)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack apply
# ---------------------------------------------------------------------------

def make_constrainer(mesh):
    """Sequence/tensor activation-sharding constraint for the residual stream.

    Megatron-style sequence parallelism: between blocks the (B, S, d) residual
    shards batch over (pod, data) and sequence over "model" — in particular
    the per-layer remat checkpoints saved by the scan carry shrink by the
    model-axis size (the 25 GB -> ~1.6 GB temp fix measured in EXPERIMENTS.md
    §Perf).  XLA inserts the all-gather before attention and re-partitions
    after, the standard SP collective pattern.
    """
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P

    daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    msize = mesh.shape.get("model", 1)

    def constrain(x):
        if x.ndim != 3:
            return x
        B, S, _ = x.shape
        spec: list = [None, None, None]
        if B % dsize == 0 and B > 1:
            spec[0] = daxes
        elif S % dsize == 0 and S >= dsize:
            spec[1] = daxes
        if spec[1] is None and S % msize == 0 and S >= msize:
            spec[1] = "model"
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return constrain


def _period_apply(p_period, x, seg, cfg, positions, mode, cache_period, pos,
                  constrain=None):
    new_cache = {}
    lb = jnp.float32(0)
    z = jnp.float32(0)
    for i, spec in enumerate(seg.specs):
        sub = f"sub{i}"
        c = cache_period[sub] if cache_period is not None else None
        if constrain is not None:
            x = constrain(x)
        x, nc, (lb_i, z_i) = _block_apply(
            p_period[sub], x, spec, cfg, positions, mode, c, pos)
        new_cache[sub] = nc
        lb, z = lb + lb_i, z + z_i
    if constrain is not None:
        x = constrain(x)  # the scan carry (saved for backward) stays sharded
    return x, new_cache, (lb, z)


def apply_stack(params, x, cfg, positions, mode, caches=None, pos=None,
                constrain=None):
    """Run all segments. Returns (x, new_caches, aux)."""
    lb = jnp.float32(0)
    z = jnp.float32(0)
    new_caches = []
    use_cache = mode in ("prefill", "decode")
    for si, seg in enumerate(plan_segments(cfg)):
        p_seg = params["segments"][si]
        c_seg = caches[si] if caches is not None else None
        if seg.kind == "plain":
            x, nc, (lb_i, z_i) = _period_apply(
                p_seg, x, seg, cfg, positions, mode, c_seg, pos, constrain)
            lb, z = lb + lb_i, z + z_i
        else:
            def body(carry, xs):
                xc, lb_c, z_c = carry
                p_i, c_i = xs if use_cache else (xs, None)
                xc, nc_i, (lb_i, z_i) = _period_apply(
                    p_i, xc, seg, cfg, positions, mode, c_i, pos, constrain)
                return (xc, lb_c + lb_i, z_c + z_i), nc_i

            if cfg.remat and mode == "train":
                body = jax.checkpoint(body)
            xs = (p_seg, c_seg) if use_cache else p_seg
            (x, lb, z), nc = lax.scan(body, (x, lb, z), xs)
        new_caches.append(nc if use_cache else None)
    return x, (new_caches if use_cache else None), {"lb": lb, "z": z}
