"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (one "rec" residual block):
  x -> [gate branch: linear -> gelu] ⊙ [main: linear -> causal conv1d(width 4)
       -> RG-LRU] -> linear out

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_r x_t + b_r)          recurrence gate
  i_t = sigmoid(W_i x_t + b_i)          input gate
  a_t = exp(-c * softplus(Λ) * r_t),  c = 8
  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluate the linear recurrence with ``associative_scan``
(log-depth over sequence); decode carries (h, conv window) state exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

__all__ = ["rglru_init", "rglru_apply", "rglru_decode_step"]

_C = 8.0


def rglru_init(key, cfg, dtype):
    d, dr, cw = cfg.d_model, cfg.d_rnn, cfg.conv_width
    ks = jax.random.split(key, 7)
    # Λ init so a ~ uniform in [0.9, 0.999] at r=0.5 (griffin recipe, simplified)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, dr, dtype=jnp.float32)) * 2.0 / _C))
    return {
        "w_x": dense_init(ks[0], (d, dr), dtype),
        "w_gate": dense_init(ks[1], (d, dr), dtype),
        "conv_w": dense_init(ks[2], (cw, dr), dtype, scale=0.5),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": dense_init(ks[3], (dr, dr), dtype),
        "b_r": jnp.zeros((dr,), dtype),
        "w_i": dense_init(ks[4], (dr, dr), dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[5], (dr, d), dtype),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", u, p["w_r"].astype(u.dtype)).astype(jnp.float32)
        + p["b_r"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", u, p["w_i"].astype(u.dtype)).astype(jnp.float32)
        + p["b_i"].astype(jnp.float32)
    )
    a = jnp.exp(-_C * jax.nn.softplus(p["lam"]) * r)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return a, b


def _conv(p, u, state=None):
    """Causal depthwise conv along time. u (B,S,dr); state (B,cw-1,dr)|None."""
    cw = p["conv_w"].shape[0]
    pad = (
        jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
        if state is None
        else state.astype(u.dtype)
    )
    xp = jnp.concatenate([pad, u], axis=1)
    out = sum(
        xp[:, i : i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
        for i in range(cw)
    )
    return out + p["conv_b"].astype(u.dtype), xp[:, -(cw - 1):]


def rglru_apply(p, x, *, conv_state=None, h_state=None):
    """Full-sequence block. x (B,S,d) -> (y (B,S,d), (h_last, conv_state))."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype))
    u, conv_state = _conv(p, u, conv_state)

    a, b = _gates(p, u)                       # (B,S,dr) fp32
    if h_state is not None:                    # inject carried state as step 0
        b = b.at[:, 0].add(a[:, 0] * h_state.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate)
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return y, (h[:, -1], conv_state)


def rglru_decode_step(p, x, h_state, conv_state):
    """One-token step. x (B,1,d); h (B,dr); conv (B,cw-1,dr)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype))
    u, conv_state = _conv(p, u, conv_state)
    a, b = _gates(p, u)                        # (B,1,dr)
    h = a[:, 0] * h_state.astype(jnp.float32) + b[:, 0]
    y = (h[:, None].astype(x.dtype) * gate)
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return y, h, conv_state
