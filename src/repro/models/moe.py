"""Top-k routed Mixture-of-Experts with token-chunked GShard dispatch.

Design notes (DESIGN.md §5):
* dispatch/combine are the classic one-hot einsum formulation — it SPMD-
  partitions predictably (token dim over "data", expert dim over "model" when
  divisible) — but evaluated under a ``lax.scan`` over token chunks of
  ``cfg.moe_chunk`` so the (tokens x experts x capacity) transient stays
  bounded regardless of batch x seq;
* capacity is per chunk: C = ceil(chunk * top_k * capacity_factor / E);
  overflowing tokens are dropped (pass through the residual stream), the
  standard "dropping" MoE semantics;
* router: softmax over all experts -> top-k -> renormalized gates; an
  auxiliary load-balance loss (Switch-style) and router z-loss are returned.
* shared experts (DeepSeek-V2) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, mlp_apply, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype, scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, ff), dtype),
        "w_up": dense_init(ks[2], (E, d, ff), dtype),
        "w_down": dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(
            ks[4], d, ff * cfg.n_shared_experts, "silu", dtype
        )
    return p


def _route(p, xc, cfg):
    """Router + per-choice expert slot positions. Shared by both dispatchers."""
    Nc, _ = xc.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, -(-int(Nc * k * cfg.capacity_factor) // E))
    logits = jnp.einsum("nd,de->ne", xc, p["router"].astype(xc.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, k)            # (Nc, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    iota_e = jnp.arange(E, dtype=jnp.int32)
    base = jnp.zeros((E,), jnp.int32)
    routes = []
    for j in range(k):                                     # choice-major priority
        e_j = expert_idx[:, j]
        oh_e = (e_j[:, None] == iota_e[None, :])           # (Nc, E)
        pos = jnp.cumsum(oh_e.astype(jnp.int32), axis=0) - 1 + base[None, :]
        pos_tok = jnp.sum(jnp.where(oh_e, pos, 0), axis=1)  # (Nc,)
        base = base + jnp.sum(oh_e.astype(jnp.int32), axis=0)
        routes.append((e_j, pos_tok, pos_tok < C))
    # aux-loss stats
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (expert_idx[:, 0][:, None] == iota_e[None, :]).astype(jnp.float32), axis=0
    )
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return C, gate_vals, routes, lb_loss, z_loss


def _experts_ffn(p, xe, dtype):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dtype))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dtype))


def _chunk_moe(p, xc, cfg):
    """One token chunk: (Nc, d) -> (Nc, d), plus aux-loss stats.

    Two dispatchers (cfg.moe_dispatch):
    * "einsum"  — GShard one-hot (Nc, E, C) dispatch/combine masks.  SPMD-
                  predictable (contraction -> all-reduce over data) but moves
                  O(Nc*E*C) mask bytes per chunk.
    * "scatter" — index-based: tokens scatter-add into the (E*C, d) buffer and
                  gather back.  O(Nc*d*k) traffic — the §Perf iteration that
                  removes the mask traffic entirely (EXPERIMENTS.md).
    """
    Nc, d = xc.shape
    E, k = cfg.n_experts, cfg.top_k
    C, gate_vals, routes, lb_loss, z_loss = _route(p, xc, cfg)

    if cfg.moe_dispatch == "scatter":
        buf = jnp.zeros((E * C, d), xc.dtype)
        for j, (e_j, pos_tok, keep) in enumerate(routes):
            slot = jnp.where(keep, e_j * C + pos_tok, E * C)  # OOB -> dropped
            buf = buf.at[slot].add(xc, mode="drop")
        ye = _experts_ffn(p, buf.reshape(E, C, d), xc.dtype).reshape(E * C, d)
        yc = jnp.zeros((Nc, d), xc.dtype)
        for j, (e_j, pos_tok, keep) in enumerate(routes):
            slot = jnp.clip(e_j * C + pos_tok, 0, E * C - 1)
            g = (gate_vals[:, j] * keep).astype(xc.dtype)
            yc = yc + ye[slot] * g[:, None]
        return yc, lb_loss, z_loss

    iota_c = jnp.arange(C, dtype=jnp.int32)
    iota_e = jnp.arange(E, dtype=jnp.int32)
    dispatch = jnp.zeros((Nc, E, C), jnp.bool_)
    combine = jnp.zeros((Nc, E, C), jnp.float32)
    for j, (e_j, pos_tok, keep) in enumerate(routes):
        oh_e = e_j[:, None] == iota_e[None, :]
        oh_c = (pos_tok[:, None] == iota_c[None, :]) & keep[:, None]
        dm = oh_e[:, :, None] & oh_c[:, None, :]
        dispatch = dispatch | dm
        combine = combine + dm * gate_vals[:, j, None, None]

    xe = jnp.einsum("nec,nd->ecd", dispatch.astype(xc.dtype), xc)
    ye = _experts_ffn(p, xe, xc.dtype)
    yc = jnp.einsum("nec,ecd->nd", combine.astype(xc.dtype), ye)
    return yc, lb_loss, z_loss


def _grouped_chunk_moe(p, xc, cfg):
    """Grouped (GShard-style) chunk: (B, Sc, d) -> (B, Sc, d) + aux.

    Routing, slot positions and capacity are PER BATCH ROW: the position
    cumsum runs along the (unsharded) sequence axis, so with batch sharded
    over (pod, data) the router never communicates — this removed the
    ~9 TB/step of routing all-gathers measured on mixtral prefill_32k
    (EXPERIMENTS.md §Perf).  Capacity C = ceil(Sc * k * cf / E) per row,
    the classic GShard "group" semantics.
    """
    B, Sc, d = xc.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, -(-int(Sc * k * cfg.capacity_factor) // E))

    logits = jnp.einsum("bsd,de->bse", xc, p["router"].astype(xc.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, k)            # (B, Sc, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    iota_e = jnp.arange(E, dtype=jnp.int32)
    iota_c = jnp.arange(C, dtype=jnp.int32)
    base = jnp.zeros((B, E), jnp.int32)
    dispatch = jnp.zeros((B, Sc, E, C), jnp.bool_)
    combine = jnp.zeros((B, Sc, E, C), jnp.float32)
    for j in range(k):
        e_j = expert_idx[..., j]                            # (B, Sc)
        oh_e = e_j[..., None] == iota_e                     # (B, Sc, E)
        pos = jnp.cumsum(oh_e.astype(jnp.int32), axis=1) - 1 + base[:, None]
        pos_tok = jnp.sum(jnp.where(oh_e, pos, 0), axis=-1)  # (B, Sc)
        base = base + jnp.sum(oh_e.astype(jnp.int32), axis=1)
        keep = pos_tok < C
        oh_c = (pos_tok[..., None] == iota_c) & keep[..., None]
        dm = oh_e[..., None] & oh_c[:, :, None, :]
        dispatch = dispatch | dm
        combine = combine + dm * gate_vals[..., j, None, None]

    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(xc.dtype), xc)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(xc.dtype)))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(xc.dtype))
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(xc.dtype))
    yc = jnp.einsum("bsec,becd->bsd", combine.astype(xc.dtype), ye)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        (expert_idx[..., 0][..., None] == iota_e).astype(jnp.float32), axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return yc, lb_loss, z_loss


def moe_apply(p, x, cfg):
    """x (B, S, d) -> (y (B, S, d), aux dict of scalar losses)."""
    B, S, d = x.shape

    if cfg.moe_group == "seq":
        # grouped routing: chunk along sequence, batch stays sharded
        Sc = max(1, min(cfg.moe_group_seq, S))
        n_chunks = -(-S // Sc)
        pad = n_chunks * Sc - S
        xg = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
        xs = xg.reshape(B, n_chunks, Sc, d).swapaxes(0, 1)

        def body(carry, xc):
            lb, z = carry
            yc, lb_c, z_c = _grouped_chunk_moe(p, xc, cfg)
            return (lb + lb_c, z + z_c), yc

        if cfg.moe_remat:
            body = jax.checkpoint(body)
        (lb, z), ys = lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
        y = ys.swapaxes(0, 1).reshape(B, n_chunks * Sc, d)[:, :S]
    else:
        N = B * S
        chunk = min(cfg.moe_chunk, N)
        n_chunks = -(-N // chunk)
        pad = n_chunks * chunk - N
        xf = x.reshape(N, d)
        if pad:
            xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)])
        xs = xf.reshape(n_chunks, chunk, d)

        def body(carry, xc):
            lb, z = carry
            yc, lb_c, z_c = _chunk_moe(p, xc, cfg)
            return (lb + lb_c, z + z_c), yc

        if cfg.moe_remat:
            # §Perf: the chunk scan otherwise SAVES every chunk's (Nc,E,C)
            # dispatch/combine masks and (E,C,d) buffers for backward — the
            # dominant HBM term on deepseek-v2 train_4k (EXPERIMENTS.md).
            body = jax.checkpoint(body)
        (lb, z), ys = lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
        y = ys.reshape(n_chunks * chunk, d)[:N].reshape(B, S, d)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, "silu")

    aux = {"moe_lb_loss": lb / n_chunks, "moe_z_loss": z / n_chunks}
    return y, aux
