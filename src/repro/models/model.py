"""Top-level model API: init / loss / forward / prefill / decode_step.

Batch conventions (all int32 unless noted):
  LM (dense/moe/rwkv/hybrid): {"tokens": (B,S), "labels": (B,S)}
  VLM:     {"tokens": (B,S_text), "labels": (B,S_text),
            "patches": (B, n_patches, d_frontend) act-dtype}
  encoder: {"frames": (B,S,d_frontend) act-dtype, "labels": (B,S)}

Labels < 0 are ignored in the loss.  Logits are computed in sequence chunks
(``cfg.logits_chunk``) so the (B,S,V) tensor never materializes — with 150k
vocabularies this is the difference between fitting and not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, norm_apply, norm_init, use_sharding_mesh
from repro.models.transformer import (
    apply_stack,
    init_cache,
    make_constrainer,
    plan_segments,
    _block_init,
)

__all__ = ["Model", "build_model"]


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh

    @property
    def _constrain(self):
        return make_constrainer(self.mesh)

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(rng, 8)
        Vp = cfg.padded_vocab
        params: dict = {}
        if cfg.family != "encoder":
            params["embed"] = dense_init(keys[0], (Vp, cfg.d_model), dtype, scale=0.02)
        if cfg.frontend:
            params["frontend"] = {
                "proj": dense_init(keys[1], (cfg.d_frontend, cfg.d_model), dtype)
            }
        segs = plan_segments(cfg)
        seg_keys = jax.random.split(keys[2], len(segs))
        seg_params = []
        for seg, sk in zip(segs, seg_keys):
            def one(k):
                sub_keys = jax.random.split(k, len(seg.specs))
                return {
                    f"sub{i}": _block_init(sub_keys[i], cfg, spec)
                    for i, spec in enumerate(seg.specs)
                }
            if seg.kind == "scan":
                seg_params.append(jax.vmap(one)(jax.random.split(sk, seg.count)))
            else:
                seg_params.append(one(sk))
        params["segments"] = seg_params
        params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        params["lm_head"] = dense_init(keys[3], (cfg.d_model, Vp), dtype)
        return params

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.act_dtype)
        if cfg.family == "encoder":
            x = jnp.einsum(
                "bsf,fd->bsd", batch["frames"].astype(dtype),
                params["frontend"]["proj"].astype(dtype),
            )
            return x, 0
        tok = params["embed"].astype(dtype)[batch["tokens"]]
        if cfg.family == "vlm":
            patches = jnp.einsum(
                "bpf,fd->bpd", batch["patches"].astype(dtype),
                params["frontend"]["proj"].astype(dtype),
            )
            return jnp.concatenate([patches, tok], axis=1), cfg.n_patches
        return tok, 0

    # ------------------------------------------------------------ logits/loss
    def _logits(self, params, x):
        cfg = self.cfg
        head = params["lm_head"].astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab:
            # mask phantom vocab entries ELEMENTWISE: an .at[...].set on the
            # vocab-sharded dim makes SPMD all-gather full-vocab logits
            # (2x 10 GB/device measured on qwen3-4b; EXPERIMENTS.md §Perf)
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(valid, logits, -1e30)
        return logits

    def loss(self, params, batch):
        """Mean next-token (or frame-label) CE + aux losses. Returns (loss, metrics)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        with use_sharding_mesh(self.mesh):
            x, _, aux = apply_stack(params, x, cfg, positions, "train",
                                    constrain=self._constrain)
        x = norm_apply(params["final_norm"], x, cfg.norm)
        if n_prefix:
            x = x[:, n_prefix:]
        labels = batch["labels"]
        if cfg.family != "encoder":            # next-token shift
            x, labels = x[:, :-1], labels[:, 1:]

        B, St, d = x.shape
        chunk = min(cfg.logits_chunk, St)
        n_chunks = -(-St // chunk)
        pad = n_chunks * chunk - St
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        xs = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
        ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

        def body(carry, xs_i):
            tot, cnt = carry
            xc, lc = xs_i
            logits = self._logits(params, xc)
            valid = lc >= 0
            lp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(
                lp, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            tot = tot + jnp.sum(jnp.where(valid, -ll, 0.0))
            cnt = cnt + jnp.sum(valid)
            return (tot, cnt), None

        (tot, cnt), _ = lax.scan(body, (jnp.float32(0), jnp.int32(0)), (xs, ls))
        ce = tot / jnp.maximum(cnt, 1).astype(jnp.float32)
        loss = ce + 0.01 * aux["lb"] + 1e-3 * aux["z"]
        return loss, {"ce": ce, "lb_loss": aux["lb"], "z_loss": aux["z"],
                      "tokens": cnt}

    # --------------------------------------------------------------- forward
    def forward(self, params, batch):
        """Full-sequence logits (small-model utility / tests)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        with use_sharding_mesh(self.mesh):
            x, _, _ = apply_stack(params, x, cfg, positions, "forward",
                                  constrain=self._constrain)
        x = norm_apply(params["final_norm"], x, cfg.norm)
        if n_prefix:
            x = x[:, n_prefix:]
        return self._logits(params, x)

    # ------------------------------------------------------------- serving
    def init_cache(self, B: int, T: int):
        return init_cache(self.cfg, B, T)

    def prefill(self, params, batch, T: int):
        """Process the prompt; returns (caches, last-position logits)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        caches = self.init_cache(x.shape[0], T)
        with use_sharding_mesh(self.mesh):
            x, caches, _ = apply_stack(params, x, cfg, positions, "prefill",
                                       caches, constrain=self._constrain)
        x = norm_apply(params["final_norm"], x, cfg.norm)
        return caches, self._logits(params, x[:, -1:])[:, 0]

    def decode_step(self, params, caches, token, pos):
        """One decode step. token (B,1) int32; pos scalar int32."""
        cfg = self.cfg
        x = params["embed"].astype(jnp.dtype(cfg.act_dtype))[token]
        positions = jnp.full((1,), pos, jnp.int32)
        with use_sharding_mesh(self.mesh):
            x, caches, _ = apply_stack(
                params, x, cfg, positions, "decode", caches, pos=pos,
                constrain=self._constrain)
        x = norm_apply(params["final_norm"], x, cfg.norm)
        return caches, self._logits(params, x)[:, 0]

    # ---------------------------------------------------------- input specs
    def input_specs(self, batch_size: int, seq_len: int, mode: str = "train"):
        """ShapeDtypeStruct stand-ins for dry-run lowering (no allocation)."""
        cfg = self.cfg
        i32 = jnp.int32
        act = jnp.dtype(cfg.act_dtype)
        sds = jax.ShapeDtypeStruct
        if mode in ("train", "forward", "prefill"):
            want_labels = mode != "prefill"
            if cfg.family == "encoder":
                out = {"frames": sds((batch_size, seq_len, cfg.d_frontend), act)}
                if want_labels:
                    out["labels"] = sds((batch_size, seq_len), i32)
                return out
            if cfg.family == "vlm":
                s_text = seq_len - cfg.n_patches
                out = {
                    "tokens": sds((batch_size, s_text), i32),
                    "patches": sds((batch_size, cfg.n_patches, cfg.d_frontend), act),
                }
                if want_labels:
                    out["labels"] = sds((batch_size, s_text), i32)
                return out
            out = {"tokens": sds((batch_size, seq_len), i32)}
            if want_labels:
                out["labels"] = sds((batch_size, seq_len), i32)
            return out
        if mode == "decode":
            caches = jax.eval_shape(
                lambda: self.init_cache(batch_size, seq_len))
            return {
                "caches": caches,
                "token": sds((batch_size, 1), i32),
            }
        raise ValueError(mode)


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    return Model(cfg, mesh=mesh)
