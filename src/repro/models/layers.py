"""Common transformer building blocks (pure JAX, dict-pytree params).

Conventions:
* params are nested dicts of jnp arrays; layer stacks hold leaves with a
  leading ``L`` axis consumed by ``lax.scan`` (constant compile time in depth);
* activations run in ``cfg.act_dtype``; norms/softmax accumulate in fp32;
* attention is q-chunked (scan over query blocks) above ``cfg.attn_q_chunk``
  so prefill_32k never materializes an (S x S) score tensor.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "dense_init",
    "linear",
    "norm_apply",
    "norm_init",
    "rope_apply",
    "attention",
    "mlp_init",
    "mlp_apply",
    "use_sharding_mesh",
    "shard_heads",
]

# Mesh context for activation-sharding constraints inside attention.  Set by
# Model methods (see model.py) at trace time; None on single-device runs.
_MESH_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_mesh",
                                                           default=None)


@contextlib.contextmanager
def use_sharding_mesh(mesh):
    tok = _MESH_CTX.set(mesh)
    try:
        yield
    finally:
        _MESH_CTX.reset(tok)


def shard_heads(x):
    """Constrain (B, S, H, D): batch->(pod,data), heads->model (else D)."""
    mesh = _MESH_CTX.get()
    if mesh is None or x.ndim != 4:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    msize = mesh.shape.get("model", 1)
    B, S, H, D = x.shape
    spec: list = [None, None, None, None]
    if B % dsize == 0 and B > 1:
        spec[0] = daxes
    elif S % dsize == 0 and S >= dsize:
        spec[1] = daxes
    if H % msize == 0 and H >= msize:
        spec[2] = "model"
    elif D % msize == 0 and D >= msize:
        spec[3] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# init / linear
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in) unless given)."""
    fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    std = scale if scale is not None else 1.0 / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
            ).astype(dtype)


def linear(x, w):
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    xf = (xf - mean) * lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def _head_rmsnorm(x, scale, eps: float = 1e-6):
    """qk_norm (Qwen3): RMSNorm over head_dim, scale shared across heads."""
    xf = x.astype(jnp.float32)
    xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_apply(x, positions, theta: float):
    """Rotate (..., S, H, D) by absolute ``positions`` (shape (S,))."""
    d = x.shape[-1]
    half = d // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA/MQA, causal / bidirectional / sliding-window, q-chunked)
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, q_pos, k_pos, causal, window, scale):
    """q (B,Sq,H,D), k (B,Sk,H,D), v (B,Sk,H,Dv) -> (B,Sq,H,Dv).

    Heads are pre-expanded to Hq (GQA kv repeated) so every tensor including
    the fp32 score block shards over "model" on the heads axis — the Megatron
    TP layout; without it the (B,H,Sq,Sk) block replicates 16x.
    """
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    valid = (k_pos >= 0)[None, :]                       # (1, Sk); -1 = unfilled
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
    scores = jnp.where(valid[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (e.g. empty cache at pos 0) -> zero output, not uniform
    any_valid = jnp.any(valid, axis=-1)                 # (Sq,) or (1,)
    probs = probs * any_valid[None, None, :, None]
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def attention(
    q,                    # (B, Sq, Hq, D)
    k,                    # (B, Sk, Hkv, D)
    v,                    # (B, Sk, Hkv, Dv)
    *,
    q_pos,                # (Sq,) absolute positions
    k_pos,                # (Sk,) absolute positions; -1 marks unfilled slots
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 1024,
    scale: float | None = None,
    chunk_remat: bool = False,
):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    if G > 1:  # expand kv to Hq heads: fully head-shardable attention
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q, k, v = shard_heads(q), shard_heads(k), shard_heads(v)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        out = _attend_block(q, k, v, q_pos, k_pos, causal, window, scale)
    else:
        nch = Sq // q_chunk
        qs = q.reshape(B, nch, q_chunk, Hq, D).transpose(1, 0, 2, 3, 4)
        ps = q_pos.reshape(nch, q_chunk)

        def body(_, xs):
            qc, pc = xs
            return None, _attend_block(qc, k, v, pc, k_pos, causal, window, scale)

        if chunk_remat:
            # §Perf: otherwise the chunk scan saves every chunk's fp32 score
            # block (nch, B, H, qc, Sk) for its backward pass
            body = jax.checkpoint(body)
        _, outs = lax.scan(body, None, (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, -1)
    return out


# ---------------------------------------------------------------------------
# MLPs (SwiGLU for silu, plain 2-layer for gelu)
# ---------------------------------------------------------------------------

def mlp_init(key, d, d_ff, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "silu":
        return {
            "w_gate": dense_init(ks[0], (d, d_ff), dtype),
            "w_up": dense_init(ks[1], (d, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d), dtype),
    }


def mlp_apply(p, x, act: str):
    if act == "silu":
        return linear(jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"]),
                      p["w_down"])
    return linear(jax.nn.gelu(linear(x, p["w_up"])), p["w_down"])
