"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay linear RNN.

Time-mix recurrence per head (K = V = head dim):
  S_t   = diag(w_t) S_{t-1} + k_t^T v_t
  out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with the *data-dependent* per-channel decay w_t = exp(-exp(w0 + lora(m_w)))
— the paper's headline contribution — plus token-shift lerps and a gated
output.  (We keep the decay LoRA faithful; the 5-way stacked ddlerp LoRA of
the reference implementation is simplified to static lerp mixes, noted in
DESIGN.md §deviations.)

Training/prefill use the standard chunked formulation (intra-chunk attention
in log-decay space + inter-chunk state scan) — O(T/C) sequential steps, state
(B, H, K, V) only.  Exponents are computed in fp32 with a clamp at ±60:
contributions needing larger magnitudes pair with factors <= e^-60 and are
exactly 0 in the limit, so the clamp is numerically inert.  Decode runs the
exact recurrence (O(1) per token) — long_500k's sub-quadratic path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

__all__ = ["rwkv_init", "rwkv_time_mix", "rwkv_time_mix_step", "rwkv_channel_mix",
           "rwkv_channel_mix_step"]

_CHUNK = 32
_CLAMP = 60.0


def rwkv_init(key, cfg, dtype):
    d, dk = cfg.d_model, cfg.rwkv_head_dim
    H = d // dk
    r = cfg.rwkv_lora
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), dtype),             # lerp for w,k,v,r,g
        "w0": jnp.full((d,), -1.0, jnp.float32),          # decay bias (pre exp-exp)
        "wA": dense_init(ks[0], (d, r), dtype, scale=0.01),
        "wB": dense_init(ks[1], (r, d), dtype, scale=0.01),
        "u": dense_init(ks[2], (H, dk), jnp.float32, scale=0.5),
        "Wr": dense_init(ks[3], (d, d), dtype),
        "Wk": dense_init(ks[4], (d, d), dtype),
        "Wv": dense_init(ks[5], (d, d), dtype),
        "Wg": dense_init(ks[6], (d, d), dtype),
        "Wo": dense_init(ks[7], (d, d), dtype),
        "gn_scale": jnp.ones((d,), jnp.float32),
        # channel-mix
        "cmu": 0.5 * jnp.ones((2, d), dtype),             # lerp for k,r
        "Ck": dense_init(ks[8], (d, cfg.d_ff), dtype),
        "Cv": dense_init(ks[9], (cfg.d_ff, d), dtype),
        "Cr": dense_init(ks[10], (d, d), dtype),
    }


def _shift(x, carry):
    """Token shift: previous token's activations (carry = last of prev call)."""
    prev = jnp.concatenate([carry[:, None], x[:, :-1]], axis=1)
    return prev


def _project(p, x, xx):
    """Lerped projections -> (lw (fp32 log-decay), k, v, r, g)."""
    mu = p["mu"].astype(x.dtype)
    m = [x + (xx - x) * mu[i] for i in range(5)]
    lora = jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", m[0], p["wA"].astype(x.dtype))),
        p["wB"].astype(x.dtype),
    )
    lw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    lw = jnp.clip(lw, -8.0, -1e-6)                        # log w_t in (-8, 0)
    k = jnp.einsum("bsd,de->bse", m[1], p["Wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", m[2], p["Wv"].astype(x.dtype))
    r = jnp.einsum("bsd,de->bse", m[3], p["Wr"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", m[4], p["Wg"].astype(x.dtype))
    return lw, k, v, r, g


def _heads(x, H):
    B, S, d = x.shape
    return x.reshape(B, S, H, d // H)


def _group_norm(x, scale, eps=1e-5):
    """Per-head LayerNorm on (B, S, H, K) -> flattened (B, S, d)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    xf = (xf - mean) * lax.rsqrt(var + eps)
    B, S, H, K = x.shape
    return (xf.reshape(B, S, H * K) * scale).astype(x.dtype)


def _chunk_wkv(r, k, v, lw, u, state):
    """One chunk: r/k/v (B,H,L,K), lw fp32 (B,H,L,K), state (B,H,K,V)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    Lcum = jnp.cumsum(lw, axis=2)                          # inclusive
    Lprev = Lcum - lw                                       # exclusive (L_{t-1})
    r_t = rf * jnp.exp(Lprev)                               # decayed queries
    k_t = kf * jnp.exp(jnp.clip(-Lcum, None, _CLAMP))       # amplified keys
    A = jnp.einsum("bhtk,bhsk->bhts", r_t, k_t)             # intra-chunk scores
    L = r.shape[2]
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)            # strictly causal
    A = jnp.where(tri[None, None], A, 0.0)
    diag = jnp.einsum("bhtk,bhtk->bht", rf * u[None, :, None, :], kf)
    out = jnp.einsum("bhts,bhsv->bhtv", A, vf)
    out = out + diag[..., None] * vf
    out = out + jnp.einsum("bhtk,bhkv->bhtv", r_t, state)   # inter-chunk
    # end-of-chunk state: S_L = diag(D_L) S_0 + sum_s diag(exp(L_L - L_s)) k_s v_s
    Dlast = Lcum[:, :, -1:, :]                              # (B,H,1,K)
    kd = kf * jnp.exp(Dlast - Lcum)                         # exponent <= 0
    new_state = state * jnp.exp(Dlast[:, :, 0, :, None]) + jnp.einsum(
        "bhsk,bhsv->bhkv", kd, vf
    )
    return out, new_state


def rwkv_time_mix(p, x, H, *, shift_carry=None, state=None):
    """Full-sequence time-mix. x (B,S,d). Returns (y, (last_x, state))."""
    B, S, d = x.shape
    K = d // H
    carry = shift_carry if shift_carry is not None else jnp.zeros((B, d), x.dtype)
    xx = _shift(x, carry)
    lw, k, v, r, g = _project(p, x, xx)

    # pad to chunk multiple
    L = _CHUNK
    n = -(-S // L)
    pad = n * L - S
    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0))) if pad else t
    lw_, k_, v_, r_ = (pad_t(t) for t in (lw, k, v, r))
    # (B,S,d) -> (n, B, H, L, K)
    def chunks(t):
        return t.reshape(B, n, L, H, K).transpose(1, 0, 3, 2, 4)
    lwc = chunks(lw_.astype(jnp.float32))
    kc, vc, rc = chunks(k_), chunks(v_), chunks(r_)
    # padded steps must not decay or contribute: lw=0, k=0
    if pad:
        mask = (jnp.arange(n * L) < S).reshape(n, 1, 1, L, 1)
        lwc = lwc * mask
        kc = kc * mask

    s0 = state if state is not None else jnp.zeros((B, H, K, K), jnp.float32)

    def body(s, xs):
        lw_i, k_i, v_i, r_i = xs
        out, s = _chunk_wkv(r_i, k_i, v_i, lw_i, p["u"], s)
        return s, out

    s_last, outs = lax.scan(body, s0, (lwc, kc, vc, rc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, n * L, H, K)[:, :S]
    y = _group_norm(out, p["gn_scale"]).astype(x.dtype) * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", y, p["Wo"].astype(x.dtype))
    return y, (x[:, -1], s_last)


def rwkv_time_mix_step(p, x, H, shift_carry, state):
    """Exact one-token recurrence. x (B,1,d); state (B,H,K,V) fp32."""
    B, _, d = x.shape
    K = d // H
    xx = shift_carry[:, None]
    lw, k, v, r, g = _project(p, x, xx)
    w = jnp.exp(lw[:, 0].reshape(B, H, K))                  # (B,H,K)
    kh = k[:, 0].reshape(B, H, K).astype(jnp.float32)
    vh = v[:, 0].reshape(B, H, K).astype(jnp.float32)
    rh = r[:, 0].reshape(B, H, K).astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    out = jnp.einsum("bhk,bhkv->bhv", rh, state + p["u"][None, :, :, None] * kv)
    new_state = state * w[..., None] + kv
    y = _group_norm(out.reshape(B, 1, H, K), p["gn_scale"]).astype(x.dtype) \
        * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", y, p["Wo"].astype(x.dtype))
    return y, x[:, -1], new_state


def rwkv_channel_mix(p, x, *, shift_carry=None):
    B, S, d = x.shape
    carry = shift_carry if shift_carry is not None else jnp.zeros((B, d), x.dtype)
    xx = _shift(x, carry)
    cmu = p["cmu"].astype(x.dtype)
    mk = x + (xx - x) * cmu[0]
    mr = x + (xx - x) * cmu[1]
    kk = jnp.einsum("bsd,df->bsf", mk, p["Ck"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["Cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mr, p["Cr"].astype(x.dtype)))
    return rr * vv, x[:, -1]


def rwkv_channel_mix_step(p, x, shift_carry):
    y, last = rwkv_channel_mix(p, x, shift_carry=shift_carry)
    return y, last
