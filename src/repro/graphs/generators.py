"""Synthetic graph generators standing in for the UF Sparse Matrix Collection.

The container has no network access, so the real-world graphs of the paper's
Table 1 are replaced by generators matched on the published (n, m, d̄, σ)
statistics; see ``suite.py`` for the mapping.  All generators return clean
(undirected, deduped, self-loop-free, sorted) ``CSRGraph`` objects.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph, csr_from_edges

__all__ = [
    "erdos_renyi",
    "grid2d",
    "grid3d",
    "stencil27",
    "honeycomb",
    "road",
    "small_world",
    "power_law",
    "jacobian_band",
    "jacobian_tall_skinny",
]


def erdos_renyi(n: int, avg_degree: float = 10.0, seed: int = 0) -> CSRGraph:
    m = int(n * avg_degree / 2)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return csr_from_edges(n, src, dst)


def grid2d(rows: int, cols: int, diagonals: bool = False) -> CSRGraph:
    """2D grid; 4-point (d̄≈4) or 8-point (d̄≈8) stencil."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    pairs = [
        (idx[:, :-1].ravel(), idx[:, 1:].ravel()),
        (idx[:-1, :].ravel(), idx[1:, :].ravel()),
    ]
    if diagonals:
        pairs += [
            (idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()),
            (idx[:-1, 1:].ravel(), idx[1:, :-1].ravel()),
        ]
    src = np.concatenate([p[0] for p in pairs])
    dst = np.concatenate([p[1] for p in pairs])
    return csr_from_edges(rows * cols, src, dst)


def grid3d(nx: int, ny: int, nz: int) -> CSRGraph:
    """3D 7-point stencil (d̄≈6, tiny variance) — atmosphere/FEM-like."""
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    pairs = [
        (idx[:-1].ravel(), idx[1:].ravel()),
        (idx[:, :-1].ravel(), idx[:, 1:].ravel()),
        (idx[:, :, :-1].ravel(), idx[:, :, 1:].ravel()),
    ]
    src = np.concatenate([p[0] for p in pairs])
    dst = np.concatenate([p[1] for p in pairs])
    return csr_from_edges(nx * ny * nz, src, dst)


def stencil27(nx: int, ny: int, nz: int) -> CSRGraph:
    """3D 27-point stencil (d̄≈26) — nlpkkt-like high-degree regular graph."""
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    srcs, dsts = [], []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if (dx, dy, dz) <= (0, 0, 0):
                    continue  # half the shifts; symmetrize adds the rest
                sx = slice(max(0, -dx), min(nx, nx - dx))
                sy = slice(max(0, -dy), min(ny, ny - dy))
                sz = slice(max(0, -dz), min(nz, nz - dz))
                tx = slice(max(0, dx), min(nx, nx + dx))
                ty = slice(max(0, dy), min(ny, ny + dy))
                tz = slice(max(0, dz), min(nz, nz + dz))
                srcs.append(idx[sx, sy, sz].ravel())
                dsts.append(idx[tx, ty, tz].ravel())
    return csr_from_edges(nx * ny * nz, np.concatenate(srcs), np.concatenate(dsts))


def honeycomb(rows: int, cols: int) -> CSRGraph:
    """Honeycomb lattice: every interior vertex has degree exactly 3 (σ≈0)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    # brick-wall representation of a hex lattice on a grid
    src = [idx[:, :-1].ravel()]
    dst = [idx[:, 1:].ravel()]
    r, c = np.meshgrid(np.arange(rows - 1), np.arange(cols), indexing="ij")
    keep = (r + c) % 2 == 0
    src.append(idx[:-1, :][keep].ravel())
    dst.append(idx[1:, :][keep].ravel())
    return csr_from_edges(rows * cols, np.concatenate(src), np.concatenate(dst))


def road(n: int, shortcut_frac: float = 0.05, seed: int = 0) -> CSRGraph:
    """Road-network-like: long path + a few shortcuts (d̄≈2.1, σ small)."""
    rng = np.random.default_rng(seed)
    src = [np.arange(n - 1)]
    dst = [np.arange(1, n)]
    k = int(n * shortcut_frac)
    src.append(rng.integers(0, n, size=k))
    dst.append(rng.integers(0, n, size=k))
    return csr_from_edges(n, np.concatenate(src), np.concatenate(dst))


def small_world(n: int, k: int = 6, rewire: float = 0.1, seed: int = 0) -> CSRGraph:
    """Watts–Strogatz ring lattice with rewiring — circuit-sim-like."""
    rng = np.random.default_rng(seed)
    base = np.arange(n)
    srcs, dsts = [], []
    for off in range(1, k // 2 + 1):
        dst = (base + off) % n
        flip = rng.random(n) < rewire
        dst = np.where(flip, rng.integers(0, n, size=n), dst)
        srcs.append(base)
        dsts.append(dst)
    return csr_from_edges(n, np.concatenate(srcs), np.concatenate(dsts))


def power_law(n: int, avg_degree: float = 7.0, exponent: float = 2.2, seed: int = 0) -> CSRGraph:
    """Chung–Lu power-law graph — kkt_power/ASIC-like skewed degrees."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1) ** (-1.0 / (exponent - 1.0)))
    w *= (n * avg_degree / 2) / w.sum()
    p = w / w.sum()
    m = int(n * avg_degree / 2)
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    return csr_from_edges(n, src, dst)


# -- Jacobian sparsity patterns (bipartite, for repro.d2) --------------------

def jacobian_band(n_rows: int, band: int = 2, n_cols: int | None = None):
    """Banded Jacobian pattern: row i is nonzero in columns [i-band, i+band].

    The classic finite-difference stencil Jacobian.  Any interior row holds
    ``2·band+1`` pairwise-conflicting columns (a clique), and columns with
    equal index mod ``2·band+1`` never share a row, so the optimal column
    count is exactly ``min(2·band+1, n_cols)`` — the quality ground truth
    used by the d2 tests/benchmarks.
    """
    from repro.d2.bipartite import BipartiteGraph

    n_cols = n_rows if n_cols is None else n_cols
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), 2 * band + 1)
    cols = rows + np.tile(np.arange(-band, band + 1), n_rows)
    keep = (cols >= 0) & (cols < n_cols)
    return BipartiteGraph.from_coo(n_rows, n_cols, rows[keep], cols[keep])


def jacobian_tall_skinny(
    n_rows: int, n_cols: int, nnz_per_row: int = 4, seed: int = 0
):
    """Random tall-skinny Jacobian pattern (n_rows >> n_cols).

    The shape that dominates least-squares / residual Jacobians: many
    observations over few parameters, each row touching a handful of
    columns.  Dense-ish column-conflict structure exercises the on-the-fly
    strategy's memory-budget fallback.
    """
    from repro.d2.bipartite import BipartiteGraph

    rng = np.random.default_rng(seed)
    nnz = min(nnz_per_row, n_cols)
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), nnz)
    # vectorized sample-without-replacement per row (n_cols is small)
    cols = np.argsort(rng.random((n_rows, n_cols)), axis=1)[:, :nnz].ravel()
    return BipartiteGraph.from_coo(n_rows, n_cols, rows, cols)
