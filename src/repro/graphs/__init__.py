from repro.graphs.rmat import rmat
from repro.graphs.generators import (
    erdos_renyi,
    grid2d,
    grid3d,
    honeycomb,
    jacobian_band,
    jacobian_tall_skinny,
    power_law,
    road,
    small_world,
    stencil27,
)
from repro.graphs.suite import SUITE, build_graph, build_suite, serving_mix

__all__ = [
    "rmat",
    "erdos_renyi",
    "grid2d",
    "grid3d",
    "honeycomb",
    "jacobian_band",
    "jacobian_tall_skinny",
    "power_law",
    "road",
    "small_world",
    "stencil27",
    "SUITE",
    "build_graph",
    "build_suite",
    "serving_mix",
]
