"""Table-1 stand-in benchmark suite.

No network access in this container, so each UF-collection graph from the
paper's Table 1 is replaced by a generator whose (d̄, σ, topology family)
matches the published statistics.  Sizes are scaled down (``scale`` multiplies
the nominal vertex count; the paper's originals range 0.3M–50M vertices) so
the single-core CPU host can run the full benchmark matrix; every benchmark
accepts ``--scale`` to grow them.

name          paper (n, m, d̄, σ)            stand-in
europe.osm    50.9M 108.1M  2.1  0.23       road()            road network
hugebubbles   21.2M  63.6M  3.0  0          honeycomb()       adaptive mesh (deg=3)
rmat-er        1.0M  10.0M 10.0 10.83       rmat(RMAT_ER)     paper's own recipe
rmat-g         1.0M  10.0M 10.0 123.3       rmat(RMAT_G)      paper's own recipe
Hamrle3        1.4M  11.0M  7.6  7.2        small_world(k=8)  circuit sim
thermal2       1.2M   8.6M  7.0  0.7        grid2d(diag)      thermal FEM
atmosmodd      1.3M   8.8M  6.9  0.1        grid3d()          atmosphere stencil
G3_circuit     1.6M   7.7M  4.8  0.4        grid2d()          circuit sim
ASIC_320ks     0.3M   1.8M  5.7 63.2        power_law(5.7)    circuit, skewed
parabolic_fem  0.5M   3.7M  7.0  0.02       grid3d()          FEM stencil
kkt_power      2.1M  14.6M  7.1 54.8        power_law(7.1)    optimization, skewed
nlpkkt160      8.3M 229.5M 27.5  7.3        stencil27()       optimization, dense-ish
cage15         5.2M  99.2M 19.2 32.9        erdos_renyi(19)+  electrophoresis
"""
from __future__ import annotations

from typing import Callable

from repro.core.csr import CSRGraph
from repro.graphs import generators as G
from repro.graphs.rmat import RMAT_ER, RMAT_G, rmat

__all__ = ["SUITE", "build_graph", "build_suite", "serving_mix"]

# name -> callable(scale) -> CSRGraph.  Nominal n at scale=1.0 is ~64k-128k
# vertices per graph (the whole suite colors in seconds on one CPU core).
SUITE: dict[str, Callable[[float], CSRGraph]] = {
    "europe.osm": lambda s: G.road(int(131072 * s), shortcut_frac=0.05, seed=1),
    "hugebubbles": lambda s: G.honeycomb(int(256 * s**0.5) or 2, 512),
    "rmat-er": lambda s: rmat(int(65536 * s), 10.0, RMAT_ER, seed=2),
    "rmat-g": lambda s: rmat(int(65536 * s), 10.0, RMAT_G, seed=3),
    "Hamrle3": lambda s: G.small_world(int(98304 * s), k=8, rewire=0.05, seed=4),
    "thermal2": lambda s: G.grid2d(int(256 * s**0.5) or 2, 384, diagonals=True),
    "atmosmodd": lambda s: G.grid3d(int(48 * s ** (1 / 3)) or 2, 48, 48),
    "G3_circuit": lambda s: G.grid2d(int(320 * s**0.5) or 2, 384),
    "ASIC_320ks": lambda s: G.power_law(int(49152 * s), 5.7, seed=5),
    "parabolic_fem": lambda s: G.grid3d(int(40 * s ** (1 / 3)) or 2, 40, 40),
    "kkt_power": lambda s: G.power_law(int(98304 * s), 7.1, seed=6),
    "nlpkkt160": lambda s: G.stencil27(int(32 * s ** (1 / 3)) or 2, 32, 32),
    "cage15": lambda s: G.erdos_renyi(int(65536 * s), 19.2, seed=7),
}


def build_graph(name: str, scale: float = 1.0) -> CSRGraph:
    return SUITE[name](scale)


def build_suite(scale: float = 1.0, names: list[str] | None = None):
    names = names or list(SUITE)
    return {name: build_graph(name, scale) for name in names}


def serving_mix(B: int, scale: float = 1.0) -> list[CSRGraph]:
    """B heterogeneous graphs cycling topology family, size, and density.

    The stand-in for a serving workload (many users, many graph shapes);
    consumed by ``benchmarks/batch.py`` and ``examples/batch_serve.py``.
    """
    gens = [
        lambda i: G.erdos_renyi(int(2000 * scale) + 37 * i, 6.0, seed=i),
        lambda i: G.power_law(int(2500 * scale) + 53 * i, 7.0, seed=i),
        lambda i: G.grid2d(int(30 * max(scale, 0.1)) + i % 7, 40),
        lambda i: G.small_world(int(1800 * scale) + 29 * i, 6, seed=i),
        lambda i: G.road(int(1500 * scale) + 41 * i, seed=i),
    ]
    return [gens[i % len(gens)](i) for i in range(B)]
