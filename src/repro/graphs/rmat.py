"""R-MAT recursive-matrix graph generator (Chakrabarti et al., SDM'04).

The paper (§4) generates its two synthetic graphs with R-MAT:

* ``rmat-er`` — (a,b,c,d) = (0.25, 0.25, 0.25, 0.25)  (Erdős–Rényi-like)
* ``rmat-g``  — (a,b,c,d) = (0.45, 0.15, 0.15, 0.25)  (skewed / power-law-ish)

both with 1M vertices and average degree 10.  We reproduce the recipe exactly
(vectorized over edges; one quadrant draw per recursion level) with a
configurable scale so the single-core container stays responsive.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph, csr_from_edges

__all__ = ["rmat", "RMAT_ER", "RMAT_G"]

RMAT_ER = (0.25, 0.25, 0.25, 0.25)
RMAT_G = (0.45, 0.15, 0.15, 0.25)


def rmat(
    n: int,
    avg_degree: float = 10.0,
    params: tuple[float, float, float, float] = RMAT_G,
    seed: int = 0,
) -> CSRGraph:
    """Generate an undirected R-MAT graph with ~``n * avg_degree / 2`` edges."""
    a, b, c, d = params
    assert abs(a + b + c + d - 1.0) < 1e-9
    levels = int(np.ceil(np.log2(max(n, 2))))
    size = 1 << levels
    m = int(n * avg_degree / 2)
    rng = np.random.default_rng(seed)

    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    # probability of "right half" for column, "bottom half" for row, with a
    # small noise term per level as in the original R-MAT description.
    for lvl in range(levels):
        u = rng.random(m)
        # quadrant thresholds: a | b / c | d  (row-major)
        p_bottom = c + d
        bottom = u >= (a + b)
        # conditional probability of right within top/bottom rows
        right_top = (u >= a) & ~bottom
        right_bottom = u >= (a + b + c)
        right = right_top | right_bottom
        bit = 1 << (levels - 1 - lvl)
        src += bottom * bit
        dst += right * bit
        del p_bottom
    keep = (src < n) & (dst < n)
    return csr_from_edges(n, src[keep], dst[keep])
