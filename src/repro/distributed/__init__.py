from repro.distributed.sharding import (
    act_spec,
    batch_shardings,
    cache_shardings,
    param_spec,
    state_shardings,
)

__all__ = [
    "param_spec",
    "act_spec",
    "batch_shardings",
    "cache_shardings",
    "state_shardings",
]
