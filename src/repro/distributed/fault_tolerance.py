"""Fault tolerance & elasticity policy for pod-scale runs.

Mechanisms shipped here (all exercised by tests/test_checkpoint.py):

* ``run_with_restarts`` — supervisor loop: run the training function, on any
  exception restore from the last checkpoint and continue, up to
  ``max_restarts``.  Combined with the stateless data pipeline (pure function
  of the step index) a restart reproduces the uninterrupted trajectory
  exactly.
* ``reshard_state`` — elastic scaling: map a checkpointed state onto a NEW
  mesh (grow/shrink the fleet between restarts).  Restore is sharding-aware
  (training/checkpoint.py) so each host only materializes its own shards.

At 1000+ node scale the remaining pieces are host-level and documented here
for the deployment runbook:
* straggler mitigation — synchronous SPMD steps bound each step by the
  slowest chip; the mitigations are (a) deterministic, load-balanced sharding
  (the resolver never leaves ragged shards), (b) asynchronous checkpoint
  writes (snapshot to host memory, persist off the critical path), and
  (c) preemption signals (SIGTERM) triggering an immediate checkpoint —
  wired in ``install_preemption_handler``.
* failure detection — the JAX runtime surfaces missing peers as collective
  timeouts; the supervisor treats any step exception as a restart trigger.
"""
from __future__ import annotations

import signal
from typing import Callable

import jax

from repro.training.checkpoint import latest_step, restore_checkpoint
from repro.distributed.sharding import state_shardings

__all__ = ["run_with_restarts", "reshard_state", "install_preemption_handler"]


def run_with_restarts(
    run_fn: Callable[[int], dict],
    *,
    ckpt_dir: str,
    max_restarts: int = 3,
) -> dict:
    """Run ``run_fn(start_step)``; on failure, restart from the checkpoint.

    ``run_fn`` must checkpoint to ``ckpt_dir`` itself (see launch/train.py)
    and accept the step to resume from.
    """
    attempts = 0
    while True:
        start = latest_step(ckpt_dir) or 0
        try:
            return run_fn(start)
        except Exception:
            attempts += 1
            if attempts > max_restarts:
                raise
            print(f"[ft] failure (attempt {attempts}/{max_restarts}); "
                  f"restarting from step {latest_step(ckpt_dir) or 0}")


def reshard_state(ckpt_dir: str, step: int, state_like, new_mesh):
    """Elastic restore: place a checkpoint onto a different mesh."""
    shardings = state_shardings(
        jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_like
        ),
        new_mesh,
    )
    return restore_checkpoint(ckpt_dir, step, state_like, shardings=shardings)


def install_preemption_handler(save_fn: Callable[[], None]):
    """SIGTERM -> checkpoint immediately (cloud preemption notice)."""
    def handler(signum, frame):
        print("[ft] preemption signal received; checkpointing")
        save_fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)
