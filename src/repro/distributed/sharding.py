"""Divisibility-aware PartitionSpec resolution for every pytree in the system.

Policy (DESIGN.md §6):
* params — TP: the trailing (output-feature) dim shards over "model" when
  divisible and large enough; FSDP: the largest remaining dim shards over
  "data".  Params are replicated across the "pod" axis (pure DP over DCN,
  the standard multi-pod recipe) so gradients all-reduce over pods only.
* batches — the batch dim shards over ("pod","data"); when batch is 1
  (long-context shapes) the *sequence* dim takes those axes instead
  (sequence parallelism).
* caches / activations — batch over ("pod","data"), then the largest
  remaining dim that divides takes "model" (e.g. a 32k KV time axis when
  kv_heads=8 cannot split 16 ways).

Everything is computed from shapes alone — no per-arch case tables — so the
same resolver serves all 10 architectures; the fallback chain IS the
arch-specific adaptation (kv_heads 8 -> shard time; 10 heads -> flattened
head-feature dim is divisible anyway; vocab 49155 -> padded table divides).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_spec",
    "act_spec",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]

_MIN_SHARD = 512  # don't bother sharding tiny param dims


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(shape, mesh: Mesh) -> P:
    """TP on trailing dim (model), FSDP on the largest remaining dim (data)."""
    ndim = len(shape)
    dims: list = [None] * ndim
    if ndim < 2:
        return P(*dims)
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    if shape[-1] % msize == 0 and shape[-1] >= max(_MIN_SHARD, msize):
        dims[-1] = "model"
    # FSDP: largest remaining dim, skipping tiny/scan-stacked leading dims
    order = sorted(range(ndim - 1), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % dsize == 0 and shape[i] >= max(_MIN_SHARD, dsize):
            dims[i] = "data"
            break
    if dims[-1] is None and shape[-1] % msize == 0 and shape[-1] >= msize:
        # second chance with a lower bar if nothing else sharded
        if all(d is None for d in dims):
            dims[-1] = "model"
    return P(*dims)


def act_spec(shape, mesh: Mesh, batch_dim: int = 0) -> P:
    """Batch over (pod,data); largest remaining divisible dim over model."""
    ndim = len(shape)
    dims: list = [None] * ndim
    daxes = _data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    used_data = False
    if ndim > batch_dim and shape[batch_dim] % dsize == 0 and shape[batch_dim] > 1:
        dims[batch_dim] = daxes
        used_data = True
    msize = mesh.shape.get("model", 1)
    order = sorted(
        (i for i in range(ndim) if dims[i] is None), key=lambda i: -shape[i]
    )
    if not used_data:
        # sequence parallelism: give (pod,data) to the largest divisible dim
        for i in order:
            if shape[i] % dsize == 0 and shape[i] >= dsize:
                dims[i] = daxes
                used_data = True
                break
        order = [i for i in order if dims[i] is None]
    for i in order:
        if shape[i] % msize == 0 and shape[i] >= msize:
            dims[i] = "model"
            break
    return P(*dims)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _named(mesh, spec) -> NamedSharding:
    return NamedSharding(mesh, spec)


_EXPERT_LEAVES = {"w_gate", "w_up", "w_down"}


def _expert_parallel_enabled() -> bool:
    import os

    return os.environ.get("REPRO_EP", "1") not in ("0", "false")


def expert_param_spec(shape, mesh: Mesh) -> P | None:
    """EP sharding for (..., E, d, ff) expert stacks: experts over "model",
    d over "data" (FSDP).  Keeps the MoE dispatch all-reduce restricted to
    each device's expert slice (16x fewer bytes than replicating E — §Perf).
    Returns None when E does not divide the model axis (e.g. mixtral's 8)."""
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    if len(shape) < 3 or shape[-3] % msize or shape[-2] % dsize:
        return None
    dims: list = [None] * len(shape)
    dims[-3] = "model"
    dims[-2] = "data"
    return P(*dims)


def state_shardings(state_shapes, mesh: Mesh):
    """NamedSharding tree for a TrainState/params pytree.

    Shape-driven (param_spec) with one path-aware exception: MoE expert
    weight stacks get expert-parallel placement when divisible (see
    ``expert_param_spec``)."""
    def one(path, leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        name = ""
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        if name in _EXPERT_LEAVES and _expert_parallel_enabled():
            spec = expert_param_spec(shape, mesh)
            if spec is not None:
                return _named(mesh, spec)
        return _named(mesh, param_spec(shape, mesh))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def batch_shardings(batch_shapes, mesh: Mesh):
    def one(leaf):
        return _named(mesh, act_spec(leaf.shape, mesh))

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    def one(leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        if len(shape) < 2:
            return replicated(mesh)
        return _named(mesh, act_spec(shape, mesh))

    return jax.tree.map(one, cache_shapes)
