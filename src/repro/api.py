"""Unified public coloring API (DESIGN.md §4).

One entry point for every coloring implementation in the repo:

    from repro.api import color
    result = color(g, algorithm="data_driven", heuristic="degree")

Algorithms self-register: each ``core/`` module decorates a small adapter
with ``@register(name)`` at import time, so adding an implementation never
touches this file.  All adapters share the ``ColoringResult`` contract from
``core/coloring.py`` (colors, iterations, work accounting, convergence).

Registered names (see ``algorithms()``):

* ``serial``      — sequential greedy oracle (Alg. 1)
* ``data_driven`` — worklist speculative-greedy, the paper's contribution
* ``fused``       — ``data_driven`` as ONE device program (``lax.while_loop``)
* ``topology``    — work-inefficient all-lanes baseline (Alg. 6)
* ``jp``          — Jones–Plassmann MIS (Alg. 3)
* ``multihash``   — CUSPARSE-csrcolor multi-hash MIS
* ``threestep``   — 3-step GM analogue (device rounds + serial host fix-up)
* ``distance2``   — distance-2 SGR (``repro.d2``; same super-step on G²)
* ``bipartite``   — bipartite partial coloring of a ``BipartiteGraph``
                    column side (the Jacobian-compression workload)
* ``dynamic``     — cold path of the streaming incremental engine
                    (``repro.dynamic``; ``open_session`` is the streaming
                    entry point — mutate with ``apply_delta`` and repair
                    with frontier-sized ``recolor()`` calls, §14)

``color_batch`` colors MANY graphs: for ``algorithm="fused"`` (distance-1)
and ``algorithm="distance2"`` it dispatches to the batched multi-graph
engine (``core/batch.py``) — one jitted call for the whole batch — and
falls back to a per-graph loop otherwise.

Backend selection (§15): ``color(g, backend="pallas")`` routes the rotated
super-step through the fused Pallas kernel (``interpret=True`` off-TPU);
``backend="jax"`` forces the pure-JAX engine, ``backend="auto"`` picks
pallas on TPU only.  Colors are bit-identical across backends, so the knob
is pure performance policy; engines that cannot host the kernel (the
multi-device sharded engine) fall back to pure-JAX automatically.

Multi-device (§13): ``color(g, engine="sharded")`` runs the sharded ragged
engine over every available device (bit-identical colors, halo-exchange
communication only) and ``color_batch(graphs, engine="sharded")`` places
batches across devices (shard-per-graph when the batch fills the mesh,
partition-within-graph otherwise).  Both fall back to the single-device
engines when only one device is present.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Iterable

from repro.options import ColorOptions

if TYPE_CHECKING:  # imports stay lazy at runtime to avoid core<->api cycles
    from repro.core.coloring import ColoringResult
    from repro.core.csr import CSRGraph

__all__ = ["register", "color", "color_batch", "algorithms", "get_algorithm",
           "open_session", "ColorOptions"]

_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    """Class-registry decorator: ``@register("jp")`` on a ``(g, **opts)`` adapter."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and _REGISTRY[name] is not fn:
            raise ValueError(f"algorithm {name!r} registered twice")
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_registered() -> None:
    # Importing the packages runs every @register decorator in their modules.
    import repro.core  # noqa: F401
    import repro.d2  # noqa: F401
    import repro.dynamic  # noqa: F401


def open_session(rows, cols=None, *, options: ColorOptions | None = None,
                 **opts):
    """Open a streaming ``ColoringSession`` (lazy alias of ``repro.dynamic``).

    Accepts the unified ``ColorOptions`` object (§19) or the equivalent
    loose kwargs — both normalize identically inside the session.
    """
    from repro.dynamic import open_session as _open_session

    return _open_session(rows, cols, options=options, **opts)


def _normalize(algorithm, options, opts) -> ColorOptions:
    """One ``ColorOptions`` from (positional algorithm | options, kwargs)."""
    if isinstance(algorithm, ColorOptions):
        if options is not None:
            raise TypeError(
                "pass ColorOptions positionally OR as options=, not both")
        options, algorithm = algorithm, None
    return ColorOptions.normalize(options, algorithm=algorithm, **opts)


def algorithms() -> tuple[str, ...]:
    """Sorted names of every registered coloring algorithm."""
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def get_algorithm(name: str) -> Callable:
    """The registered adapter for ``name`` (raises ValueError if unknown)."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {', '.join(sorted(_REGISTRY))}"
        ) from None


def color(graph: "CSRGraph", algorithm: "str | ColorOptions | None" = None,
          *, options: ColorOptions | None = None, **opts) -> "ColoringResult":
    """Color ``graph`` with the named algorithm; extra ``opts`` pass through.

    Returns a ``ColoringResult``; ``result.colors`` is an int32 array in
    ``[1, num_colors]`` and ``result.num_colors`` the color count.

    Options come in either spelling (§19) — a frozen ``ColorOptions``
    (positionally in place of ``algorithm``, or as ``options=``) or loose
    kwargs; both normalize into the same object first, so results are
    bit-identical across spellings.  Kwargs override fields already set on
    the options object.  The default algorithm is ``"data_driven"``.

    Robustness knobs (DESIGN.md §17):

    ``validate_input`` runs the ``repro.ingest.sanitize_csr`` front door on
    a ``CSRGraph`` input first — ``"strict"`` raises ``IngestError`` with a
    structured report on any defect (asymmetry, self-loops, duplicates,
    unsorted rows, bad indices, broken indptr), ``"repair"`` fixes the
    input and records every action on ``result.degradations``.

    ``ensure_valid=True`` guarantees the returned coloring validates
    against the algorithm's conflict relation: a run that failed to
    converge (or returned corrupt colors) is escalated through the §17
    guarantee ladder — deterministic reseed → full iteration budget →
    serialize-the-survivors → serial oracle — instead of surfacing an
    error.  Every escalation taken is recorded in
    ``result.degradations`` and emitted as ``guarantee_ladder`` obs spans.
    """
    o = _normalize(algorithm, options, opts)
    algorithm = o.algorithm or "data_driven"
    fn = get_algorithm(algorithm)
    engine_opts = o.engine_kwargs()
    pre = ()
    if o.validate_input is not None:
        from repro.core.csr import CSRGraph as _CSR
        from repro.ingest import sanitize_csr

        if not isinstance(graph, _CSR):
            raise TypeError(
                "validate_input= applies to CSRGraph inputs; got "
                f"{type(graph).__name__} (sanitize bipartite halves with "
                "sanitize_csr(..., require_symmetric=False) directly)")
        graph, report = sanitize_csr(graph, policy=o.validate_input)
        pre = report.degradations()
    result = fn(graph, **engine_opts)
    if pre:
        result.degradations = pre + tuple(result.degradations)
    if o.ensure_valid:
        result = _apply_ladder(graph, algorithm, fn, engine_opts, result)
    return result


def _apply_ladder(graph, algorithm: str, fn: Callable, opts: dict, result):
    """Escalate ``result`` through the §17 guarantee ladder (see above)."""
    from repro.core.guarantee import ensure_valid_result, square_graph
    from repro.obs.spans import SpanRecorder

    if algorithm == "bipartite":
        cg = graph.column_conflict_graph()
    elif algorithm == "distance2":
        cg = square_graph(graph)
    else:
        cg = graph

    def rerun(rung):
        o = dict(opts)
        if rung == "reseed":
            cur = o.get("heuristic", "degree")
            o["heuristic"] = "id" if cur == "degree" else "degree"
        elif rung == "budget_extension":
            o["max_iters"] = None  # the engine default: always enough
            if o.get("tail_serial", "auto") is None:
                o["tail_serial"] = "auto"
        return fn(graph, **o)

    if result.trace is not None:
        # §16 surfacing: ladder spans land on the run's own trace even
        # without a user recorder (an outer recorder still sees them)
        with SpanRecorder() as rec:
            out = ensure_valid_result(cg, result, rerun)
        if out.trace is not None and rec.events:
            out.trace.spans = list(out.trace.spans or []) + rec.events
        return out
    return ensure_valid_result(cg, result, rerun)


# the knobs the batched fused engine understands — everything else must go
# through the per-graph ``color`` path.  Derived from ColorOptions fields
# (this replaced the old hand-rolled ``supported = {...}`` set; §19).
_BATCH_SUPPORTED = ("heuristic", "firstfit", "max_iters", "tail_serial",
                    "engine", "devices", "backend", "trace",
                    "validate_input", "ensure_valid")


def color_batch(
    graphs: Iterable["CSRGraph"],
    algorithm: "str | ColorOptions | None" = None, *,
    options: ColorOptions | None = None, **opts
) -> "list[ColoringResult]":
    """Color many graphs; the serving-path entry point.

    Options come as a ``ColorOptions`` or loose kwargs, exactly like
    ``color`` (§19); results are bit-identical across spellings.  The
    default algorithm is ``"fused"``.

    ``trace=True`` (supported by every algorithm here) attaches a per-run
    ``RunTrace`` to each result — see ``repro.obs``.

    ``algorithm="fused"`` uses the batched engine: the graphs are packed into
    one stacked padded-adjacency layout and a single jitted ``while_loop``
    colors all of them concurrently (see ``core/batch.py``).  Any other name
    loops ``color`` over the graphs.  Algorithm-specific knobs the batched
    engine cannot honor are refused by name with the supported list.
    """
    graphs = list(graphs)
    o = _normalize(algorithm, options, opts)
    algorithm = o.algorithm or "fused"
    if algorithm in ("fused", "distance2"):
        from repro.core.batch import color_batch_fused, color_batch_sharded

        extra = o.extra_dict()
        devices = extra.pop("devices", None)
        if extra:
            raise ValueError(
                f"options {sorted(extra)} are not supported by the batched "
                f"fused engine (supported: {sorted(_BATCH_SUPPORTED)}); "
                f"use color(g, {algorithm!r}, ...) per graph instead"
            )
        pre = [()] * len(graphs)
        if o.validate_input is not None:
            from repro.ingest import sanitize_csr

            sanitized = []
            for i, g in enumerate(graphs):
                g, report = sanitize_csr(g, policy=o.validate_input)
                sanitized.append(g)
                pre[i] = report.degradations()
            graphs = sanitized
        kw = o.engine_kwargs()
        kw.pop("engine", None)
        engine = o.engine or "batch"
        if engine == "sharded":
            results = color_batch_sharded(
                graphs, distance2=(algorithm == "distance2"),
                devices=devices, **kw
            )
        elif engine != "batch":
            raise ValueError(
                f"unknown batch engine {engine!r}; options: batch, sharded"
            )
        elif devices is not None:
            raise ValueError(
                "devices= only applies to engine='sharded'; the default "
                "batched engine runs on the default device placement"
            )
        else:
            results = color_batch_fused(
                graphs, distance2=(algorithm == "distance2"), **kw
            )
        for g, r, p in zip(graphs, results, pre):
            if p:
                r.degradations = tuple(p) + tuple(r.degradations)
        if o.ensure_valid:
            fn = get_algorithm(algorithm)
            results = [_apply_ladder(g, algorithm, fn, kw, r)
                       for g, r in zip(graphs, results)]
        return results
    per_graph = o.merged(algorithm=algorithm)
    return [color(g, options=per_graph) for g in graphs]
