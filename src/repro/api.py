"""Unified public coloring API (DESIGN.md §4).

One entry point for every coloring implementation in the repo:

    from repro.api import color
    result = color(g, algorithm="data_driven", heuristic="degree")

Algorithms self-register: each ``core/`` module decorates a small adapter
with ``@register(name)`` at import time, so adding an implementation never
touches this file.  All adapters share the ``ColoringResult`` contract from
``core/coloring.py`` (colors, iterations, work accounting, convergence).

Registered names (see ``algorithms()``):

* ``serial``      — sequential greedy oracle (Alg. 1)
* ``data_driven`` — worklist speculative-greedy, the paper's contribution
* ``fused``       — ``data_driven`` as ONE device program (``lax.while_loop``)
* ``topology``    — work-inefficient all-lanes baseline (Alg. 6)
* ``jp``          — Jones–Plassmann MIS (Alg. 3)
* ``multihash``   — CUSPARSE-csrcolor multi-hash MIS
* ``threestep``   — 3-step GM analogue (device rounds + serial host fix-up)
* ``distance2``   — distance-2 SGR (``repro.d2``; same super-step on G²)
* ``bipartite``   — bipartite partial coloring of a ``BipartiteGraph``
                    column side (the Jacobian-compression workload)
* ``dynamic``     — cold path of the streaming incremental engine
                    (``repro.dynamic``; ``open_session`` is the streaming
                    entry point — mutate with ``apply_delta`` and repair
                    with frontier-sized ``recolor()`` calls, §14)

``color_batch`` colors MANY graphs: for ``algorithm="fused"`` (distance-1)
and ``algorithm="distance2"`` it dispatches to the batched multi-graph
engine (``core/batch.py``) — one jitted call for the whole batch — and
falls back to a per-graph loop otherwise.

Backend selection (§15): ``color(g, backend="pallas")`` routes the rotated
super-step through the fused Pallas kernel (``interpret=True`` off-TPU);
``backend="jax"`` forces the pure-JAX engine, ``backend="auto"`` picks
pallas on TPU only.  Colors are bit-identical across backends, so the knob
is pure performance policy; engines that cannot host the kernel (the
multi-device sharded engine) fall back to pure-JAX automatically.

Multi-device (§13): ``color(g, engine="sharded")`` runs the sharded ragged
engine over every available device (bit-identical colors, halo-exchange
communication only) and ``color_batch(graphs, engine="sharded")`` places
batches across devices (shard-per-graph when the batch fills the mesh,
partition-within-graph otherwise).  Both fall back to the single-device
engines when only one device is present.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Iterable

if TYPE_CHECKING:  # imports stay lazy at runtime to avoid core<->api cycles
    from repro.core.coloring import ColoringResult
    from repro.core.csr import CSRGraph

__all__ = ["register", "color", "color_batch", "algorithms", "get_algorithm",
           "open_session"]

_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    """Class-registry decorator: ``@register("jp")`` on a ``(g, **opts)`` adapter."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and _REGISTRY[name] is not fn:
            raise ValueError(f"algorithm {name!r} registered twice")
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_registered() -> None:
    # Importing the packages runs every @register decorator in their modules.
    import repro.core  # noqa: F401
    import repro.d2  # noqa: F401
    import repro.dynamic  # noqa: F401


def open_session(rows, cols=None, **opts):
    """Open a streaming ``ColoringSession`` (lazy alias of ``repro.dynamic``)."""
    from repro.dynamic import open_session as _open_session

    return _open_session(rows, cols, **opts)


def algorithms() -> tuple[str, ...]:
    """Sorted names of every registered coloring algorithm."""
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def get_algorithm(name: str) -> Callable:
    """The registered adapter for ``name`` (raises ValueError if unknown)."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {', '.join(sorted(_REGISTRY))}"
        ) from None


def color(graph: "CSRGraph", algorithm: str = "data_driven", **opts) -> "ColoringResult":
    """Color ``graph`` with the named algorithm; extra ``opts`` pass through.

    Returns a ``ColoringResult``; ``result.colors`` is an int32 array in
    ``[1, num_colors]`` and ``result.num_colors`` the color count.
    """
    return get_algorithm(algorithm)(graph, **opts)


def color_batch(
    graphs: Iterable["CSRGraph"], algorithm: str = "fused", **opts
) -> "list[ColoringResult]":
    """Color many graphs; the serving-path entry point.

    ``trace=True`` (supported by every algorithm here) attaches a per-run
    ``RunTrace`` to each result — see ``repro.obs``.

    ``algorithm="fused"`` uses the batched engine: the graphs are packed into
    one stacked padded-adjacency layout and a single jitted ``while_loop``
    colors all of them concurrently (see ``core/batch.py``).  Any other name
    loops ``color`` over the graphs.
    """
    graphs = list(graphs)
    if algorithm in ("fused", "distance2"):
        from repro.core.batch import color_batch_fused, color_batch_sharded

        supported = {"heuristic", "firstfit", "use_kernel", "max_iters",
                     "tail_serial", "engine", "devices", "backend", "trace"}
        extra = set(opts) - supported
        if extra:
            raise ValueError(
                f"options {sorted(extra)} are not supported by the batched "
                f"fused engine (supported: {sorted(supported)}); "
                f"use color(g, {algorithm!r}, ...) per graph instead"
            )
        engine = opts.pop("engine", "batch")
        devices = opts.pop("devices", None)
        if engine == "sharded":
            return color_batch_sharded(
                graphs, distance2=(algorithm == "distance2"),
                devices=devices, **opts
            )
        if engine != "batch":
            raise ValueError(
                f"unknown batch engine {engine!r}; options: batch, sharded"
            )
        if devices is not None:
            raise ValueError(
                "devices= only applies to engine='sharded'; the default "
                "batched engine runs on the default device placement"
            )
        return color_batch_fused(
            graphs, distance2=(algorithm == "distance2"), **opts
        )
    fn = get_algorithm(algorithm)
    return [fn(g, **opts) for g in graphs]
