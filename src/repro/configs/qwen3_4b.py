"""Qwen3-4B — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B family; hf]  36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936.
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="hf:Qwen/Qwen3-4B",
    )
