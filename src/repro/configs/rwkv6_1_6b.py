"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay linear RNN.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Head dim 64 (32 heads).  Trained/prefilled with chunked linear attention;
decoded with the exact (H, K, V) state recurrence -> O(1)/token, long_500k ok.
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="rwkv",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab=65536,
        rwkv_head_dim=64,
        rwkv_lora=64,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2404.05892",
    )
