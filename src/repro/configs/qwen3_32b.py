"""Qwen3-32B — dense GQA with per-head qk RMSNorm.

[hf:Qwen/Qwen3-8B family; hf]  64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936.
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="hf:Qwen/Qwen3-32B",
    )
