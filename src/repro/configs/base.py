"""Model configuration system + architecture registry.

Every assigned architecture registers a full-size ``ModelConfig`` (exact
published dimensions) and gets a ``reduced()`` variant for CPU smoke tests.
The full configs are only ever lowered via ShapeDtypeStruct (launch/dryrun.py)
— never allocated on this host.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["ModelConfig", "ARCHS", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | rwkv | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    act: str = "silu"              # silu -> SwiGLU MLP; gelu -> plain MLP
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None      # sliding-window attention size
    causal: bool = True

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0    # leading dense-FFN layers (DeepSeek-V2)
    capacity_factor: float = 1.0
    moe_chunk: int = 4096          # token-chunked dispatch (bounds transients)
    moe_dispatch: str = "einsum"   # einsum (GShard one-hot) | scatter (indexed)
    moe_group: str = "flat"        # flat (global capacity) | seq (per-row groups)
    moe_group_seq: int = 512       # group length along S for moe_group="seq"
    moe_remat: bool = True         # recompute chunk dispatch in backward (§Perf)
    attn_chunk_remat: bool = True  # recompute q-chunk scores in backward (§Perf)

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid (RecurrentGemma / Griffin) -----------------------------------
    pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn"), cycled
    d_rnn: int = 0
    conv_width: int = 4

    # --- rwkv -----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_lora: int = 64            # data-dependent decay LoRA rank

    # --- modality frontend (stubbed per task rules) ---------------------------
    frontend: str | None = None    # "patch" (vlm) | "frame" (audio)
    d_frontend: int = 0
    n_patches: int = 0

    # --- numerics / training ---------------------------------------------------
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    vocab_pad_to: int = 256
    remat: bool = True
    logits_chunk: int = 1024       # CE loss computed in seq chunks (memory)
    attn_q_chunk: int = 1024       # chunked-softmax attention threshold/size
    sources: str = ""

    # ------------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def attn_kind(self) -> str:
        if self.family == "rwkv":
            return "rwkv"
        if self.mla:
            return "mla"
        return "gqa"

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'rec' (cycled hybrid pattern)."""
        if not self.pattern:
            return ["attn"] * self.n_layers
        return [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]

    def ffn_kinds(self) -> list[str]:
        if self.n_experts:
            return [
                "dense" if i < self.first_dense_layers else "moe"
                for i in range(self.n_layers)
            ]
        return ["dense"] * self.n_layers

    def params_estimate(self) -> tuple[int, int]:
        """(total, active-per-token) parameter counts — for 6ND model FLOPs."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.family == "encoder" else 2)
        per_layer_total = per_layer_active = 0
        kinds = self.layer_kinds()
        ffns = self.ffn_kinds()
        for kind, ffn in zip(kinds, ffns):
            if kind == "rec":
                blk = 2 * d * self.d_rnn + self.conv_width * self.d_rnn \
                    + 2 * self.d_rnn * self.d_rnn // max(self.d_rnn // d, 1) \
                    + self.d_rnn * d
            elif self.family == "rwkv":
                blk = 5 * d * d + 6 * self.rwkv_lora * d
            elif self.mla:
                blk = (
                    d * self.q_lora
                    + self.q_lora * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora + self.qk_rope_dim)
                    + self.kv_lora * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
            else:
                blk = d * self.n_heads * self.head_dim \
                    + 2 * d * self.n_kv_heads * self.head_dim \
                    + self.n_heads * self.head_dim * d
            mlp_mult = 3 if self.act == "silu" else 2
            if ffn == "moe":
                expert = mlp_mult * d * self.expert_d_ff
                total_ffn = self.n_experts * expert + self.n_shared_experts * expert \
                    + d * self.n_experts
                active_ffn = (self.top_k + self.n_shared_experts) * expert \
                    + d * self.n_experts
            else:
                ff = self.d_ff if not (self.n_experts and ffn == "dense") else self.d_ff
                total_ffn = active_ffn = mlp_mult * d * ff
            per_layer_total += blk + total_ffn
            per_layer_active += blk + active_ffn
        return emb + per_layer_total, emb + per_layer_active

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        period = max(len(self.pattern), 1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, period + 1) if self.pattern else 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128,
            vocab=257,
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            expert_d_ff=48 if self.n_experts else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            moe_chunk=64,
            capacity_factor=8.0,   # drop-free at smoke scale (exactness tests)
            q_lora=24 if self.q_lora else 0,
            kv_lora=16 if self.kv_lora else 0,
            qk_nope_dim=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            d_rnn=64 if self.d_rnn else 0,
            rwkv_lora=8,
            window=min(self.window, 8) if self.window else None,
            d_frontend=32 if self.frontend else 0,
            n_patches=4 if self.frontend == "patch" else 0,
            vocab_pad_to=32,
            logits_chunk=64,
            attn_q_chunk=32,
            param_dtype="float32",
            act_dtype="float32",
        )


ARCHS: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        ARCHS[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)

    return ARCHS[arch_id]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(ARCHS)
