"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1, head 256) d_ff=7680
vocab=256000.  Pattern (rec, rec, attn) cycled; local attention window 2048;
RG-LRU width 2560, causal conv width 4.  The assignment sheet writes the
pattern ratio as "1:2" (attn:rec) — same 2 recurrent : 1 attention mix.
"""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        act="gelu",
        pattern=("rec", "rec", "attn"),
        d_rnn=2560,
        conv_width=4,
        window=2048,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2402.19427",
    )
