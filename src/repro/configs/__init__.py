"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import ARCHS, ModelConfig, get_config, list_archs, register

# one module per assigned architecture; import order = registry order
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    granite_3_8b,
    hubert_xlarge,
    internvl2_26b,
    mixtral_8x22b,
    qwen3_32b,
    qwen3_4b,
    recurrentgemma_2b,
    rwkv6_1_6b,
    starcoder2_15b,
)

__all__ = ["ARCHS", "ModelConfig", "get_config", "list_archs", "register"]
