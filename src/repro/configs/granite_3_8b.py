"""Granite-3 8B — dense GQA.

[hf:ibm-granite/granite-3.0 family; hf] 40L d_model=4096 32H (kv=8) d_ff=12800
vocab=49155 (note: odd vocab -> physically padded to 49408, logits masked).
"""
from repro.configs.base import ModelConfig, register


@register("granite-3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab=49155,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="hf:ibm-granite/granite-3.0-8b-base",
    )
