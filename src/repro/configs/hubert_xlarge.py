"""HuBERT X-Large — encoder-only audio transformer (frame classification).

[arXiv:2106.07447; unverified]  48L d_model=1280 16H d_ff=5120 vocab=504
(masked-unit prediction classes).  Encoder-only: bidirectional attention, no
decode shapes.  The conv waveform frontend is stubbed: ``input_specs()``
supplies precomputed 512-d frame features which a learned projector embeds.
"""
from repro.configs.base import ModelConfig, register


@register("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab=504,
        act="gelu",
        norm="layernorm",
        causal=False,
        frontend="frame",
        d_frontend=512,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2106.07447",
    )
