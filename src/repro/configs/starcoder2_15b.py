"""StarCoder2-15B — dense GQA (kv=4), LayerNorm + GELU MLP, RoPE.

[arXiv:2402.19173; hf]  40L d_model=6144 48H (kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig, register


@register("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        act="gelu",
        norm="layernorm",
        rope_theta=100_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2402.19173",
    )
