"""InternVL2-26B — VLM: InternViT frontend (stubbed) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]  backbone 48L d_model=6144 48H (kv=8) d_ff=16384
vocab=92553 (padded).  Per task rules the modality frontend is a stub:
``input_specs()`` supplies precomputed ViT patch embeddings (B, 256, 1024)
which a learned projector maps into the text stream.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-26b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=92553,
        frontend="patch",
        d_frontend=1024,
        n_patches=256,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2404.16821",
    )
