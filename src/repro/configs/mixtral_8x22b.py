"""Mixtral 8x22B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        n_experts=8,
        top_k=2,
        expert_d_ff=16384,
        window=4096,             # SWA -> sub-quadratic; long_500k runs
        rope_theta=1_000_000.0,
        moe_group="seq",          # grouped routing (GShard groups; §Perf)
        moe_group_seq=1024,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2401.04088",
    )
