"""DeepSeek-V2 236B — MLA (kv_lora 512) + MoE 160 routed top-6 + 2 shared.

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64 (decoupled), v 128.
Layer 0 uses a dense FFN (d_ff 12288) per the paper; the rest are MoE.
"""
from repro.configs.base import ModelConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,          # MLA: latent cache is shared; heads read it
        head_dim=192,            # qk_nope + qk_rope (scoring width)
        d_ff=12288,              # the dense layer-0 FFN
        vocab=102400,
        mla=True,
        q_lora=1536,
        kv_lora=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        expert_d_ff=1536,
        first_dense_layers=1,
        rope_theta=10_000.0,
        moe_group="seq",          # grouped routing (GShard groups; §Perf)
        moe_group_seq=1024,
        param_dtype="bfloat16",
        act_dtype="bfloat16",
        sources="arXiv:2405.04434",
    )
