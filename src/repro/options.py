"""``ColorOptions`` — the unified, frozen options object (DESIGN.md §19).

Every coloring entry point (``repro.color``, ``repro.color_batch``,
``repro.open_session``, the serving layer) accepts the same options two
ways: loose keyword arguments, exactly as before, or one frozen
``ColorOptions`` value::

    opts = repro.ColorOptions(algorithm="fused", heuristic="id")
    repro.color(g, opts)                       # options object
    repro.color(g, "fused", heuristic="id")    # kwargs — same result, bit-identical

Both spellings normalize into the SAME ``ColorOptions`` before any engine
runs, so the two paths cannot drift.  The object is hashable (frozen
dataclass, tuple-normalized contents), which is what the serving layer's
micro-batcher keys its request buckets on: requests that share a
``(pow2 shape class, ColorOptions)`` bucket share jit cache entries.

Fields cover the knobs every engine understands — ``algorithm``,
``engine``, ``backend``, ``heuristic``, ``firstfit``, ``validate_input``,
``ensure_valid``, ``trace``, and the tail/iteration knobs ``tail_serial``
/ ``max_iters``.  Algorithm-specific knobs (``mode``, ``tiling``,
``strategy``, ``compact_frac``, ``devices``, …) ride along in ``extra``
as a sorted tuple of pairs; entry points that cannot honor them refuse
with the option names (this replaces ``color_batch``'s old hand-rolled
``supported = {...}`` set).

A field left at its default is *unset*: ``engine_kwargs()`` omits it, so
the callee's own default applies and an options-object call stays
bit-identical to the equivalent kwargs call.  ``tail_serial`` uses the
``UNSET`` sentinel because ``None`` is a meaningful value there (disable
the tail).

The legacy ``use_kernel=`` knob is accepted one more release: it warns
(``DeprecationWarning``) and normalizes into ``backend=`` —
``use_kernel=True`` means ``backend="pallas"`` and still conflicts
loudly with an explicit ``backend="jax"``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

__all__ = ["ColorOptions", "UNSET"]


class _Unset:
    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "UNSET"

    def __reduce__(self):  # pickling round-trips to the singleton
        return (_Unset, ())


UNSET = _Unset()

_DEPRECATION_MSG = (
    "use_kernel= is deprecated; use backend='pallas' (use_kernel=True) or "
    "drop it / backend='jax' (use_kernel=False).  The knob will be removed "
    "next release."
)
_CONFLICT_MSG = (
    "backend='jax' contradicts use_kernel=True; drop one of them "
    "(backend='pallas' is the kernel path)"
)


def _freeze(value):
    """Recursively tuple-ify lists/dicts so ColorOptions stays hashable."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    if isinstance(value, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in value.items()))
    return value


@dataclasses.dataclass(frozen=True)
class ColorOptions:
    """Frozen, hashable options for one coloring request (see module doc)."""

    algorithm: str | None = None
    engine: str | None = None
    backend: str | None = None
    heuristic: str | None = None
    firstfit: str | None = None
    validate_input: str | None = None
    ensure_valid: bool = False
    trace: Any = False
    tail_serial: Any = UNSET
    max_iters: int | None = None
    extra: tuple = ()

    def __post_init__(self):
        # accept extra as a dict (the ergonomic spelling) and normalize to
        # the canonical sorted-pair tuple; freeze list values so the whole
        # object is hashable (the micro-batch bucket key)
        object.__setattr__(self, "extra", _freeze(dict(self.extra)
                                                  if isinstance(self.extra,
                                                                dict)
                                                  else dict(self.extra or ())))
        object.__setattr__(
            self, "tail_serial",
            self.tail_serial if self.tail_serial is UNSET
            else _freeze(self.tail_serial))
        object.__setattr__(self, "trace", _freeze(self.trace))

    # -- construction ------------------------------------------------------
    _FIELDS = ("algorithm", "engine", "backend", "heuristic", "firstfit",
               "validate_input", "ensure_valid", "trace", "tail_serial",
               "max_iters")

    @classmethod
    def normalize(cls, options: "ColorOptions | None" = None, /,
                  **kwargs) -> "ColorOptions":
        """Merge loose ``kwargs`` over ``options`` into one ColorOptions.

        This is the single normalization point every entry point routes
        through: kwargs win over fields already set on ``options``,
        unknown kwargs land in ``extra``, and the deprecated
        ``use_kernel=`` knob is translated into ``backend=`` (with a
        ``DeprecationWarning``; ``backend="jax"`` + ``use_kernel=True``
        still raises).
        """
        if options is None:
            options = cls()
        elif not isinstance(options, ColorOptions):
            raise TypeError(
                f"options must be a ColorOptions, got {type(options).__name__}")
        if not kwargs:
            return options
        fields = {}
        extra = dict(options.extra)
        if "use_kernel" in kwargs:
            use_kernel = kwargs.pop("use_kernel")
            warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=3)
            backend = kwargs.get("backend", options.backend)
            if use_kernel:
                if backend == "jax":
                    raise ValueError(_CONFLICT_MSG)
                if backend in (None, "auto"):
                    fields["backend"] = "pallas"
        if "options" in kwargs:
            raise TypeError(
                "options= must be passed positionally or as the dedicated "
                "keyword of the entry point, not inside the loose kwargs")
        for key, value in kwargs.items():
            if key in cls._FIELDS:
                fields.setdefault(key, value)
                if key in ("algorithm",) and value is None:
                    fields.pop(key)  # positional default: keep options' value
            else:
                extra[key] = value
        merged = {f.name: getattr(options, f.name)
                  for f in dataclasses.fields(cls)}
        merged.update(fields)
        merged["extra"] = extra
        return cls(**merged)

    def merged(self, **kwargs) -> "ColorOptions":
        """A copy with ``kwargs`` merged over this object (kwargs win)."""
        return ColorOptions.normalize(self, **kwargs)

    # -- consumption -------------------------------------------------------
    def engine_kwargs(self) -> dict:
        """The kwargs dict an algorithm adapter receives.

        Only explicitly-set knobs are emitted (unset fields fall through to
        the callee's own defaults), which is what makes the options path
        bit-identical to the loose-kwargs path.  ``algorithm``,
        ``validate_input`` and ``ensure_valid`` are consumed by the entry
        point itself and never appear here.
        """
        out: dict = {}
        for key in ("engine", "backend", "heuristic", "firstfit",
                    "max_iters"):
            value = getattr(self, key)
            if value is not None:
                out[key] = value
        if self.tail_serial is not UNSET:
            out["tail_serial"] = self.tail_serial
        if self.trace:
            out["trace"] = self.trace
        out.update(self.extra_dict())
        return out

    def extra_dict(self) -> dict:
        return dict(self.extra)

    def session_kwargs(self) -> dict:
        """The kwargs dict ``ColoringSession`` accepts (open_session path).

        Same only-set-knobs contract as ``engine_kwargs``.  The session
        pins its own engine (the ragged frontier engine, §14), so an
        ``engine`` field is refused; ``ensure_valid=True`` maps to the
        session's equivalent guarantee knob ``on_fail="ladder"`` unless an
        explicit ``on_fail`` rides in ``extra``.
        """
        if self.engine is not None:
            raise ValueError(
                f"engine={self.engine!r} does not apply to sessions; the "
                "streaming engine is fixed (ragged frontier recolors, §14)")
        if self.algorithm not in (None, "dynamic"):
            raise ValueError(
                f"algorithm={self.algorithm!r} does not apply to sessions "
                "(sessions ARE the 'dynamic' algorithm)")
        out: dict = {}
        for key in ("backend", "heuristic", "firstfit", "max_iters",
                    "validate_input"):
            value = getattr(self, key)
            if value is not None:
                out[key] = value
        if self.tail_serial is not UNSET:
            out["tail_serial"] = self.tail_serial
        if self.trace:
            out["trace"] = self.trace
        out.update(self.extra_dict())
        if self.ensure_valid:
            out.setdefault("on_fail", "ladder")
        return out

    def describe(self) -> str:
        """Compact one-line rendering of the set knobs (for logs/metrics)."""
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "extra":
                parts.extend(f"{k}={val!r}" for k, val in self.extra)
            elif f.name == "tail_serial":
                if v is not UNSET:
                    parts.append(f"tail_serial={v!r}")
            elif v not in (None, False):
                parts.append(f"{f.name}={v!r}")
        return "ColorOptions(" + ", ".join(parts) + ")"
