"""Production mesh definition (a function — importing never touches devices)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)              # one v5e pod slice: 256 chips
MULTI_POD_SHAPE = (2, 16, 16)     # 2 pods over DCN: 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
