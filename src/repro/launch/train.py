"""End-to-end training driver.

Runs real training (CPU-scale or TPU-scale — same code path): synthetic data
pipeline, AdamW, checkpoint/restart with ``--resume auto``, periodic metrics.
On a multi-device fleet pass ``--mesh dxm`` to shard with the production
sharding rules; on this container it runs single-device reduced configs
(see examples/train_lm.py for the ~100M-param end-to-end run).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.distributed.sharding import batch_shardings, state_shardings
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.data import SyntheticData

__all__ = ["train_loop", "main"]


def train_loop(
    cfg,
    *,
    steps: int,
    batch_size: int,
    seq_len: int,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    resume: bool = False,
    mesh=None,
    log_every: int = 10,
    seed: int = 0,
    fail_at_step: int | None = None,
) -> dict:
    """Returns summary metrics. ``fail_at_step`` injects a crash (FT tests)."""
    model = build_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=lr, warmup_steps=min(50, steps // 10 + 1),
                          total_steps=steps)
    data = SyntheticData.for_model(cfg, batch_size, seq_len, seed=seed)

    state = init_train_state(model, jax.random.PRNGKey(seed))
    start_step = 0
    if resume and ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
        shardings = state_shardings(state, mesh) if mesh else None
        state = restore_checkpoint(ckpt_dir, last, state, shardings=shardings)
        start_step = last
        print(f"[train] resumed from step {last}")

    step_fn = make_train_step(model, opt_cfg)
    if mesh is not None:
        st_sh = state_shardings(jax.eval_shape(lambda: state), mesh)
        b_sh = batch_shardings(
            jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.batch(0)
            ),
            mesh,
        )
        step_fn = jax.jit(step_fn, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
        state = jax.device_put(state, st_sh)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(
                f"[train] step {step + 1}/{steps} loss={loss:.4f} "
                f"ce={float(metrics['ce']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e}",
                flush=True,
            )
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, jax.device_get(state))
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, jax.device_get(state))
    dt = time.time() - t0
    return {
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "losses": losses,
        "steps": steps - start_step,
        "wall_s": dt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", choices=("auto", "never"), default="never")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    summary = train_loop(
        cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume == "auto",
        seed=args.seed,
    )
    print(json.dumps({k: v for k, v in summary.items() if k != "losses"}))


if __name__ == "__main__":
    main()
