"""Trip-count-aware HLO cost analysis (the dry-run "profiler").

XLA's built-in ``cost_analysis()`` visits each ``while`` body ONCE, so any
scanned model (all of ours — scan-over-layers, chunked attention/MoE/loss)
under-reports FLOPs, bytes and collectives by ~the trip count.  This module
parses the post-SPMD optimized HLO text and computes, per computation and
recursively through fusions/calls/whiles/conditionals:

  * ``flops``        — 2*M*N*K for dots (MXU work; convolutions likewise)
  * ``traffic``      — sum of operand+output bytes of *top-level* ops per
                        computation (fusion internals excluded): an HBM
                        traffic model — fusions touch HBM only at their
                        boundary
  * ``collectives``  — ring-cost bytes moved per collective op, grouped by op

``while`` bodies are multiplied by the trip count recovered from the loop
condition (counter < constant); ``conditional`` takes the max across
branches.  Validated against hand-computed scans in tests/test_dryrun.py.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([a-z0-9\-]+)\("
)
_ARRAY = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _ARRAY.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    traffic: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k,
            self.traffic * k,
            self.collective_bytes * k,
            {op: {kk: v * k for kk, v in d.items()} for op, d in self.collectives.items()},
            self.unknown_trip_counts,
        )

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.traffic += other.traffic
        self.collective_bytes += other.collective_bytes
        for op, d in other.collectives.items():
            mine = self.collectives.setdefault(op, {"count": 0, "moved_bytes": 0.0})
            mine["count"] += d["count"]
            mine["moved_bytes"] += d["moved_bytes"]
        self.unknown_trip_counts += other.unknown_trip_counts


def _coll_moved(op: str, out_bytes: int, n: int) -> float:
    n = max(n, 2)
    if op == "all-gather":
        return out_bytes * (n - 1) / n
    if op == "all-reduce":
        return out_bytes * 2 * (n - 1) / n
    if op == "reduce-scatter":
        return out_bytes * (n - 1)
    if op == "all-to-all":
        return out_bytes * (n - 1) / n
    return float(out_bytes)


# zero-cost "view" ops: no physical data movement
_VIEW_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
             "after-all", "partition-id", "replica-id"}


class _Module:
    def __init__(self, text: str):
        self.comps: dict[str, list[tuple]] = {}
        self.roots: dict[str, tuple] = {}
        self.entry = None
        self._parse(text)
        self._cache: dict[str, HloCost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HEADER.match(line.strip())
                if m and "{" in line:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            mi = _INSTR.match(line)
            if mi:
                rec = (mi.group(1), mi.group(2), mi.group(3), line)
                self.comps[cur].append(rec)
                if line.lstrip().startswith("ROOT"):
                    self.roots[cur] = rec

    def _fusion_effective_bytes(self, comp_name: str) -> int | None:
        """Effective HBM write size of a fusion: if the root is an in-place
        dynamic-update-slice (the scan save/accumulate pattern), the physical
        write is the update slice, not the whole aliased buffer."""
        root = self.roots.get(comp_name)
        if root is None:
            return None
        shapes = {n: t for n, t, _o, _l in self.comps[comp_name]}

        def effective(name_or_rec):
            name, type_str, op, line = name_or_rec
            if op == "dynamic-update-slice":
                ops = _OPERANDS.findall(line.split("(", 1)[1].split(")", 1)[0])
                if len(ops) >= 2 and ops[1] in shapes:
                    return 2 * _shape_bytes(shapes[ops[1]])  # read+write slice
                return _shape_bytes(type_str)
            if op == "dynamic-slice":
                return 2 * _shape_bytes(type_str)
            return None

        eff = effective(root)
        if eff is not None:
            return eff
        if root[2] == "tuple":
            by_name = {n: (n, t, o, l) for n, t, o, l in self.comps[comp_name]}
            ops = _OPERANDS.findall(root[3].split("(", 1)[1].split(")", 1)[0])
            total = 0
            for o in ops:
                rec = by_name.get(o)
                e = effective(rec) if rec else None
                total += e if e is not None else _shape_bytes(shapes.get(o, ""))
            return total
        return None

    # -- trip count from a loop condition computation ------------------------
    def _trip_count(self, cond_name: str) -> int | None:
        comp = self.comps.get(cond_name)
        if not comp:
            return None
        constants = {}
        for name, _type, op, line in comp:
            if op == "constant":
                m = re.search(r"constant\((-?\d+)\)", line)
                if m:
                    constants[name] = int(m.group(1))
        for name, _type, op, line in comp:
            if op == "compare":
                ops = _OPERANDS.findall(line.split("compare(", 1)[1])
                vals = [constants[o] for o in ops if o in constants]
                if vals:
                    m = re.search(r"direction=(\w+)", line)
                    d = m.group(1) if m else "LT"
                    v = abs(vals[0])
                    return v + 1 if d in ("LE", "GE") else v
        return None

    def cost(self, comp_name: str) -> HloCost:
        if comp_name in self._cache:
            return self._cache[comp_name]
        self._cache[comp_name] = HloCost()  # cycle guard
        total = HloCost()
        shapes = {}
        for name, type_str, op, line in self.comps.get(comp_name, []):
            shapes[name] = type_str
            out_bytes = _shape_bytes(type_str)

            if op == "dot":
                seg = line.split("dot(", 1)[1]
                ops = _OPERANDS.findall(seg.split(")", 1)[0])
                lhs_type = shapes.get(ops[0], "") if ops else ""
                mdims = _ARRAY.search(lhs_type)
                k = 1
                mc = _CONTRACT.search(line)
                if mdims and mc:
                    dims = [int(d) for d in mdims.group(2).split(",") if d]
                    for ci in (int(c) for c in mc.group(1).split(",") if c):
                        if ci < len(dims):
                            k *= dims[ci]
                total.flops += 2.0 * _shape_elems(type_str) * k
                total.traffic += out_bytes + sum(
                    _shape_bytes(shapes.get(o, "")) for o in ops)
            elif op == "convolution":
                total.flops += 2.0 * _shape_elems(type_str)  # lower bound
                total.traffic += out_bytes
            elif op == "fusion" or op == "call":
                called = _CALLS.search(line)
                eff = None
                if called and called.group(1) in self.comps:
                    sub = self.cost(called.group(1))
                    # fusion internals: flops yes, traffic only at boundary
                    total.flops += sub.flops
                    total.collective_bytes += sub.collective_bytes
                    for opn, d in sub.collectives.items():
                        mine = total.collectives.setdefault(
                            opn, {"count": 0, "moved_bytes": 0.0})
                        mine["count"] += d["count"]
                        mine["moved_bytes"] += d["moved_bytes"]
                    total.unknown_trip_counts += sub.unknown_trip_counts
                    eff = self._fusion_effective_bytes(called.group(1))
                if eff is not None:
                    # in-place slice pattern: aliased big operands excluded
                    total.traffic += eff
                else:
                    seg = line.split("(", 1)[1]
                    ops = _OPERANDS.findall(seg.split(")", 1)[0])
                    total.traffic += out_bytes + sum(
                        _shape_bytes(shapes.get(o, "")) for o in ops)
            elif op == "while":
                body = _CALLS.search(line)
                cond = _COND.search(line)
                mt = _TRIP_RE.search(line)
                trips = int(mt.group(1)) if mt else (
                    self._trip_count(cond.group(1)) if cond else None)
                sub = HloCost()
                if body and body.group(1) in self.comps:
                    sub = self.cost(body.group(1))
                if cond and cond.group(1) in self.comps:
                    csub = self.cost(cond.group(1))
                    sub = HloCost(
                        sub.flops + csub.flops, sub.traffic + csub.traffic,
                        sub.collective_bytes + csub.collective_bytes,
                        sub.collectives, sub.unknown_trip_counts)
                if trips is None:
                    trips = 1
                    total.unknown_trip_counts += 1
                total.add(sub.scaled(trips))
            elif op == "conditional":
                mb = _BRANCHES.search(line)
                names = []
                if mb:
                    names = [n.strip().lstrip("%") for n in mb.group(1).split(",")]
                else:
                    names = [c.group(1) for c in
                             re.finditer(r"(?:true|false)_computation=%?([\w\.\-]+)", line)]
                subs = [self.cost(n) for n in names if n in self.comps]
                if subs:
                    best = max(subs, key=lambda s: s.flops)
                    total.add(best)
            elif op in _COLL_OPS or (
                op.endswith("-start") and op[:-6] in _COLL_OPS
            ):
                base = op[:-6] if op.endswith("-start") else op
                gm = _GROUPS_RE.search(line)
                if gm:
                    n = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACE_RE.search(line)
                    n = len(gb.group(1).split(",")) if gb else 2
                moved = _coll_moved(base, out_bytes, n)
                total.collective_bytes += moved
                d = total.collectives.setdefault(
                    base, {"count": 0, "moved_bytes": 0.0})
                d["count"] += 1
                d["moved_bytes"] += moved
                total.traffic += out_bytes
            elif op == "dynamic-update-slice":
                ops = _OPERANDS.findall(line.split("(", 1)[1].split(")", 1)[0])
                upd = _shape_bytes(shapes.get(ops[1], "")) if len(ops) > 1 else 0
                total.traffic += 2 * (upd or out_bytes)
            elif op == "dynamic-slice":
                total.traffic += 2 * out_bytes
            elif op in _VIEW_OPS:
                pass  # views: no physical movement
            else:
                # top-level elementwise / copies / slices: HBM traffic only
                if "[" in type_str:
                    total.traffic += out_bytes
        self._cache[comp_name] = total
        return total


def analyze_hlo(hlo_text: str) -> HloCost:
    mod = _Module(hlo_text)
    if mod.entry is None:
        # fall back: largest computation
        if not mod.comps:
            return HloCost()
        mod.entry = max(mod.comps, key=lambda c: len(mod.comps[c]))
    return mod.cost(mod.entry)
