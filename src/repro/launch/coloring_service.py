"""Coloring-as-a-service: the session-pool serving layer (DESIGN.md §19).

``ColoringService`` turns the repo's coloring engines into a long-lived
server loop with explicit capacity contracts:

* **Session pool.**  The service owns a pool of live ``ColoringSession``
  objects (§14) keyed by caller-chosen ids, LRU-ordered.  Admission past
  ``pool_size`` evicts the least-recently-used session: with a
  ``spill_dir`` the victim is checkpointed through the §17 durable
  journal (``attach_durable``) and transparently ``restore()``d on its
  next touch; without one the eviction is permanent and later touches
  raise the structured ``SessionEvicted``.

* **Bounded queue + backpressure.**  Every request enters one bounded
  FIFO queue.  A full queue REJECTS at submit time with ``Overloaded``
  (payload: depth, limit, a retry-after hint from the recent per-request
  service time) — the queue never grows without bound and the caller
  always learns immediately, instead of timing out into an opaque stall.

* **Micro-batching.**  One-shot ``color()`` requests drained in the same
  cycle are bucketed by ``(distance2, pow2 n class, pow2 width class,
  ColorOptions)`` and dispatched as ONE padded ``color_batch_fused``
  call per bucket: the batch is padded to a pow2 graph count, a pow2
  ``n_max`` (one edge-free shape graph) and a pow2 adjacency width, so a
  bucket presents ONE jit cache key per pow2 batch size — steady-state
  traffic never leaves the jit cache.  Per-graph results are independent
  of the padding (the batched engine vmaps per graph), so service colors
  are bit-identical to direct ``repro.color`` calls.  Requests the
  batched engine cannot host (other algorithms, ``ensure_valid``,
  ``trace``, ``validate_input``, extra knobs) fall back to per-request
  ``repro.color`` inside the worker — same results, no bucketing.

* **Deferred maintenance.**  Pooled sessions run with
  ``defer_maintenance=True``: DeltaCSR compaction and durable snapshots
  never fire inside a request; the worker runs ``session.maintain()``
  in idle slots instead, so tail latency is bounded by coloring work
  only.

* **Unified options (§19).**  Everything accepts ``ColorOptions`` or the
  equivalent loose kwargs; per-session/per-request options override the
  service-wide default.  Errors cross the thread boundary as the
  ``repro.errors`` hierarchy, so callers can map them to structured
  responses (``exc.payload()``) without string matching.

Synchronous calls block on a ``Ticket`` (a thread-safe future-lite that
also timestamps enqueue/start/finish — the latency the serving benchmark
reports); pass ``wait=False`` to get the ticket itself and overlap
request submission, as ``benchmarks/serve.py`` does for Poisson traffic.

The LM serving driver that previously lived at ``repro.launch.serve``
moved to ``repro.launch.serve_lm``.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict, deque

import numpy as np

from repro.errors import Overloaded, SessionEvicted
from repro.obs.spans import SpanRecorder, span
from repro.options import ColorOptions

__all__ = ["ColoringService", "Ticket"]


class Ticket:
    """One queued request's completion handle (thread-safe future-lite).

    ``wait()`` blocks until the worker finished the request, re-raising
    the worker-side exception verbatim.  Timestamps (``enqueued_at``,
    ``started_at``, ``done_at``; monotonic seconds) make queueing delay
    and service time separable: ``latency`` is the full submit→finish
    wall time a client observes.
    """

    __slots__ = ("kind", "sid", "payload", "options", "result", "error",
                 "enqueued_at", "started_at", "done_at", "_event")

    def __init__(self, kind: str, sid: str | None = None, payload=None,
                 options: ColorOptions | None = None):
        self.kind = kind
        self.sid = sid
        self.payload = payload
        self.options = options
        self.result = None
        self.error: BaseException | None = None
        self.enqueued_at = time.perf_counter()
        self.started_at: float | None = None
        self.done_at: float | None = None
        self._event = threading.Event()

    def wait(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.kind!r} did not finish within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency(self) -> float:
        """Submit→finish wall seconds (queueing + service time)."""
        if self.done_at is None:
            raise RuntimeError("request has not finished")
        return self.done_at - self.enqueued_at

    def _finish(self, result=None, error: BaseException | None = None):
        self.result = result
        self.error = error
        self.done_at = time.perf_counter()
        self._event.set()


def _safe_name(sid: str) -> str:
    """A filesystem-safe spill directory name for a caller-chosen sid."""
    return "".join(c if (c.isalnum() or c in "-_.") else "_" for c in sid)


class ColoringService:
    """Session-pool coloring server: see the module doc for the contract.

    Parameters
    ----------
    pool_size:
        Live ``ColoringSession`` capacity; admission past it evicts LRU.
    queue_limit:
        Bounded request queue depth; a full queue raises ``Overloaded``
        at submit time (backpressure, never unbounded growth).
    max_batch:
        Requests drained per worker cycle (the micro-batch window).
    spill_dir:
        Directory for durable eviction spill (§17 journals); ``None``
        makes evictions permanent (``SessionEvicted`` on later touch).
    options:
        Service-wide default ``ColorOptions`` (or ``None``); per-call
        options/kwargs override it.
    trace:
        Keep a live ``SpanRecorder`` over the worker loop; drained via
        ``take_spans()`` (per-request / micro-batch / maintenance spans).
    """

    def __init__(self, *, pool_size: int = 8, queue_limit: int = 64,
                 max_batch: int = 32, spill_dir: str | None = None,
                 options: ColorOptions | None = None,
                 idle_maintenance: bool = True, trace: bool = False):
        if pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {pool_size}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self._pool_size = int(pool_size)
        self._queue_limit = int(queue_limit)
        self._max_batch = max(1, int(max_batch))
        self._spill_dir = spill_dir
        self._default_options = (ColorOptions() if options is None
                                 else ColorOptions.normalize(options))
        self._idle_maintenance = bool(idle_maintenance)
        self._recorder = SpanRecorder() if trace else None

        self._lock = threading.Lock()        # queue + counters
        self._pool_lock = threading.Lock()   # pool/spill/bucket structures
        self._not_empty = threading.Condition(self._lock)
        self._queue: deque[Ticket] = deque()
        self._pool: "OrderedDict[str, object]" = OrderedDict()
        self._spilled: set[str] = set()      # sids durable on disk, not live
        self._evicted: set[str] = set()      # sids dropped with no spill
        self._jit_keys: set = set()          # (bucket, pow2 B) keys presented
        self._bucket_stats: dict = {}
        self._counters = {
            "admitted": 0, "rejected": 0, "completed": 0, "failed": 0,
            "evictions": 0, "spills": 0, "restores": 0, "maintenance": 0,
            "microbatches": 0, "batched_requests": 0, "slow_requests": 0,
            "bucket_jit_hits": 0, "bucket_jit_misses": 0,
        }
        self._ewma_req_s = 0.0
        self._closed = False
        self._worker = threading.Thread(
            target=self._run, name="coloring-service", daemon=True)
        self._worker.start()

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "ColoringService":
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    # -- submission (any thread) --------------------------------------------
    def _submit(self, ticket: Ticket) -> Ticket:
        with self._not_empty:
            if self._closed:
                raise RuntimeError("ColoringService is shut down")
            depth = len(self._queue)
            if depth >= self._queue_limit:
                self._counters["rejected"] += 1
                raise Overloaded(
                    f"request queue full ({depth}/{self._queue_limit}); "
                    "retry after the backlog drains",
                    queue_depth=depth, limit=self._queue_limit,
                    retry_after=round(depth * self._ewma_req_s, 6))
            self._queue.append(ticket)
            self._counters["admitted"] += 1
            self._not_empty.notify()
        return ticket

    def _normalize(self, options, opts) -> ColorOptions:
        base = self._default_options if options is None else options
        return ColorOptions.normalize(base, **opts)

    # -- public API ---------------------------------------------------------
    def open_session(self, sid: str, graph, *, options=None, wait=True,
                     **opts):
        """Admit a session for ``graph`` under id ``sid`` (evicting LRU).

        Returns a summary dict (n, num_colors, converged, evicted victim
        if any).  Re-using a live ``sid`` replaces that session.
        """
        o = self._normalize(options, opts)
        t = Ticket("open", sid=sid, payload=graph, options=o)
        self._submit(t)
        return t.wait() if wait else t

    def apply_delta(self, sid: str, *, wait=True, **delta):
        """Mutate session ``sid``; returns the dirtied vertex ids."""
        t = Ticket("delta", sid=sid, payload=delta)
        self._submit(t)
        return t.wait() if wait else t

    def recolor(self, sid: str, *, full: bool = False, wait=True):
        """Repair session ``sid`` after pending deltas (``ColoringResult``).

        Back-to-back recolors of one session drained in the same cycle
        coalesce naturally: the first clears the frontier, the rest are
        zero-work no-ops returning the committed coloring.
        """
        t = Ticket("recolor", sid=sid, payload={"full": bool(full)})
        self._submit(t)
        return t.wait() if wait else t

    def colors(self, sid: str, *, wait=True):
        """The committed coloring of session ``sid`` (a copy)."""
        t = Ticket("colors", sid=sid)
        self._submit(t)
        return t.wait() if wait else t

    def color(self, graph, *, options=None, wait=True, **opts):
        """One-shot coloring through the micro-batcher (``ColoringResult``).

        Requests sharing a ``(shape class, ColorOptions)`` bucket in a
        drain cycle run as one padded batched call (see module doc);
        colors are bit-identical to ``repro.color(graph, options=...)``.
        """
        o = self._normalize(options, opts)
        t = Ticket("color", payload=graph, options=o)
        self._submit(t)
        return t.wait() if wait else t

    def session_metrics(self, sid: str, *, wait=True):
        """The session's own ``metrics()`` dict (§16 counters)."""
        t = Ticket("session_metrics", sid=sid)
        self._submit(t)
        return t.wait() if wait else t

    def close_session(self, sid: str, *, wait=True):
        """Drop session ``sid`` from the pool (spilled state stays on disk)."""
        t = Ticket("close", sid=sid)
        self._submit(t)
        return t.wait() if wait else t

    def maintain(self, sid: str | None = None, *, wait=True):
        """Run due deferred maintenance NOW (compaction / snapshot).

        ``sid=None`` sweeps every live session.  Idle-slot maintenance only
        fires after a sustained silence, so a service under continuous load
        should call this in a known lull (rollout pause, low-traffic
        window) — otherwise session overlays keep growing and recolor cost
        creeps.  Returns ``{sid: [actions...]}``.
        """
        t = Ticket("maintain", sid=sid)
        self._submit(t)
        return t.wait() if wait else t

    def metrics(self) -> dict:
        """Service-level counters: queue, pool, buckets, jit accounting.

        ``bucket_jit_misses`` counts micro-batch dispatches whose
        ``(bucket, pow2 batch size)`` key was never presented before — the
        serving CI gate pins this to the warmup phase (zero after).
        """
        with self._lock:
            out = dict(self._counters)
            out["queue_depth"] = len(self._queue)
            out["queue_limit"] = self._queue_limit
            out["ewma_request_seconds"] = self._ewma_req_s
        with self._pool_lock:
            out["pool_occupancy"] = len(self._pool)
            out["pool_size"] = self._pool_size
            out["spilled_sessions"] = len(self._spilled)
            out["buckets"] = {k: dict(v) for k, v in
                              self._bucket_stats.items()}
            sessions = list(self._pool.values())
        hits = misses = 0
        for s in sessions:
            c = s._counters
            hits += c["engine_cache_hits"]
            misses += c["engine_cache_misses"]
        out["session_engine_cache_hits"] = hits
        out["session_engine_cache_misses"] = misses
        return out

    def take_spans(self) -> list:
        """Drain the service recorder's span events (``trace=True`` only)."""
        if self._recorder is None:
            return []
        events, self._recorder.events = self._recorder.events, []
        return events

    def shutdown(self, *, wait: bool = True) -> None:
        """Stop accepting requests; drain the queue, then stop the worker."""
        with self._not_empty:
            if self._closed:
                return
            self._closed = True
            self._not_empty.notify_all()
        if wait:
            self._worker.join()

    # -- worker loop ---------------------------------------------------------
    def _run(self) -> None:
        if self._recorder is not None:
            with self._recorder:
                self._loop()
        else:
            self._loop()

    def _loop(self) -> None:
        while True:
            with self._not_empty:
                # Hysteresis: a maintenance slice (compaction/snapshot) can
                # stall the worker for a while, so a gap between Poisson
                # arrivals must NOT trigger one — only a sustained silence
                # (several full poll intervals, ~0.25 s) counts as idle.
                idle = 0
                while not self._queue and not self._closed:
                    if (idle >= 5 and self._idle_maintenance
                            and self._maintenance_target()):
                        break  # leave the lock to run one maintenance slice
                    idle = 0 if self._not_empty.wait(timeout=0.05) else idle + 1
                if self._closed and not self._queue:
                    return
                cycle = [self._queue.popleft()
                         for _ in range(min(len(self._queue),
                                            self._max_batch))]
            if not cycle:
                self._run_maintenance()
                continue
            self._dispatch(cycle)

    def _dispatch(self, cycle: list[Ticket]) -> None:
        t0 = time.perf_counter()
        # Arrival order, batching maximal CONSECUTIVE runs of one-shot
        # colors.  Hoisting all session ops ahead of the colors would invert
        # priority — a color enqueued first would wait on session ops that
        # arrived after it — so only adjacent colors share a micro-batch.
        i = 0
        while i < len(cycle):
            if cycle[i].kind == "color":
                j = i
                while j < len(cycle) and cycle[j].kind == "color":
                    cycle[j].started_at = time.perf_counter()
                    j += 1
                self._dispatch_colors(cycle[i:j])
                i = j
            else:
                cycle[i].started_at = time.perf_counter()
                self._run_session_op(cycle[i])
                i += 1
        # retry-after hint: EWMA of per-request service time this cycle
        per_req = (time.perf_counter() - t0) / len(cycle)
        self._ewma_req_s = (per_req if self._ewma_req_s == 0.0
                            else 0.8 * self._ewma_req_s + 0.2 * per_req)

    # -- session ops ---------------------------------------------------------
    def _run_session_op(self, t: Ticket) -> None:
        try:
            with span("serve_request", kind=t.kind, sid=t.sid):
                result = getattr(self, f"_op_{t.kind}")(t)
            self._counters["completed"] += 1
            t._finish(result=result)
        except BaseException as e:  # cross the thread boundary verbatim
            self._counters["failed"] += 1
            t._finish(error=e)

    def _touch(self, sid: str):
        """The live session for ``sid``, restoring a spilled one (LRU bump)."""
        with self._pool_lock:
            sess = self._pool.get(sid)
            if sess is not None:
                self._pool.move_to_end(sid)
                return sess
        if sid in self._spilled:
            from repro.dynamic import ColoringSession

            with span("serve_restore", sid=sid):
                sess = ColoringSession.restore(self._spill_path(sid))
            with self._pool_lock:
                self._spilled.discard(sid)
                self._counters["restores"] += 1
                self._admit(sid, sess)
            return sess
        if sid in self._evicted:
            raise SessionEvicted(
                f"session {sid!r} was evicted from the pool (no spill_dir "
                "was configured); re-open it from the source graph",
                session_id=sid)
        raise KeyError(f"unknown session id {sid!r}")

    def _spill_path(self, sid: str) -> str:
        return os.path.join(self._spill_dir, _safe_name(sid))

    def _admit(self, sid: str, sess) -> str | None:
        """Insert ``sess`` under ``sid``, evicting LRU victims past capacity."""
        victim = None
        while len(self._pool) >= self._pool_size:
            vsid, vsess = self._pool.popitem(last=False)
            self._counters["evictions"] += 1
            if self._spill_dir is not None:
                with span("serve_spill", sid=vsid):
                    vsess.attach_durable(self._spill_path(vsid))
                self._spilled.add(vsid)
                self._counters["spills"] += 1
            else:
                self._evicted.add(vsid)
            victim = vsid
        self._pool[sid] = sess
        return victim

    def _op_open(self, t: Ticket):
        from repro.core.csr import CSRGraph
        from repro.dynamic import ColoringSession

        graph = t.payload
        if not isinstance(graph, CSRGraph):
            raise TypeError(
                "open_session takes a CSRGraph; build one first (e.g. "
                f"csr_from_edges) — got {type(graph).__name__}")
        kwargs = t.options.session_kwargs()
        kwargs.setdefault("defer_maintenance", True)
        sess = ColoringSession(graph, **kwargs)
        with self._pool_lock:
            self._evicted.discard(t.sid)
            self._spilled.discard(t.sid)
            self._pool.pop(t.sid, None)  # re-open replaces
            victim = self._admit(t.sid, sess)
        return {"sid": t.sid, "n": int(sess.n),
                "num_colors": int(sess.num_colors),
                "converged": bool(sess.result.converged),
                "evicted": victim}

    def _op_delta(self, t: Ticket):
        return self._touch(t.sid).apply_delta(**t.payload)

    def _op_recolor(self, t: Ticket):
        return self._touch(t.sid).recolor(full=t.payload["full"])

    def _op_colors(self, t: Ticket):
        return np.asarray(self._touch(t.sid).colors).copy()

    def _op_session_metrics(self, t: Ticket):
        return self._touch(t.sid).metrics()

    def _op_close(self, t: Ticket):
        with self._pool_lock:
            existed = self._pool.pop(t.sid, None) is not None
            existed = (t.sid in self._spilled) or existed
            self._spilled.discard(t.sid)
            self._evicted.discard(t.sid)
        return bool(existed)

    def _op_maintain(self, t: Ticket):
        if t.sid is not None:
            sids = [t.sid]
        else:
            with self._pool_lock:
                sids = list(self._pool.keys())
        out = {}
        for sid in sids:
            sess = self._touch(sid)
            with span("serve_maintenance", sid=sid):
                actions = sess.maintain()
            out[sid] = actions
            if actions:
                self._counters["maintenance"] += 1
        return out

    # -- one-shot micro-batching ---------------------------------------------
    def _bucket_key(self, graph, o: ColorOptions):
        """The micro-batch bucket, or None for the per-request slow path."""
        import dataclasses

        from repro.core.csr import CSRGraph, next_pow2

        if not isinstance(graph, CSRGraph):
            return None
        algorithm = o.algorithm or "fused"
        if (algorithm not in ("fused", "distance2")
                or o.engine not in (None, "batch") or o.ensure_valid
                or o.trace or o.validate_input is not None or o.extra):
            return None
        d2 = algorithm == "distance2"
        wb = graph.two_hop_degree_bound() if d2 else graph.max_degree
        canon = dataclasses.replace(o, algorithm=algorithm, engine=None)
        return (d2, next_pow2(max(graph.n, 1)), next_pow2(max(wb, 1)), canon)

    def _dispatch_colors(self, tickets: list[Ticket]) -> None:
        buckets: dict = {}
        for t in tickets:
            key = self._bucket_key(t.payload, t.options)
            if key is None:
                self._run_slow_color(t)
            else:
                buckets.setdefault(key, []).append(t)
        for key, ts in buckets.items():
            self._run_bucket(key, ts)

    def _run_slow_color(self, t: Ticket) -> None:
        import repro.api as api

        try:
            with span("serve_request", kind="color_slow"):
                result = api.color(t.payload, options=t.options)
            self._counters["completed"] += 1
            self._counters["slow_requests"] += 1
            t._finish(result=result)
        except BaseException as e:
            self._counters["failed"] += 1
            t._finish(error=e)

    def _run_bucket(self, key, tickets: list[Ticket]) -> None:
        from repro.core.batch import GraphBatch, _EMPTY, color_batch_fused
        from repro.core.csr import CSRGraph, next_pow2

        d2, n2, w2, o = key
        try:
            real = [t.payload for t in tickets]
            # pad to a pow2 jit key: one edge-free graph of n2 vertices pins
            # n_max, _EMPTY graphs pin the batch count, width= pins W —
            # per-graph results are independent of all three (vmap)
            shape_pad = CSRGraph(np.zeros(n2 + 1, np.int64),
                                 np.zeros(0, np.int32))
            Bp = next_pow2(len(real) + 1)
            batch = GraphBatch.from_graphs(
                real + [shape_pad] + [_EMPTY] * (Bp - len(real) - 1),
                width=w2, distance2=d2)
            kw = {k: v for k, v in o.engine_kwargs().items()
                  if k in ("heuristic", "firstfit", "max_iters",
                           "tail_serial", "backend")}
            jkey = (d2, Bp, n2, w2, o)
            stats = self._bucket_stats.setdefault(
                repr((d2, n2, w2, o.describe())),
                {"requests": 0, "dispatches": 0, "jit_hits": 0,
                 "jit_misses": 0})
            hit = jkey in self._jit_keys
            self._jit_keys.add(jkey)
            self._counters["bucket_jit_hits" if hit else
                           "bucket_jit_misses"] += 1
            stats["jit_hits" if hit else "jit_misses"] += 1
            stats["requests"] += len(real)
            stats["dispatches"] += 1
            with span("serve_microbatch", B=len(real), padded_B=Bp,
                      d2=d2, jit_hit=hit):
                results = color_batch_fused(batch, distance2=d2, **kw)
            self._counters["microbatches"] += 1
            self._counters["batched_requests"] += len(real)
            self._counters["completed"] += len(tickets)
            for t, r in zip(tickets, results):
                t._finish(result=r)
        except BaseException as e:
            self._counters["failed"] += len(tickets)
            for t in tickets:
                t._finish(error=e)

    # -- idle maintenance ----------------------------------------------------
    def _maintenance_target(self) -> str | None:
        for sid, sess in self._pool.items():
            due = sess.maintenance_due()
            if due["compact"] or due["snapshot"]:
                return sid
        return None

    def _run_maintenance(self) -> None:
        """One deferred-maintenance slice (one session), preemptible."""
        with self._pool_lock:
            sid = self._maintenance_target()
            sess = self._pool.get(sid) if sid is not None else None
        if sess is None:
            return
        with span("serve_maintenance", sid=sid):
            done = sess.maintain()
        if done:
            self._counters["maintenance"] += 1
