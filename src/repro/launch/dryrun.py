import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# --- the two lines above MUST run before any jax import (device count locks
# at first init).  Tests may shrink the placeholder fleet via env override:
if os.environ.get("REPRO_DRYRUN_FLAGS"):
    os.environ["XLA_FLAGS"] = os.environ["REPRO_DRYRUN_FLAGS"]

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding resolution is coherent (SPMD partitioner accepts it),
  * the program fits (memory_analysis),
  * and extracts the roofline inputs: cost_analysis FLOPs/bytes plus
    collective bytes parsed from the post-SPMD HLO.

Results append incrementally to a JSON file consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    replicated,
    state_shardings,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}

# long_500k needs sub-quadratic attention: SWA (mixtral), RG-LRU hybrid,
# linear-attention RWKV.  Pure full-attention archs skip it (DESIGN.md §9).
SUBQUADRATIC = {"mixtral-8x22b", "recurrentgemma-2b", "rwkv6-1.6b"}


def plan_cells() -> list[tuple[str, str, str | None]]:
    """(arch, shape, skip_reason|None) for all 40 nominal cells."""
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            skip = None
            if cfg.family == "encoder" and shape in ("decode_32k", "long_500k"):
                skip = "encoder-only: no decode step"
            elif shape == "long_500k" and arch not in SUBQUADRATIC:
                skip = "full quadratic attention at 500k"
            cells.append((arch, shape, skip))
    return cells


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
# instruction lines look like:
#   %x = s32[16,1024]{1,0} all-gather(%y), channel_id=3, replica_groups=[64,4]<=[256], ...
# operands print WITHOUT type annotations, so transfer volume is accounted
# from the OUTPUT shape + the replica group size n (ring-algorithm costs):
#   all-gather:         out * (n-1)/n         (out = gathered size)
#   all-reduce:         out * 2(n-1)/n
#   reduce-scatter:     out * (n-1)            (input = n * out)
#   all-to-all:         out * (n-1)/n
#   collective-permute: out
_LINE_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLL_OPS) + r")(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _coll_bytes(op: str, out_bytes: int, n: int) -> float:
    n = max(n, 2)
    if op == "all-gather":
        return out_bytes * (n - 1) / n
    if op == "all-reduce":
        return out_bytes * 2 * (n - 1) / n
    if op == "reduce-scatter":
        return out_bytes * (n - 1)
    if op == "all-to-all":
        return out_bytes * (n - 1) / n
    return float(out_bytes)  # collective-permute


def collective_stats(hlo_text: str) -> dict:
    stats: dict[str, dict] = {
        op: {"count": 0, "out_bytes": 0, "moved_bytes": 0.0} for op in _COLL_OPS
    }
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or "-done(" in line:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        nelem = 1
        for d in dims.split(","):
            if d:
                nelem *= int(d)
        out_bytes = nelem * _DTYPE_BYTES[dtype]
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            n = len(gb.group(1).split(",")) if gb else 2
        stats[op]["count"] += 1
        stats[op]["out_bytes"] += out_bytes
        stats[op]["moved_bytes"] += _coll_bytes(op, out_bytes, n)
    stats["total_factored_bytes"] = sum(
        s["moved_bytes"] for s in stats.values() if isinstance(s, dict)
    )
    return stats


def memory_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover - backend-dependent
        return {"error": str(e)}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals", "utilization"):
        if k in ca:
            keep[k] = float(ca[k])
    return keep


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def build_lowered(arch: str, shape_name: str, mesh, cfg=None):
    cfg = cfg or get_config(arch)
    model = build_model(cfg, mesh=mesh)
    info = SHAPES[shape_name]
    S, B, mode = info["seq"], info["batch"], info["mode"]

    params_s = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    params_sh = state_shardings(params_s, mesh)

    if mode == "train":
        state_s = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0)))
        state_sh = state_shardings(state_s, mesh)
        batch_s = model.input_specs(B, S, "train")
        batch_sh = batch_shardings(batch_s, mesh)
        step = make_train_step(model, AdamWConfig())
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,))
        return jitted.lower(state_s, batch_s)

    if mode == "prefill":
        batch_s = model.input_specs(B, S, "prefill")
        batch_sh = batch_shardings(batch_s, mesh)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, S)

        jitted = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
        return jitted.lower(params_s, batch_s)

    # decode: one token against a seq_len-deep cache
    specs = model.input_specs(B, S, "decode")
    caches_s, token_s = specs["caches"], specs["token"]
    cache_sh = cache_shardings(caches_s, mesh)
    token_sh = batch_shardings(token_s, mesh)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(params_sh, cache_sh, token_sh, replicated(mesh)),
        donate_argnums=(1,),
    )
    return jitted.lower(params_s, caches_s, token_s, pos_s)


def run_cell(arch: str, shape_name: str, mesh_kind: str, mesh=None) -> dict:
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    cfg = get_config(arch)
    info = SHAPES[shape_name]
    n_total, n_active = cfg.params_estimate()
    tokens = info["batch"] * (info["seq"] if info["mode"] != "decode" else 1)
    flops_per_tok = 6 if info["mode"] == "train" else 2
    rec.update(
        params_total=n_total,
        params_active=n_active,
        model_flops=float(flops_per_tok * n_active * tokens),
        mode=info["mode"],
    )
    try:
        if mesh is None:
            mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
        t0 = time.time()
        lowered = build_lowered(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["cost"] = cost_stats(compiled)
        rec["memory"] = memory_stats(compiled)
        text = compiled.as_text()
        rec["collectives"] = collective_stats(text)
        hc = analyze_hlo(text)
        rec["analysis"] = {
            "flops": hc.flops,
            "traffic_bytes": hc.traffic,
            "collective_bytes": hc.collective_bytes,
            "collectives": hc.collectives,
            "unknown_trip_counts": hc.unknown_trip_counts,
        }
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def _load(out):
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    return []


def _save(out, records):
    tmp = out + ".tmp"
    with open(tmp, "w") as f:
        json.dump(records, f, indent=1)
    os.replace(tmp, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "pod", "both"), default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--mesh-shape", help="override, e.g. 2x4 (tests)")
    ap.add_argument("--mesh-axes", help="override, e.g. data,model (tests)")
    args = ap.parse_args()

    if args.list:
        for arch, shape, skip in plan_cells():
            print(f"{arch:22s} {shape:12s} {'SKIP: ' + skip if skip else 'run'}")
        return

    mesh_override = None
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split("x"))
        axes = tuple(args.mesh_axes.split(",")) if args.mesh_axes else (
            ("data", "model") if len(shape) == 2 else ("pod", "data", "model"))
        mesh_override = jax.make_mesh(shape, axes)

    records = _load(args.out)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records if r.get("ok")}

    cells = plan_cells()
    if not args.all:
        cells = [
            (a, s, sk) for a, s, sk in cells
            if (args.arch is None or a == args.arch)
            and (args.shape is None or s == args.shape)
        ]
    meshes = ["single", "pod"] if args.mesh == "both" else [args.mesh]

    for arch, shape, skip in cells:
        for mesh_kind in meshes:
            key = (arch, shape, mesh_kind)
            if skip:
                if not any(
                    r["arch"] == arch and r["shape"] == shape
                    and r["mesh"] == mesh_kind for r in records
                ):
                    records.append({
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "ok": True, "skipped": skip,
                    })
                    _save(args.out, records)
                print(f"SKIP {arch} {shape} {mesh_kind}: {skip}", flush=True)
                continue
            if key in done and not args.force:
                print(f"done {arch} {shape} {mesh_kind} (cached)", flush=True)
                continue
            print(f"RUN  {arch} {shape} {mesh_kind} ...", flush=True)
            rec = run_cell(arch, shape, mesh_kind, mesh=mesh_override)
            records = [
                r for r in records
                if (r["arch"], r["shape"], r["mesh"]) != key
            ] + [rec]
            _save(args.out, records)
            status = "OK" if rec.get("ok") else f"FAIL {rec.get('error')}"
            print(
                f"  -> {status} lower={rec.get('lower_s')}s "
                f"compile={rec.get('compile_s')}s "
                f"flops={rec.get('cost', {}).get('flops')}",
                flush=True,
            )


if __name__ == "__main__":
    main()
