"""Batched LM serving driver: prefill a batch of prompts, decode greedily.

(Relocated from ``repro.launch.serve`` when the coloring service (§19) took
the serving slot — see ``repro.launch.coloring_service``.)

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-4b --reduced \
        --batch 4 --prompt-len 16 --gen 24

Uses the same prefill/decode_step paths the dry-run lowers at 32k/500k scale;
on this CPU host run it with --reduced.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.training.data import SyntheticData

__all__ = ["serve_batch", "main"]


def serve_batch(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    data = SyntheticData.for_model(cfg, batch, prompt_len, seed=seed)
    prompts = jnp.asarray(data.batch(0)["tokens"])

    T = prompt_len + gen + (cfg.n_patches if cfg.family == "vlm" else 0)
    pre = {"tokens": prompts}
    if cfg.family == "vlm":
        pre["patches"] = jnp.asarray(data.batch(0)["patches"])

    t0 = time.perf_counter()
    caches, logits = model.prefill(params, pre, T)
    t_prefill = time.perf_counter() - t0

    dec = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    off = cfg.n_patches if cfg.family == "vlm" else 0
    t0 = time.perf_counter()
    for t in range(gen - 1):
        caches, logits = dec(params, caches, tok,
                             jnp.int32(prompt_len + off + t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    tokens = np.concatenate([np.asarray(t) for t in out], axis=1)
    return {
        "generated": tokens,
        "prefill_s": t_prefill,
        "decode_tok_s": (gen - 1) * batch / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen)
    print(f"[serve] batch={args.batch} prefill={out['prefill_s']*1e3:.1f}ms "
          f"decode={out['decode_tok_s']:.1f} tok/s (incl. jit warmup)")
    print(f"[serve] sample generation: {out['generated'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
