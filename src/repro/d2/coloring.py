"""Distance-2 speculative-greedy coloring on the SGR super-step (DESIGN.md §11).

A distance-2 coloring gives distinct colors to any two vertices within
distance ≤ 2 — equivalently, a distance-1 coloring of the square graph G².
That equivalence is the backbone of this module; two execution strategies
share one quality contract:

* ``precomputed`` — build G² host-side (``CSRGraph.square``) and run the
  UNCHANGED distance-1 ragged engine (``core.coloring.run_ragged_engine``)
  over its CSR — the same rotated super-step, degree-tiled dispatch, and
  adaptive tail-serialization as distance-1 (§12).  This is also what the
  batched engine packs (``core/batch.py``), so batched D2 is bit-identical
  to per-graph fused D2 for free.
* ``onthefly`` — when the ``(n, W2)`` square view would blow the memory
  budget, compose TWO sentinel-padded gathers through ``colors_ext`` per
  super-step instead (``TwoHopRows``): sentinel ids yield all-sentinel rows
  in hop 1, which yield all-sentinel rows again in hop 2, so padding stays
  inert through both hops — the D2 analogue of the §2 trick.  The
  ``coarsen`` knob chunks the worklist to bound the ``(w, W + W²)``
  transient, mirroring D1 thread coarsening.

Both strategies order conflict losers by the ORIGINAL graph's degree (ties
by id) — not G²'s — and the rotated super-step is insensitive to duplicate
or self lanes (duplicates cannot change a forbidden set or an any-reduce;
the self lane never beats its owner under either strict total order, and
the serial tail masks it explicitly), so with ``coarsen=1`` the two
strategies produce bit-identical colorings (tested) and the choice is
purely a memory/performance policy.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import register
from repro.core.coloring import (
    ColoringResult,
    _chunk_bounds,
    _packed_gather_ok,
    _resolve_classes,
    compact,
    cr_flags,
    ff_apply,
    gather_rows,
    resolve_tail_threshold,
    run_ragged_engine,
)
from repro.core.csr import CSRGraph, DeviceCSR, PartitionedCSR
from repro.obs.spans import SpanRecorder, span
from repro.obs.trace import empty_trace

__all__ = ["color_distance2", "d2_sgr_step", "TwoHopRows", "DEFAULT_D2_BUDGET"]

# bytes the precomputed strategy may spend on the (n, W2) square view plus
# the transient two-hop pair expansion; past this, auto falls back to
# on-the-fly composition (the W2 capping policy of DESIGN.md §11)
DEFAULT_D2_BUDGET = 256 * 2**20


class TwoHopRows:
    """Composed two-hop row provider: ``ids → adj_a → adj_b`` (§11 + §12).

    For distance-2 on one graph, ``adj_a is adj_b`` and hop-1 neighbors are
    part of the neighborhood (``include_first_hop=True``); for bipartite
    partial coloring, ``adj_a`` is cols→rows, ``adj_b`` rows→cols, and only
    hop-2 (column-side) ids carry colors.  Tiles may contain duplicate and
    self lanes — harmless to the rotated super-step (see module docstring).

    The provider also runs over a ``PartitionedCSR`` shard (§13): pass the
    shard's dense first-hop slice as ``adj_a`` with ``start`` = its first
    owned id and ``n_colored`` = the GLOBAL colored-side count.  Worklist
    ids stay global (``id - start`` picks the local row), hop-1 output ids
    stay global, and ``adj_b`` is the whole second hop — so the composed
    tile is identical to the unsharded one and sharded distance-2/bipartite
    colors match single-device runs bit-for-bit.
    """

    def __init__(self, adj_a, adj_b, include_first_hop: bool = True,
                 start=0, n_colored: int | None = None):
        self.adj_a = adj_a
        self.adj_b = adj_b
        self.include_first_hop = bool(include_first_hop)
        self.start = start
        self.n_colored = n_colored

    @property
    def width(self) -> int:
        w1, w2 = int(self.adj_a.shape[1]), int(self.adj_b.shape[1])
        return w1 * w2 + (w1 if self.include_first_hop else 0)

    def rows(self, ids, width: int | None = None):
        n = (int(self.adj_a.shape[0]) if self.n_colored is None
             else self.n_colored)               # colored side (global)
        n_rows = self.adj_a.shape[0]
        lidx = ids - self.start
        rows1 = self.adj_a[jnp.clip(lidx, 0, n_rows - 1)]
        valid = (ids < n) & (lidx < n_rows)
        rows1 = jnp.where(valid[:, None], rows1, self.adj_b.shape[0])
        rows2 = gather_rows(self.adj_b, rows1.reshape(-1), sentinel=n)
        rows2 = rows2.reshape(ids.shape[0], -1)
        if self.include_first_hop:
            # hop-1 fill ids index the MID side; remap masked lanes to the
            # colored-side sentinel so they stay inert through colors_ext
            rows1 = jnp.where(valid[:, None], rows1, n)
            return jnp.concatenate([rows1, rows2], axis=1)
        return rows2

    def row1(self, v):
        return self.rows(v[None])[0]


jax.tree_util.register_pytree_node(
    TwoHopRows,
    lambda t: ((t.adj_a, t.adj_b, t.start), (t.include_first_hop, t.n_colored)),
    lambda aux, ch: TwoHopRows(ch[0], ch[1], aux[0], ch[2], aux[1]),
)


# --------------------------------------------------------------------------
# the classic two-hop super-step (kept as the paper-faithful baseline)
# --------------------------------------------------------------------------

@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "use_kernel", "include_first_hop",
                     "coarsen"),
)
def d2_sgr_step(
    adj_a,
    adj_b,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    use_kernel: bool = False,
    include_first_hop: bool = True,
    coarsen: int = 1,
):
    """One classic D2 super-step: FirstFit → ConflictResolve → compaction.

    The two-phase (pre-§12) formulation, retained for A/B comparison and
    for the two-tile ``kernels/d2`` bitset kernel.  The production engine
    routes through ``TwoHopRows`` + the rotated super-step instead.
    """
    n = colors_ext.shape[0] - 1  # colored-side vertex count (sentinel slot)
    cap = wl.shape[0]

    def rows_for(ids):
        rows1 = gather_rows(adj_a, ids, sentinel=adj_b.shape[0])
        rows2 = gather_rows(adj_b, rows1.reshape(-1), sentinel=n)
        rows2 = rows2.reshape(ids.shape[0], -1)
        if include_first_hop:
            return jnp.concatenate([rows1, rows2], axis=1), rows1, rows2
        return rows2, rows1, rows2

    # the gathered rows are color-independent, so with an unchunked worklist
    # the (dominant) two-hop gather is shared by both phases; chunked runs
    # recompute per chunk to keep the transient bounded — that is the point
    # of coarsening
    shared = rows_for(wl) if coarsen == 1 else None

    # ---- FirstFit phase (coarsened: later chunks see earlier chunk colors) --
    for lo, hi in _chunk_bounds(cap, coarsen):
        ids = wl[lo:hi]
        rows, rows1, rows2 = shared if shared is not None else rows_for(ids)
        if use_kernel and include_first_hop:
            from repro.kernels.d2.ops import d2_firstfit_bitset_tpu

            c = d2_firstfit_bitset_tpu(colors_ext[rows1], colors_ext[rows2])
            c = jnp.where(ids < n, c, 0).astype(colors_ext.dtype)
            colors_ext = colors_ext.at[ids].set(c)
        else:
            colors_ext = ff_apply(adj_a, colors_ext, ids, kind, use_kernel,
                                  rows=rows)

    # ---- ConflictResolve + color clearing --------------------------------
    lose_parts = []
    for lo, hi in _chunk_bounds(cap, coarsen):
        ids = wl[lo:hi]
        rows, _, _ = shared if shared is not None else rows_for(ids)
        lose = cr_flags(adj_a, deg_ext, colors_ext, ids, heuristic, use_kernel,
                        rows=rows)
        colors_ext = colors_ext.at[ids].set(
            jnp.where(lose, 0, colors_ext[ids])
        )
        lose_parts.append(lose)
    lose = jnp.concatenate(lose_parts) if len(lose_parts) > 1 else lose_parts[0]

    # ---- worklist compaction ---------------------------------------------
    new_wl, new_count = compact(wl, lose, sentinel=n)
    return colors_ext, new_wl, new_count


# --------------------------------------------------------------------------
# engine plumbing (shared with bipartite.py)
# --------------------------------------------------------------------------

def run_d2_engine(
    *, n, provider, deg_ext, tiling, degrees_for_tiling, mode, heuristic,
    kind, use_kernel, coarsen, tail_serial, max_iters, algorithm,
    deg_bound: int = 2**15, trace=False,
) -> ColoringResult:
    """Drive the rotated engine over a D2 row provider (shared w/ bipartite).

    ``degrees_for_tiling`` (the gathered-side degree histogram, e.g. G²'s)
    sizes the degree-tiled dispatch when the provider honors widths
    (``DeviceCSR``); composed providers gather their full two-hop width and
    pass ``None``.
    """
    if degrees_for_tiling is not None:
        classes, tile_widths = _resolve_classes(degrees_for_tiling, (), tiling)
        acc_widths = tile_widths
        tail_width = max(int(np.asarray(degrees_for_tiling).max(initial=0)), 1)
        if len(classes) == 1:
            tile_widths = [None]  # provider serves its natural full width
    else:
        classes = [np.arange(n, dtype=np.int32)]
        tile_widths = [None]
        width = provider.width if hasattr(provider, "width") else (
            provider.max_width if hasattr(provider, "max_width")
            else int(provider.adj.shape[1]))
        acc_widths = [int(width)]
        tail_width = int(width)
    tail_enabled, thr = resolve_tail_threshold(tail_serial, n)
    return run_ragged_engine(
        n=n, provider=provider, deg_ext=deg_ext, classes=classes,
        tile_widths=tile_widths, acc_widths=acc_widths, tail_width=tail_width,
        mode=mode, heuristic=heuristic, kind=kind, use_kernel=use_kernel,
        coarsen=coarsen, tail_enabled=tail_enabled, tail_threshold=thr,
        max_iters=max_iters, algorithm=algorithm,
        # colors <= tail_width + 1; the loser rule's degrees are bounded by
        # deg_bound (the caller's original/column degrees)
        pack_degrees=_packed_gather_ok(max(tail_width, deg_bound)),
        trace=trace,
    )


def resolve_strategy(strategy: str, est_bytes: int, budget: int) -> str:
    if strategy == "auto":
        return "precomputed" if est_bytes <= budget else "onthefly"
    if strategy not in ("precomputed", "onthefly"):
        raise ValueError(
            f"unknown strategy {strategy!r}; options: auto, precomputed, onthefly"
        )
    return strategy


def resolve_d2_strategy(g: CSRGraph, strategy: str, budget: int) -> str:
    """Footprint-gated strategy pick, shared by the ragged and sharded
    paths so ``auto`` resolves identically on either engine: the estimate
    is the (n, W2) square view plus the transient two-hop pair expansion.
    """
    w2_bound = max(g.two_hop_degree_bound(), 1)
    pair_bound = g.m + int((g.degrees.astype(np.int64) ** 2).sum())
    return resolve_strategy(strategy, 4 * g.n * w2_bound + 16 * pair_bound,
                            budget)


def run_sharded_d2_engine(
    *, n, devices, plan, provider_kind, prov_np, deg_ext_np,
    degrees_for_tiling, tiling, heuristic, kind, tail_serial, max_iters,
    algorithm, tail_provider, include_first_hop=True, deg_bound: int = 2**15,
    full_width: int | None = None, trace=False,
) -> ColoringResult:
    """Drive the §13 sharded engine over a D2 partition plan.

    The sharded sibling of ``run_d2_engine`` (same class/width resolution,
    same pack gate), shared by distance-2 and bipartite: ``provider_kind``
    is ``"csr"`` for precomputed strategies (the G²/conflict-graph shards)
    and ``"twohop"`` for on-the-fly composition (``TwoHopRows`` over the
    plan's first-hop slices).
    """
    from repro.core.coloring import resolve_tail_threshold
    from repro.core.distributed import run_sharded_engine

    if degrees_for_tiling is not None:
        classes, tile_widths = _resolve_classes(degrees_for_tiling, (), tiling)
        acc_widths = tile_widths
        tail_width = max(int(np.asarray(degrees_for_tiling).max(initial=0)), 1)
        if len(classes) == 1:
            tile_widths = [None]  # provider serves its natural full width
    else:
        classes = [np.arange(n, dtype=np.int32)]
        tile_widths = [None]
        acc_widths = [int(full_width)]
        tail_width = int(full_width)
    tail_enabled, thr = resolve_tail_threshold(tail_serial, n)
    return run_sharded_engine(
        plan=plan, devices=devices, provider_kind=provider_kind,
        prov_np=prov_np, deg_ext_np=deg_ext_np, classes=classes,
        tile_widths=tile_widths, acc_widths=acc_widths,
        tail_width=tail_width, tail_provider=tail_provider,
        heuristic=heuristic, kind=kind, tail_enabled=tail_enabled,
        tail_threshold=thr, max_iters=max_iters, algorithm=algorithm,
        pack_degrees=_packed_gather_ok(max(tail_width, deg_bound)),
        include_first_hop=include_first_hop, trace=trace,
    )


@register("distance2")
def color_distance2(
    g: CSRGraph,
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    mode: str = "workefficient",
    strategy: str = "auto",
    memory_budget: int = DEFAULT_D2_BUDGET,
    coarsen: int = 1,
    max_iters: int | None = None,
    tiling="auto",
    tail_serial="auto",
    engine: str = "ragged",
    devices=None,
    backend: str | None = None,
    trace=False,
) -> ColoringResult:
    """Distance-2 coloring of ``g`` with the rotated SGR super-step (§12).

    ``backend`` (§15) picks the super-step implementation exactly as in
    ``color_data_driven``: ``"pallas"`` routes the rotated two-hop tiles
    through the fused superstep kernel (bit-identical — the kernel's loser
    rule and winner-clearing FirstFit are insensitive to the duplicate/self
    lanes composed tiles carry, see the module docstring), ``"jax"`` forces
    pure-JAX, ``None`` defers to ``use_kernel``.  The multi-device sharded
    engine always runs pure-JAX (automatic fallback).

    ``strategy="auto"`` precomputes the G² CSR when its estimated footprint
    (view + two-hop pair expansion) fits ``memory_budget``, else composes
    the two hops on the fly per super-step.  Either way the engine applies
    unchanged: one gather pair per super-step, degree-tiled dispatch over
    G²'s histogram (precomputed only), and adaptive tail-serialization.
    ``coarsen`` chunks the worklist to bound the composed-gather transient
    (on-the-fly) or the tile transient (precomputed).

    ``engine="sharded"`` runs the same schedule over every device in
    ``devices`` (§13): the precomputed strategy shards G²'s CSR along a
    ``PartitionedCSR`` plan (two-hop reach decides the halo sets), the
    on-the-fly strategy runs ``TwoHopRows`` over the plan's first-hop
    slices.  Colors are bit-identical to the single-device run; with one
    device it falls back to ``ragged``.
    """
    from repro.kernels.dispatch import resolve_backend

    n = g.n
    if engine == "sharded":
        # validated before the one-device fallback: option surface must not
        # depend on how many devices are present
        if use_kernel:
            raise ValueError(
                "engine='sharded' does not support use_kernel=True")
        if coarsen != 1:
            raise ValueError(
                "engine='sharded' runs the unchunked (coarsen=1) schedule")
        devs = list(devices) if devices is not None else jax.devices()
        if len(devs) > 1 and n > 0:
            # §15 fallback: the shard_map body stays pure-JAX
            resolve_backend(backend)
            return _color_distance2_sharded(
                g, devs, heuristic=heuristic, firstfit=firstfit,
                strategy=strategy, memory_budget=memory_budget,
                tiling=tiling, tail_serial=tail_serial, max_iters=max_iters,
                trace=trace,
            )
        # one device: fall back to the ragged fused realization — pin mode
        # so colors AND accounting are device-count-independent
        mode = "fused"
    elif engine != "ragged":
        raise ValueError(
            f"unknown engine {engine!r}; options: ragged, sharded")
    from repro.kernels.dispatch import kernel_mode

    use_kernel = kernel_mode(resolve_backend(backend, use_kernel))
    if n == 0:
        result = ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True,
                                algorithm="distance2_sgr")
        if trace:
            result.trace = empty_trace("distance2_sgr")
        return result
    max_iters = max_iters or n + 1

    def run():
        deg_ext = jnp.asarray(np.concatenate(
            [g.degrees, np.zeros(1, np.int32)]).astype(np.int32))
        strat = resolve_d2_strategy(g, strategy, memory_budget)
        if strat == "precomputed":
            with span("csr_build", engine="d2_precomputed"):
                g2 = g.square()
                provider = DeviceCSR.from_csr(g2)
            degrees_for_tiling = g2.degrees
        else:
            with span("csr_build", engine="d2_onthefly"):
                adj = jnp.asarray(g.padded_adjacency())
                provider = TwoHopRows(adj, adj, include_first_hop=True)
            degrees_for_tiling = None
        return run_d2_engine(
            n=n, provider=provider, deg_ext=deg_ext, tiling=tiling,
            degrees_for_tiling=degrees_for_tiling, mode=mode,
            heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
            coarsen=coarsen, tail_serial=tail_serial, max_iters=max_iters,
            algorithm="distance2_sgr", deg_bound=g.max_degree, trace=trace,
        )

    if not trace:
        return run()
    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result


def _color_distance2_sharded(
    g: CSRGraph, devices, *, heuristic, firstfit, strategy, memory_budget,
    tiling, tail_serial, max_iters, trace=False,
) -> ColoringResult:
    """The §13 multi-device realization of ``color_distance2``."""
    n = g.n
    ndev = len(devices)
    max_iters = max_iters or n + 1
    strategy = resolve_d2_strategy(g, strategy, memory_budget)

    def run():
        deg_ext_np = np.concatenate(
            [g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
        if strategy == "precomputed":
            # G² reduces distance-2 to distance-1 (§11), so the plan
            # partitions G² directly: its 1-hop boundary IS the two-hop
            # reader set of g
            with span("csr_build", engine="d2_precomputed"):
                g2 = g.square()
            with span("partition_plan", ndev=ndev):
                plan = PartitionedCSR.from_graph(g2, ndev)
                prov_np = plan.stack_shards(g2)
            return run_sharded_d2_engine(
                n=n, devices=devices, plan=plan, provider_kind="csr",
                prov_np=prov_np, deg_ext_np=deg_ext_np,
                degrees_for_tiling=g2.degrees, tiling=tiling,
                heuristic=heuristic, kind=firstfit, tail_serial=tail_serial,
                max_iters=max_iters,
                algorithm=f"distance2_sgr_sharded_{ndev}dev",
                tail_provider=DeviceCSR.from_csr(g2), deg_bound=g.max_degree,
                trace=trace,
            )
        with span("csr_build", engine="d2_onthefly"):
            adj_np = g.padded_adjacency()
            adj = jnp.asarray(adj_np)
        with span("partition_plan", ndev=ndev):
            plan = PartitionedCSR.from_graph(g, ndev, boundary_mode="two_hop")
            rows_np = plan.stack_rows(adj_np, fill=n)
        full_width = adj_np.shape[1] * adj_np.shape[1] + adj_np.shape[1]
        return run_sharded_d2_engine(
            n=n, devices=devices, plan=plan, provider_kind="twohop",
            prov_np=(rows_np, adj_np),
            deg_ext_np=deg_ext_np, degrees_for_tiling=None, tiling=tiling,
            heuristic=heuristic, kind=firstfit, tail_serial=tail_serial,
            max_iters=max_iters, algorithm=f"distance2_sgr_sharded_{ndev}dev",
            tail_provider=TwoHopRows(adj, adj, include_first_hop=True),
            include_first_hop=True, deg_bound=g.max_degree,
            full_width=full_width, trace=trace,
        )

    if not trace:
        return run()
    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result
