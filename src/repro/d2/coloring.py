"""Distance-2 speculative-greedy coloring on the SGR super-step (DESIGN.md §11).

A distance-2 coloring gives distinct colors to any two vertices within
distance ≤ 2 — equivalently, a distance-1 coloring of the square graph G².
That equivalence is the backbone of this module; two execution strategies
share one quality contract:

* ``precomputed`` — build G² host-side (``CSRGraph.square``) and run the
  UNCHANGED distance-1 super-step (``core.coloring.sgr_step``) over its
  padded adjacency.  One gather per phase, exactly the §2 layout; this is
  also what the batched engine packs (``core/batch.py``), so batched D2 is
  bit-identical to per-graph fused D2 for free.
* ``onthefly`` — when the ``(n, W2)`` square view would blow the memory
  budget, compose TWO sentinel-padded gathers through ``colors_ext`` per
  super-step instead (``d2_sgr_step``): sentinel ids yield all-sentinel
  rows in hop 1, which yield all-sentinel rows again in hop 2, so padding
  stays inert through both hops — the D2 analogue of the §2 trick.  The
  ``coarsen`` knob chunks the worklist to bound the ``(w, W + W²)``
  transient, mirroring D1 thread coarsening.

Both strategies order conflict losers by the ORIGINAL graph's degree (ties
by id) — not G²'s — so with ``coarsen=1`` they produce bit-identical
colorings (tested), and the choice is purely a memory/performance policy.

Self-visits need no masking: a vertex reaches itself through any two-hop
round trip ``v → u → v``, but at FirstFit time a worklist vertex's own
color is always 0 (uncolored/cleared), and both conflict loser rules are
strict total orders, so the self lane is inert in both phases.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import register
from repro.core.coloring import (
    ColoringResult,
    _chunk_bounds,
    compact,
    cr_flags,
    ff_apply,
    fused_result,
    gather_rows,
    run_fused_loop,
    run_workefficient_loop,
    sgr_step,
)
from repro.core.csr import CSRGraph

__all__ = ["color_distance2", "d2_sgr_step", "DEFAULT_D2_BUDGET"]

# bytes the precomputed strategy may spend on the (n, W2) square view plus
# the transient two-hop pair expansion; past this, auto falls back to
# on-the-fly composition (the W2 capping policy of DESIGN.md §11)
DEFAULT_D2_BUDGET = 256 * 2**20


# --------------------------------------------------------------------------
# the two-hop super-step (shared with bipartite.py)
# --------------------------------------------------------------------------

@partial(
    jax.jit,
    static_argnames=("heuristic", "kind", "use_kernel", "include_first_hop",
                     "coarsen"),
)
def d2_sgr_step(
    adj_a,
    adj_b,
    deg_ext,
    colors_ext,
    wl,
    *,
    heuristic: str = "degree",
    kind: str = "bitset",
    use_kernel: bool = False,
    include_first_hop: bool = True,
    coarsen: int = 1,
):
    """One D2 super-step: FirstFit → ConflictResolve(+clear) → compaction.

    The forbidden/conflict neighborhood of worklist vertex ``v`` is composed
    per step from two gathers: ``rows1 = adj_a[v]`` then ``rows2 =
    adj_b[rows1]``.  For distance-2 on one graph, ``adj_a is adj_b`` and
    hop-1 neighbors are part of the neighborhood (``include_first_hop``);
    for bipartite partial coloring, ``adj_a`` is cols→rows, ``adj_b`` is
    rows→cols, and only hop-2 (column-side) ids carry colors.  All phase
    helpers are the distance-1 ones from ``core.coloring`` — only the row
    provider changed.
    """
    n = colors_ext.shape[0] - 1  # colored-side vertex count (sentinel slot)
    cap = wl.shape[0]

    def rows_for(ids):
        rows1 = gather_rows(adj_a, ids, sentinel=adj_b.shape[0])
        rows2 = gather_rows(adj_b, rows1.reshape(-1), sentinel=n)
        rows2 = rows2.reshape(ids.shape[0], -1)
        if include_first_hop:
            return jnp.concatenate([rows1, rows2], axis=1), rows1, rows2
        return rows2, rows1, rows2

    # the gathered rows are color-independent, so with an unchunked worklist
    # the (dominant) two-hop gather is shared by both phases; chunked runs
    # recompute per chunk to keep the transient bounded — that is the point
    # of coarsening
    shared = rows_for(wl) if coarsen == 1 else None

    # ---- FirstFit phase (coarsened: later chunks see earlier chunk colors) --
    for lo, hi in _chunk_bounds(cap, coarsen):
        ids = wl[lo:hi]
        rows, rows1, rows2 = shared if shared is not None else rows_for(ids)
        if use_kernel and include_first_hop:
            from repro.kernels.d2.ops import d2_firstfit_bitset_tpu

            c = d2_firstfit_bitset_tpu(colors_ext[rows1], colors_ext[rows2])
            c = jnp.where(ids < n, c, 0).astype(colors_ext.dtype)
            colors_ext = colors_ext.at[ids].set(c)
        else:
            colors_ext = ff_apply(adj_a, colors_ext, ids, kind, use_kernel,
                                  rows=rows)

    # ---- ConflictResolve + color clearing --------------------------------
    lose_parts = []
    for lo, hi in _chunk_bounds(cap, coarsen):
        ids = wl[lo:hi]
        rows, _, _ = shared if shared is not None else rows_for(ids)
        lose = cr_flags(adj_a, deg_ext, colors_ext, ids, heuristic, use_kernel,
                        rows=rows)
        colors_ext = colors_ext.at[ids].set(
            jnp.where(lose, 0, colors_ext[ids])
        )
        lose_parts.append(lose)
    lose = jnp.concatenate(lose_parts) if len(lose_parts) > 1 else lose_parts[0]

    # ---- worklist compaction ---------------------------------------------
    new_wl, new_count = compact(wl, lose, sentinel=n)
    return colors_ext, new_wl, new_count


# --------------------------------------------------------------------------
# drivers (shared with bipartite.py)
# --------------------------------------------------------------------------

def drive(step, n: int, mode: str, max_iters: int, algorithm: str) -> ColoringResult:
    """Run ``step`` to convergence under the requested execution mode.

    Reuses the generic loops refactored out of ``core.coloring``; the work
    accounting mirrors the distance-1 drivers exactly.
    """
    colors_ext = jnp.zeros((n + 1,), dtype=jnp.int32)
    wl0 = jnp.arange(n, dtype=jnp.int32)
    if mode == "fused":
        colors_ext, _, count, it, work = run_fused_loop(
            step, colors_ext, wl0, n, max_iters
        )
        return fused_result(colors_ext, n, count, it, work, algorithm)
    if mode != "workefficient":
        raise ValueError(f"unknown mode {mode!r}")
    colors_ext, iters, work, padded, converged = run_workefficient_loop(
        step, colors_ext, wl0, n, max_iters
    )
    return ColoringResult(
        np.asarray(colors_ext[:n]), iters, work, padded, converged,
        algorithm=algorithm,
    )


def resolve_strategy(strategy: str, est_bytes: int, budget: int) -> str:
    if strategy == "auto":
        return "precomputed" if est_bytes <= budget else "onthefly"
    if strategy not in ("precomputed", "onthefly"):
        raise ValueError(
            f"unknown strategy {strategy!r}; options: auto, precomputed, onthefly"
        )
    return strategy


@register("distance2")
def color_distance2(
    g: CSRGraph,
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    mode: str = "workefficient",
    strategy: str = "auto",
    memory_budget: int = DEFAULT_D2_BUDGET,
    coarsen: int = 1,
    max_iters: int | None = None,
) -> ColoringResult:
    """Distance-2 coloring of ``g`` with the SGR super-step.

    ``strategy="auto"`` precomputes the G² padded adjacency when its
    estimated footprint (view + two-hop pair expansion) fits
    ``memory_budget``, else composes the two hops on the fly per super-step.
    ``coarsen`` only affects the on-the-fly strategy (chunks the worklist to
    bound the composed-gather transient).
    """
    n = g.n
    if n == 0:
        return ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True,
                              algorithm="distance2_sgr")
    max_iters = max_iters or n + 1
    deg_ext = jnp.asarray(
        np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    )
    w2_bound = max(g.two_hop_degree_bound(), 1)
    pair_bound = g.m + int((g.degrees.astype(np.int64) ** 2).sum())
    est_bytes = 4 * n * w2_bound + 16 * pair_bound
    strategy = resolve_strategy(strategy, est_bytes, memory_budget)

    if strategy == "precomputed":
        adj2 = jnp.asarray(g.square().padded_adjacency())
        step = partial(
            sgr_step, adj2, deg_ext,
            heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
        )
    else:
        adj = jnp.asarray(g.padded_adjacency())
        step = partial(
            d2_sgr_step, adj, adj, deg_ext,
            heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
            include_first_hop=True, coarsen=coarsen,
        )
    return drive(step, n, mode, max_iters, algorithm="distance2_sgr")
