"""Bipartite partial coloring — the Jacobian-compression workload (§11).

A sparse Jacobian pattern ``J`` (n_rows × n_cols) is a bipartite graph;
columns ``u, v`` conflict iff some row holds nonzeros in both (a length-2
path ``u → row → v``).  A partial coloring of the COLUMN side with that
conflict rule partitions columns into structurally-orthogonal groups, so
``J`` is recovered from ``num_groups`` directional products ``J @ seed``
instead of ``n_cols`` — the classic CPR/Curtis-Powell-Reid compression that
dominates real demand for coloring (Taş & Kaya, arXiv:1701.02628).

Same two strategies as ``d2/coloring.py``:

* ``precomputed`` — materialize the column-conflict graph (a ``CSRGraph``
  via ``compose_pairs`` cols→rows→cols) and run the unchanged distance-1
  super-step on it;
* ``onthefly`` — compose the cols→rows and rows→cols padded gathers per
  super-step (``d2_sgr_step`` with ``include_first_hop=False``: row-side
  ids carry no colors).  Handles patterns whose conflict graph is dense
  (e.g. one nearly-full row) without materializing it.

Both order losers by bipartite column degree (nnz per column, ties by id),
so they are bit-identical; ``compress_jacobian_pattern`` is the packaged
entry point.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.api import register
from repro.core.coloring import ColoringResult
from repro.core.csr import (CSRGraph, DeviceCSR, PartitionedCSR,
                            compose_pairs, csr_from_edges, padded_ragged)
from repro.d2.coloring import (
    DEFAULT_D2_BUDGET,
    TwoHopRows,
    resolve_strategy,
    run_d2_engine,
    run_sharded_d2_engine,
)

__all__ = [
    "BipartiteGraph",
    "CompressionResult",
    "color_bipartite",
    "compress_jacobian_pattern",
]


def _resolve_bipartite_strategy(bg: "BipartiteGraph", strategy: str,
                                budget: int) -> str:
    """Footprint-gated strategy pick, shared by ragged and sharded paths
    so ``auto`` resolves identically on either engine."""
    w2_bound = max(bg.conflict_degree_bound(), 1)
    pair_bound = int((bg.row_degrees.astype(np.int64) ** 2).sum())
    return resolve_strategy(
        strategy, 4 * bg.n_cols * w2_bound + 16 * pair_bound, budget)


@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """A sparse bipartite pattern stored as BOTH ragged halves.

    ``row_offsets``/``row_to_col`` — rows→cols CSR (the pattern's rows);
    ``col_offsets``/``col_to_row`` — cols→rows CSR (its transpose).  Only
    the column side is colored; rows are the conflict carriers.
    """

    row_offsets: np.ndarray  # (n_rows+1,)
    row_to_col: np.ndarray   # (nnz,) int32
    col_offsets: np.ndarray  # (n_cols+1,)
    col_to_row: np.ndarray   # (nnz,) int32

    @property
    def n_rows(self) -> int:
        return int(self.row_offsets.shape[0] - 1)

    @property
    def n_cols(self) -> int:
        return int(self.col_offsets.shape[0] - 1)

    @property
    def nnz(self) -> int:
        return int(self.row_to_col.shape[0])

    @property
    def row_degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int32)

    @property
    def col_degrees(self) -> np.ndarray:
        return np.diff(self.col_offsets).astype(np.int32)

    @classmethod
    def from_coo(
        cls, n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray
    ) -> "BipartiteGraph":
        """Build (deduplicated, sorted) from nonzero coordinates."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if rows.size:
            key = np.unique(rows * n_cols + cols)
            rows, cols = key // n_cols, key % n_cols
        r_off = np.zeros(n_rows + 1, dtype=np.int64)
        np.add.at(r_off, rows + 1, 1)
        c_off = np.zeros(n_cols + 1, dtype=np.int64)
        np.add.at(c_off, cols + 1, 1)
        order_c = np.lexsort((rows, cols))  # transpose ordering
        return cls(
            np.cumsum(r_off),
            cols.astype(np.int32),
            np.cumsum(c_off),
            rows[order_c].astype(np.int32),
        )

    @classmethod
    def from_dense(cls, pattern: np.ndarray) -> "BipartiteGraph":
        """Build from a dense (n_rows, n_cols) boolean/nonzero mask."""
        pattern = np.asarray(pattern)
        rows, cols = np.nonzero(pattern)
        return cls.from_coo(pattern.shape[0], pattern.shape[1], rows, cols)

    # -- derived views -------------------------------------------------------
    def column_conflict_graph(self) -> CSRGraph:
        """The column-side conflict relation as a plain ``CSRGraph``.

        ``u ~ v`` iff a length-2 path ``u → row → v`` exists; distance-1
        coloring of this graph IS the bipartite partial coloring, so any
        registered algorithm applies to it.
        """
        src, dst = compose_pairs(
            self.col_offsets, self.col_to_row, self.row_offsets, self.row_to_col
        )
        return csr_from_edges(self.n_cols, src, dst, symmetrize=False, dedup=True)

    def conflict_degree_bound(self) -> int:
        """Upper bound on the conflict graph's max degree (no dedup)."""
        if self.nnz == 0:
            return 0
        rdeg = self.row_degrees.astype(np.int64)
        per_col = np.bincount(
            np.repeat(np.arange(self.n_cols, dtype=np.int64), self.col_degrees),
            weights=rdeg[self.col_to_row],
            minlength=self.n_cols,
        )
        return int(per_col.max())

    def padded_halves(self) -> tuple[np.ndarray, np.ndarray]:
        """Padded cols→rows and rows→cols views with cross-side sentinels."""
        wc = max(int(self.col_degrees.max(initial=0)), 1)
        wr = max(int(self.row_degrees.max(initial=0)), 1)
        cols2rows = padded_ragged(self.col_offsets, self.col_to_row, wc, self.n_rows)
        rows2cols = padded_ragged(self.row_offsets, self.row_to_col, wr, self.n_cols)
        return cols2rows, rows2cols


@register("bipartite")
def color_bipartite(
    bg: BipartiteGraph,
    *,
    heuristic: str = "degree",
    firstfit: str = "bitset",
    use_kernel: bool = False,
    mode: str = "workefficient",
    strategy: str = "auto",
    memory_budget: int = DEFAULT_D2_BUDGET,
    coarsen: int = 1,
    max_iters: int | None = None,
    tiling="auto",
    tail_serial="auto",
    engine: str = "ragged",
    devices=None,
    trace=False,
) -> ColoringResult:
    """Partial coloring of ``bg``'s column side with the SGR super-step.

    ``result.colors[c]`` is the group of column ``c``; validity means no two
    columns sharing a row share a color (``d2.validate_bipartite``).  Runs
    on the rotated ragged engine (§12): the precomputed strategy colors the
    column-conflict graph's CSR, the on-the-fly strategy composes the
    cols→rows→cols gathers per super-step; both inherit degree-tiled
    dispatch (precomputed) and adaptive tail-serialization.
    ``engine="sharded"`` distributes the column side over ``devices`` along
    a ``PartitionedCSR.from_bipartite`` plan (§13), bit-identical to the
    single-device run; one device falls back to ``ragged``.
    """
    nc = bg.n_cols
    if engine == "sharded":
        import jax

        # validated before the one-device fallback: option surface must not
        # depend on how many devices are present
        if use_kernel:
            raise ValueError(
                "engine='sharded' does not support use_kernel=True")
        if coarsen != 1:
            raise ValueError(
                "engine='sharded' runs the unchunked (coarsen=1) schedule")
        devs = list(devices) if devices is not None else jax.devices()
        if len(devs) > 1 and nc > 0:
            return _color_bipartite_sharded(
                bg, devs, heuristic=heuristic, firstfit=firstfit,
                strategy=strategy, memory_budget=memory_budget,
                tiling=tiling, tail_serial=tail_serial, max_iters=max_iters,
                trace=trace,
            )
        # one device: fall back to the ragged fused realization — pin mode
        # so colors AND accounting are device-count-independent
        mode = "fused"
    elif engine != "ragged":
        raise ValueError(
            f"unknown engine {engine!r}; options: ragged, sharded")
    if nc == 0:
        result = ColoringResult(np.zeros(0, np.int32), 0, 0, 0, True,
                                algorithm="bipartite_partial_sgr")
        if trace:
            from repro.obs.trace import empty_trace

            result.trace = empty_trace("bipartite_partial_sgr")
        return result
    max_iters = max_iters or nc + 1
    strategy = _resolve_bipartite_strategy(bg, strategy, memory_budget)

    def run():
        from repro.obs.spans import span

        deg_ext = jnp.asarray(np.concatenate(
            [bg.col_degrees, np.zeros(1, np.int32)]).astype(np.int32))
        if strategy == "precomputed":
            with span("csr_build", engine="bipartite_precomputed"):
                cg = bg.column_conflict_graph()
                provider = DeviceCSR.from_csr(cg)
            degrees_for_tiling = cg.degrees
        else:
            with span("csr_build", engine="bipartite_onthefly"):
                cols2rows, rows2cols = bg.padded_halves()
                provider = TwoHopRows(jnp.asarray(cols2rows),
                                      jnp.asarray(rows2cols),
                                      include_first_hop=False)
            degrees_for_tiling = None
        return run_d2_engine(
            n=nc, provider=provider, deg_ext=deg_ext, tiling=tiling,
            degrees_for_tiling=degrees_for_tiling, mode=mode,
            heuristic=heuristic, kind=firstfit, use_kernel=use_kernel,
            coarsen=coarsen, tail_serial=tail_serial, max_iters=max_iters,
            algorithm="bipartite_partial_sgr",
            deg_bound=int(bg.col_degrees.max(initial=0)), trace=trace,
        )

    if not trace:
        return run()
    from repro.obs.spans import SpanRecorder

    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result


def _color_bipartite_sharded(
    bg: BipartiteGraph, devices, *, heuristic, firstfit, strategy,
    memory_budget, tiling, tail_serial, max_iters, trace=False,
) -> ColoringResult:
    """The §13 multi-device realization of ``color_bipartite``."""
    from repro.obs.spans import SpanRecorder, span

    nc = bg.n_cols
    ndev = len(devices)
    max_iters = max_iters or nc + 1
    strategy = _resolve_bipartite_strategy(bg, strategy, memory_budget)

    def run():
        deg_ext_np = np.concatenate(
            [bg.col_degrees, np.zeros(1, np.int32)]).astype(np.int32)
        if strategy == "precomputed":
            with span("csr_build", engine="bipartite_precomputed"):
                cg = bg.column_conflict_graph()
            with span("partition_plan", ndev=ndev):
                plan = PartitionedCSR.from_graph(cg, ndev)
                prov_np = plan.stack_shards(cg)
            return run_sharded_d2_engine(
                n=nc, devices=devices, plan=plan, provider_kind="csr",
                prov_np=prov_np, deg_ext_np=deg_ext_np,
                degrees_for_tiling=cg.degrees, tiling=tiling,
                heuristic=heuristic, kind=firstfit, tail_serial=tail_serial,
                max_iters=max_iters,
                algorithm=f"bipartite_partial_sgr_sharded_{ndev}dev",
                tail_provider=DeviceCSR.from_csr(cg),
                deg_bound=int(bg.col_degrees.max(initial=0)), trace=trace,
            )
        with span("csr_build", engine="bipartite_onthefly"):
            cols2rows, rows2cols = bg.padded_halves()
        with span("partition_plan", ndev=ndev):
            plan = PartitionedCSR.from_bipartite(bg, ndev)
            rows_np = plan.stack_rows(cols2rows, fill=bg.n_rows)
        full_width = cols2rows.shape[1] * rows2cols.shape[1]
        return run_sharded_d2_engine(
            n=nc, devices=devices, plan=plan, provider_kind="twohop",
            prov_np=(rows_np, rows2cols),
            deg_ext_np=deg_ext_np, degrees_for_tiling=None, tiling=tiling,
            heuristic=heuristic, kind=firstfit, tail_serial=tail_serial,
            max_iters=max_iters,
            algorithm=f"bipartite_partial_sgr_sharded_{ndev}dev",
            tail_provider=TwoHopRows(jnp.asarray(cols2rows),
                                     jnp.asarray(rows2cols),
                                     include_first_hop=False),
            include_first_hop=False,
            deg_bound=int(bg.col_degrees.max(initial=0)),
            full_width=full_width, trace=trace,
        )

    if not trace:
        return run()
    with SpanRecorder() as rec:
        result = run()
    if result.trace is not None:
        result.trace.spans = rec.events
    return result


# --------------------------------------------------------------------------
# Jacobian compression
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CompressionResult:
    """Column groups for compressed Jacobian recovery."""

    coloring: ColoringResult
    groups: list[np.ndarray]  # column ids per group, 0-indexed groups

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def seed_matrix(self, dtype=np.float32) -> np.ndarray:
        """(n_cols, num_groups) 0/1 seed: column c contributes to its group.

        ``J @ seed`` evaluates the whole Jacobian in ``num_groups``
        directional derivatives; structural orthogonality within each group
        makes the entries recoverable without cancellation.
        """
        n_cols = self.coloring.colors.shape[0]
        seed = np.zeros((n_cols, self.num_groups), dtype=dtype)
        for k, cols in enumerate(self.groups):
            seed[cols, k] = 1
        return seed


def compress_jacobian_pattern(pattern, *, on_fail: str = "ladder",
                              **opts) -> CompressionResult:
    """Color a Jacobian sparsity pattern into structurally-orthogonal groups.

    ``pattern`` may be a ``BipartiteGraph``, a dense (n_rows, n_cols)
    boolean/nonzero mask, or a ``(n_rows, n_cols, rows, cols)`` COO tuple.
    Extra ``opts`` pass through to ``color_bipartite``.

    A run that exhausts ``max_iters`` before converging is escalated
    through the §17 guarantee ladder on the column-conflict graph (every
    rung recorded in ``result.coloring.degradations``), so the returned
    partition is always total — uncolored (color-0) columns would silently
    vanish from the groups, breaking the invariant the seed matrix relies
    on.  ``on_fail="raise"`` restores the old refuse-with-ValueError
    behavior instead.
    """
    if on_fail not in ("ladder", "raise"):
        raise ValueError(
            f"unknown on_fail {on_fail!r}; options: ladder, raise")
    if isinstance(pattern, BipartiteGraph):
        bg = pattern
    elif isinstance(pattern, tuple) and len(pattern) == 4:
        bg = BipartiteGraph.from_coo(*pattern)
    else:
        bg = BipartiteGraph.from_dense(pattern)
    result = color_bipartite(bg, **opts)
    if not result.converged and on_fail == "raise":
        from repro.errors import NonConvergenceError

        raise NonConvergenceError(
            f"bipartite coloring did not converge after {result.iterations} "
            f"super-steps (raise max_iters); refusing to build a partial "
            f"column partition"
        )
    if not result.converged:
        from repro.core.guarantee import ensure_valid_result

        def rerun(rung):
            o = dict(opts)
            if rung == "reseed":
                cur = o.get("heuristic", "degree")
                o["heuristic"] = "id" if cur == "degree" else "degree"
            elif rung == "budget_extension":
                o["max_iters"] = None
                if o.get("tail_serial", "auto") is None:
                    o["tail_serial"] = "auto"
            return color_bipartite(bg, **o)

        result = ensure_valid_result(bg.column_conflict_graph(), result,
                                     rerun)
    groups = [
        np.where(result.colors == c)[0].astype(np.int32)
        for c in range(1, result.num_colors + 1)
    ]
    return CompressionResult(result, groups)
