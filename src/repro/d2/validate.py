"""Exact host-side validity checks for distance-2 / bipartite colorings.

Independent of both the engine and the oracles: the distance-2 condition is
checked through its characterization "every vertex's neighbor list is
rainbow" — any two vertices at distance exactly 2 share a middle vertex, so
(with the distance-1 edge check) pairwise-distinct colors inside every
adjacency segment is equivalent to no two vertices within distance ≤ 2
sharing a color.  Fully vectorized via a segment sort.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph

__all__ = ["validate_d2", "validate_bipartite"]


def _segments_rainbow(
    row_offsets: np.ndarray, col_indices: np.ndarray, colors: np.ndarray
) -> bool:
    """True iff within every CSR row, distinct vertices have distinct colors."""
    m = col_indices.shape[0]
    if m == 0:
        return True
    seg = np.repeat(
        np.arange(row_offsets.shape[0] - 1, dtype=np.int64),
        np.diff(row_offsets),
    )
    nc = colors[col_indices]
    order = np.lexsort((nc, seg))
    seg_s, nc_s, vid_s = seg[order], nc[order], col_indices[order]
    dup = (
        (seg_s[1:] == seg_s[:-1])
        & (nc_s[1:] == nc_s[:-1])
        & (vid_s[1:] != vid_s[:-1])  # repeated entries of one vertex are fine
    )
    return not bool(dup.any())


def validate_d2(g: CSRGraph, colors: np.ndarray) -> bool:
    """True iff all colored (>0) and no two vertices within distance ≤ 2 share."""
    colors = np.asarray(colors)
    if colors.shape[0] < g.n or (colors[: g.n] <= 0).any():
        return False
    src, dst = g.edges()
    if bool((colors[src] == colors[dst]).any()):
        return False
    return _segments_rainbow(g.row_offsets, g.col_indices, colors)


def validate_bipartite(bg, colors: np.ndarray) -> bool:
    """True iff every column is colored and every row's columns are rainbow.

    That is the bipartite partial-coloring condition: two columns connected
    by a length-2 path through a row never share a color (the seed-matrix
    correctness condition for Jacobian compression).
    """
    colors = np.asarray(colors)
    if colors.shape[0] < bg.n_cols or (colors[: bg.n_cols] <= 0).any():
        return False
    return _segments_rainbow(bg.row_offsets, bg.row_to_col, colors)
