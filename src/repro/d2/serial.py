"""Sequential greedy distance-2 / bipartite oracles (quality baselines).

Deliberately independent of ``CSRGraph.square`` and the device engine: the
two-hop neighborhood is enumerated directly from the CSR arrays per vertex,
the most obviously-correct formulation, so oracle and engine share no
two-hop code path (``validate_d2`` is independent of both).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph

__all__ = ["greedy_serial_d2", "greedy_serial_bipartite"]


def _order(n: int, degrees: np.ndarray, order) -> "np.ndarray | range":
    if isinstance(order, str):
        if order == "natural":
            return range(n)
        if order == "largest_degree_first":
            return np.argsort(-degrees, kind="stable")
        raise ValueError(f"unknown order {order!r}")
    return order


def _first_free(forbidden: np.ndarray, limit: int) -> int:
    """Smallest color in [1, limit] not present in ``forbidden``."""
    mask = np.zeros(limit + 2, dtype=bool)
    mask[forbidden[(forbidden >= 1) & (forbidden <= limit)]] = True
    return int(np.nonzero(~mask[1:])[0][0]) + 1


def greedy_serial_d2(
    g: CSRGraph, order: str | np.ndarray = "natural"
) -> np.ndarray:
    """Greedy distance-2 coloring; colors in [1, Δ₂+1], Δ₂ ≤ Δ(Δ-1)+Δ."""
    n = g.n
    R, C = g.row_offsets, g.col_indices
    colors = np.zeros(n, dtype=np.int32)
    for v in _order(n, g.degrees, order):
        n1 = C[R[v] : R[v + 1]]
        if n1.size:
            n2 = np.concatenate([C[R[u] : R[u + 1]] for u in n1])
            nbrs = np.concatenate([n1, n2[n2 != v]])
        else:
            nbrs = n1
        colors[v] = _first_free(colors[nbrs], nbrs.shape[0] + 1)
    return colors


def greedy_serial_bipartite(bg, order: str | np.ndarray = "natural") -> np.ndarray:
    """Greedy partial coloring of the column side of a ``BipartiteGraph``.

    Two columns conflict iff a length-2 path through a row connects them —
    the Jacobian-compression rule (structurally-orthogonal columns share a
    color).  Natural order on a banded pattern recovers the optimal count.
    """
    nc = bg.n_cols
    Rc, Cc = bg.col_offsets, bg.col_to_row
    Rr, Cr = bg.row_offsets, bg.row_to_col
    colors = np.zeros(nc, dtype=np.int32)
    for v in _order(nc, bg.col_degrees, order):
        rows = Cc[Rc[v] : Rc[v + 1]]
        if rows.size:
            cols2 = np.concatenate([Cr[Rr[r] : Rr[r + 1]] for r in rows])
            nbrs = cols2[cols2 != v]
        else:
            nbrs = rows  # empty
        colors[v] = _first_free(colors[nbrs], nbrs.shape[0] + 1)
    return colors
