"""Distance-2 & bipartite partial coloring engine (DESIGN.md §11).

The paper's speculate → detect-conflicts → recolor super-step is not
specific to distance-1 coloring: this subpackage runs the same SGR machinery
on two-hop neighborhoods, covering the variants that dominate real demand
for coloring — sparse Jacobian/Hessian compression in AD and optimization
(Taş & Kaya, arXiv:1701.02628; Besta et al., arXiv:2008.11321).

* ``color_distance2``    — distance-2 coloring of a ``CSRGraph`` (registered
                           as ``"distance2"`` in ``repro.api``)
* ``color_bipartite``    — partial coloring of one side of a
                           ``BipartiteGraph`` (registered as ``"bipartite"``)
* ``compress_jacobian_pattern`` — the Jacobian-compression entry point:
                           structurally-orthogonal column groups + seed matrix
* ``greedy_serial_d2`` / ``greedy_serial_bipartite`` — quality oracles
* ``validate_d2`` / ``validate_bipartite`` — exact host-side validity checks
"""
from repro.d2.bipartite import (
    BipartiteGraph,
    CompressionResult,
    color_bipartite,
    compress_jacobian_pattern,
)
from repro.d2.coloring import color_distance2, d2_sgr_step
from repro.d2.serial import greedy_serial_bipartite, greedy_serial_d2
from repro.d2.validate import validate_bipartite, validate_d2

__all__ = [
    "BipartiteGraph",
    "CompressionResult",
    "color_bipartite",
    "color_distance2",
    "compress_jacobian_pattern",
    "d2_sgr_step",
    "greedy_serial_bipartite",
    "greedy_serial_d2",
    "validate_bipartite",
    "validate_d2",
]
