"""``ColoringSession`` — streaming incremental recoloring (DESIGN.md §14).

The production north-star workload is a *mutating* graph: millions of users
streaming edge updates, where a cold ``color()`` per mutation wastes
everything the previous coloring already knows.  The paper's speculative
scheme is exactly the machinery needed to serve it: the §12 rotated
super-step already tolerates stale colors and repairs conflicts
iteratively, so incremental recoloring is the SAME engine with the live
mask restricted to the **dirty frontier** — the vertices whose
neighborhoods changed since the last recolor — while every other color is
frozen as snapshot context.

Why the frontier suffices (the §14 cascade-confinement argument): a
worklist vertex FirstFits a color distinct from *every* color visible in
its gathered tile, frozen neighbors included, so a frontier vertex can
never create a conflict against a frozen one — fresh conflicts only involve
other frontier vertices speculating in the same step, and the cascade stays
inside the worklist.  Edges between frozen vertices were valid before the
delta (insertions dirty both endpoints; deletions cannot invalidate), so
convergence of the frontier loop certifies validity of the whole coloring.
Work is therefore frontier-proportional, not n-proportional.

    session = open_session(rows, cols)          # cold ragged coloring
    session.apply_delta(add_edges=(src, dst))   # O(Δ) overlay mutation
    result = session.recolor()                  # frontier-sized super-steps

Guarantees (tested in ``tests/test_dynamic.py``):

* every committed ``recolor()`` result passes ``is_valid_coloring``;
* an empty delta is a bit-identical no-op with zero work;
* ``recolor(full=True)`` compacts the overlay and reproduces the cold
  ragged engine bit-for-bit on the compacted graph;
* ``result.work_items`` scales with the frontier (≥5x under 1% churn).
"""
from __future__ import annotations

import numpy as np

from repro.api import register
from repro.core.coloring import (
    ColoringResult,
    _graph_device_cache,
    _packed_gather_ok,
    _resolve_classes,
    color_data_driven,
    resolve_tail_threshold,
    run_ragged_engine,
)
from repro.core.csr import CSRGraph, DeviceCSR, csr_from_edges, next_pow2
from repro.obs.spans import SpanRecorder, span
from repro.obs.trace import empty_trace

__all__ = ["ColoringSession", "color_dynamic", "open_session"]

# Frontiers at or below this size recolor as a single full-width class so
# the engine jit key is a function of pow2(frontier.size) alone; above it
# the per-degree-class tiling pays for itself and keys change slowly.
_SMALL_FRONTIER = 64


def _padded_edge_cap(m: int, wcap: int) -> int:
    """Pow2 device-CSR column capacity with ≥25% edge-growth headroom."""
    return next_pow2(m + wcap + max(m // 4, 64))


def _device_csr_padded(g: CSRGraph, wcap: int,
                       cap: int | None = None) -> DeviceCSR:
    """A ``DeviceCSR`` whose array shapes are power-of-two stable.

    ``DeviceCSR.from_csr`` sizes ``col_padded`` exactly (``m + Δmax``), so
    every churn round would present new shapes to the jitted engine and
    retrace it.  Padding the column array to a power of two (extra slots
    hold the inert sentinel ``n``) with at least 25% growth headroom makes
    consecutive recolors of a slowly-mutating graph hit the jit cache —
    and keeps hitting it until the graph grows past the headroom, so a
    long-lived pooled session recompiles O(log m) times, never per-delta.
    """
    import jax.numpy as jnp

    n, m = g.n, g.m
    if cap is None:
        cap = _padded_edge_cap(m, wcap)
    col = np.full(cap, n, np.int32)
    col[:m] = g.col_indices
    deg = np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32)
    return DeviceCSR(
        jnp.asarray(g.row_offsets.astype(np.int32)), jnp.asarray(col),
        jnp.asarray(deg), n, wcap,
    )


def open_session(rows, cols=None, *, n: int | None = None, options=None,
                 **opts) -> "ColoringSession":
    """Open a streaming session from COO edge arrays (or a ready CSRGraph).

    ``rows``/``cols`` are undirected edge endpoints (symmetrized and
    deduplicated like every loader in the repo); ``n`` widens the vertex
    count beyond ``max(endpoint) + 1`` when isolated vertices exist.

    Options come in either spelling (§19): a frozen ``ColorOptions`` as
    ``options=``, or the loose kwargs (heuristic, firstfit, mode, tiling,
    tail_serial, max_iters, compact_frac, backend, …) exactly as before —
    both normalize through ``ColorOptions.session_kwargs`` first, so the
    resulting sessions are configured identically.
    """
    if options is not None or opts:
        from repro.options import ColorOptions

        opts = ColorOptions.normalize(options, **opts).session_kwargs()
    if cols is None:
        if not isinstance(rows, CSRGraph):
            raise TypeError(
                "open_session takes (rows, cols) edge arrays or a CSRGraph; "
                f"got {type(rows).__name__}")
        g = rows
    else:
        rows = np.asarray(rows, dtype=np.int64).ravel()
        cols = np.asarray(cols, dtype=np.int64).ravel()
        hi = int(max(rows.max(initial=-1), cols.max(initial=-1))) + 1
        n = hi if n is None else int(n)
        if n < hi:
            raise ValueError(f"n={n} < max endpoint + 1 = {hi}")
        g = csr_from_edges(n, rows, cols)
    return ColoringSession(g, **opts)


def _edge_payload(pair):
    """COO edge-batch args as a JSON-safe journal payload (None passes)."""
    if pair is None:
        return None
    src, dst = pair
    return [np.asarray(src).astype(int).tolist(),
            np.asarray(dst).astype(int).tolist()]


def _payload_edges(payload):
    """Inverse of ``_edge_payload`` for journal replay."""
    if payload is None:
        return None
    return (np.asarray(payload[0], np.int64), np.asarray(payload[1], np.int64))


class ColoringSession:
    """Persistent coloring of one mutating graph (DeltaCSR + §12 engine)."""

    def __init__(self, graph, *, heuristic: str = "degree",
                 firstfit: str = "bitset", mode: str = "fused",
                 tiling="auto", tail_serial="auto",
                 max_iters: int | None = None, compact_frac: float = 0.25,
                 backend: str | None = None, trace=False,
                 validate_input: str | None = None, on_fail: str = "raise",
                 durable_dir: str | None = None, snapshot_every: int = 64,
                 defer_maintenance: bool = False):
        from repro.dynamic.delta import DeltaCSR

        if validate_input is not None and isinstance(graph, CSRGraph):
            from repro.ingest import sanitize_csr

            graph, self.ingest_report = sanitize_csr(
                graph, policy=validate_input)
        else:
            self.ingest_report = None
        self.delta = (graph if isinstance(graph, DeltaCSR)
                      else DeltaCSR(graph, compact_frac=compact_frac))
        self._configure(
            heuristic=heuristic, firstfit=firstfit, mode=mode, tiling=tiling,
            tail_serial=tail_serial, max_iters=max_iters,
            compact_frac=compact_frac, backend=backend, trace=trace,
            on_fail=on_fail, snapshot_every=snapshot_every,
            defer_maintenance=defer_maintenance)
        if self._defer_maintenance:
            # the pool owns compaction scheduling: suppress the inline
            # auto-compact and let maintain() run it from an idle slot
            self.delta.compact_frac = float("inf")
        self.result = self._cold(self.delta.graph())
        if not self.result.converged and self._on_fail == "ladder":
            self.result = self._escalate(self.result, True)
        self.colors = self.result.colors
        if durable_dir is not None:
            from repro.dynamic.journal import SessionJournal

            self._journal = SessionJournal(durable_dir, fresh=True)
            self.checkpoint()

    def _configure(self, *, heuristic, firstfit, mode, tiling, tail_serial,
                   max_iters, compact_frac, backend, trace, on_fail,
                   snapshot_every, defer_maintenance=False) -> None:
        from repro.kernels.dispatch import kernel_mode, resolve_backend

        if on_fail not in ("raise", "ladder"):
            raise ValueError(
                f"unknown on_fail {on_fail!r}; options: raise, ladder")
        self._heuristic = heuristic
        self._firstfit = firstfit
        self._mode = mode
        self._tiling = tuple(tiling) if isinstance(tiling, list) else tiling
        self._tail_serial = tail_serial
        self._max_iters = max_iters
        self._compact_frac = compact_frac
        # §15/§18: frontier recolors reuse the fused superstep kernels — the
        # pow2-padded worklists below already keep their jit cache keys
        # stable, and the session's padded DeviceCSR feeds pallas-csr
        self._backend = backend
        self._use_kernel = kernel_mode(resolve_backend(backend))
        # §16: trace knob threads to the cold and every frontier recolor
        self._trace = trace
        # §17: non-convergence policy + durability plumbing.  A pooled
        # session (§19) runs with defer_maintenance=True: snapshots stop
        # firing inline from the journal hot path and wait for the owner to
        # call maintain() in an idle slot instead.
        self._on_fail = on_fail
        self._defer_maintenance = bool(defer_maintenance)
        self._snapshot_every = int(snapshot_every)
        self._journal = None
        self._records_since_snapshot = 0
        self.recovery = None
        self._dirty: list[np.ndarray] = []
        # cumulative session counters behind .metrics(); engine cache
        # hits/misses track the (shape, static-args) keys THIS session has
        # presented to the jitted frontier engine — a repeat key is a jit
        # cache hit by construction (the pow2 padding exists to make churn
        # rounds repeat keys; PR 5's steady-state wall win depends on it)
        self._counters = {
            "deltas": 0, "dirtied_total": 0, "recolors": 0,
            "full_recolors": 0, "noop_recolors": 0, "frontier_total": 0,
            "work_total": 0, "supersteps_total": 0,
            "engine_cache_hits": 0, "engine_cache_misses": 0,
        }
        self._engine_keys: set = set()

    # -- engine plumbing -----------------------------------------------------
    def _cold(self, g: CSRGraph) -> ColoringResult:
        return color_data_driven(
            g, engine="ragged", mode=self._mode, heuristic=self._heuristic,
            firstfit=self._firstfit, tiling=self._tiling,
            tail_serial=self._tail_serial, max_iters=self._max_iters,
            backend=self._backend, trace=self._trace,
        )

    # -- state views ---------------------------------------------------------
    @property
    def graph(self) -> CSRGraph:
        """The current (post-delta) graph snapshot."""
        return self.delta.graph()

    @property
    def n(self) -> int:
        return self.delta.n

    @property
    def num_colors(self) -> int:
        return int(self.colors.max(initial=0))

    def frontier(self) -> np.ndarray:
        """Dirty vertex ids pending the next ``recolor()`` (sorted, unique)."""
        if not self._dirty:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(self._dirty)).astype(np.int64)

    @property
    def pending_dirty(self) -> int:
        """Cheap upper bound on the dirty-frontier size (no dedup pass).

        The pool's idle/dirty signal (§19): 0 means a recolor would no-op,
        a positive value bounds the repair work without paying the
        ``frontier()`` concatenate+unique on every poll.
        """
        return sum(int(a.size) for a in self._dirty)

    def validate(self) -> bool:
        """True iff the committed coloring is proper on the current graph."""
        from repro.core.validate import is_valid_coloring

        return is_valid_coloring(self.delta.graph(), self.colors)

    # -- mutation ------------------------------------------------------------
    def apply_delta(self, *, add_vertices: int = 0, add_edges=None,
                    remove_edges=None, remove_vertices=None) -> np.ndarray:
        """Apply one batched mutation; returns the vertex ids it dirtied.

        Applied in order vertex-adds → edge-adds → edge-removes →
        vertex-removes, so a single delta can create vertices and
        immediately wire them up.  ``add_edges``/``remove_edges`` are
        ``(src, dst)`` array pairs; no-op entries (inserting an existing
        edge, deleting a missing one) dirty nothing.
        """
        if self._journal is not None:
            # write-ahead (§17): the journal records the INTENT before the
            # overlay mutates, so a crash mid-mutation replays the whole
            # batch from the last consistent state instead of losing it
            self._journal_append("delta", {
                "add_vertices": int(add_vertices),
                "add_edges": _edge_payload(add_edges),
                "remove_edges": _edge_payload(remove_edges),
                "remove_vertices": (
                    None if remove_vertices is None
                    else np.asarray(remove_vertices).astype(int).tolist()),
            })
        with span("delta_mutation"):
            touched: list[np.ndarray] = []
            if add_vertices:
                touched.append(self.delta.add_vertices(add_vertices))
            if add_edges is not None:
                touched.append(self.delta.add_edges(*add_edges))
            if remove_edges is not None:
                touched.append(self.delta.remove_edges(*remove_edges))
            if remove_vertices is not None:
                touched.append(self.delta.remove_vertices(remove_vertices))
            self._counters["deltas"] += 1
            if not touched:
                return np.zeros(0, np.int32)
            out = np.unique(np.concatenate(
                [np.asarray(t, dtype=np.int64) for t in touched]))
            if out.size:
                self._dirty.append(out)
            self._counters["dirtied_total"] += int(out.size)
            return out.astype(np.int32)

    # -- recoloring ----------------------------------------------------------
    def recolor(self, *, full: bool = False) -> ColoringResult:
        """Repair the coloring after pending deltas; commits on convergence.

        Default: frontier-restricted §12 super-steps (work ∝ frontier).
        ``full=True`` is the escape hatch — compact the overlay and rerun
        the cold ragged engine on the whole graph, bit-for-bit the same
        result a fresh ``color(g, "fused")`` would produce.
        """
        if full:
            with span("compaction", overlay=self.delta.overlay_size):
                g = self.delta.compact()
            self._counters["full_recolors"] += 1
            result = self._cold(g)
        else:
            frontier = self.frontier()
            if frontier.size == 0:
                self._counters["noop_recolors"] += 1
                result = ColoringResult(
                    self.colors.copy(), 0, 0, 0, True, "dynamic_sgr")
                if self._trace:
                    result.trace = empty_trace("dynamic_sgr")
                return result
            self._counters["frontier_total"] += int(frontier.size)
            if self._trace:
                with SpanRecorder() as rec:
                    result = self._recolor_frontier(frontier)
                if result.trace is not None:
                    result.trace.spans = rec.events
            else:
                result = self._recolor_frontier(frontier)
        if not result.converged:
            if self._on_fail == "ladder":
                result = self._escalate(result, full)
            else:
                from repro.errors import NonConvergenceError

                raise NonConvergenceError(
                    "recolor() hit max_iters before converging; the session "
                    "coloring was NOT updated — retry with a larger "
                    "max_iters, tail_serial enabled, recolor(full=True), or "
                    "open the session with on_fail='ladder' to escalate "
                    "through the §17 guarantee ladder instead")
        self._counters["recolors"] += 1
        self._counters["work_total"] += int(result.work_items)
        self._counters["supersteps_total"] += int(result.iterations)
        self.colors = result.colors
        self.result = result
        self._dirty.clear()
        if self._journal is not None:
            # post-commit record: a crash before this line replays as "the
            # recolor never happened", which is exactly true of the state
            self._journal_append("recolor", {"full": bool(full)})
        return result

    def _escalate(self, result, full: bool):
        """§17 guarantee ladder for a frontier recolor that hit max_iters."""
        from repro.core.guarantee import ensure_valid_result

        g = self.delta.graph()

        def rerun(rung):
            if rung != "budget_extension":
                # reseed would flip the session's pinned heuristic and
                # desynchronize later frontier recolors — not applicable
                return None
            saved = self._max_iters
            self._max_iters = None
            try:
                if full:
                    return self._cold(g)
                return self._recolor_frontier(self.frontier())
            finally:
                self._max_iters = saved

        return ensure_valid_result(g, result, rerun)

    def _recolor_frontier(self, frontier: np.ndarray) -> ColoringResult:
        import jax.numpy as jnp

        g = self.delta.graph()
        n = g.n
        prev = self.colors
        colors0 = np.zeros(n + 1, np.int32)
        colors0[: prev.shape[0]] = prev  # n only grows; new slots stay 0
        colors0[frontier] = 0            # the frontier recolors from scratch
        deg = g.degrees
        dmax = max(g.max_degree, 1)
        # High-water capacities: balanced churn (add + remove deltas) makes
        # max-degree and m FLAP around pow2 boundaries — if the caps tracked
        # them both directions, the session would alternate between two jit
        # keys per boundary.  Never shrinking a capacity keeps the key set
        # monotone: after the first crossing only the larger key re-presents.
        self._wcap_hw = wcap = max(next_pow2(dmax),
                                   getattr(self, "_wcap_hw", 0))
        self._ecap_hw = ecap = max(_padded_edge_cap(g.m, wcap),
                                   getattr(self, "_ecap_hw", 0))
        small = frontier.size <= _SMALL_FRONTIER
        if small:
            # small-frontier fast path: ONE class at the full tile width,
            # padded to the fixed ``_SMALL_FRONTIER`` floor — the jit key is
            # then a single constant per capacity state, independent of the
            # frontier's size or how the dirtied vertices scatter across
            # degree classes (§19 serving stability: steady churn re-presents
            # one warm key).  The padded work delta is negligible here.
            classes_idx, widths = [np.arange(frontier.size)], [wcap]
        else:
            classes_idx, widths = _resolve_classes(
                deg[frontier], (), self._tiling)
            # pow2-round tile widths so consecutive recolors present
            # REPEATING static args to the jitted engine
            widths = [min(next_pow2(w), wcap) for w in widths]
        # pow2-pad worklists (inert sentinel n) for the same reason — without
        # shape-stable padding every churn round retraces the while_loop and
        # wall time is dominated by compilation, not work
        classes, counts = [], []
        for ci in classes_idx:
            ids = frontier[ci].astype(np.int32)
            pad_to = _SMALL_FRONTIER if small else next_pow2(ids.size)
            classes.append(np.concatenate(
                [ids, np.full(pad_to - ids.size, n, np.int32)]))
            counts.append(int(ids.size))
        deg_ext = _graph_device_cache(g, "deg_ext", lambda: jnp.asarray(
            np.concatenate([deg, np.zeros(1, np.int32)]).astype(np.int32)))
        provider = _graph_device_cache(
            g, f"dcsr_dyn:{wcap}:{ecap}",
            lambda: _device_csr_padded(g, wcap, cap=ecap))
        tail_enabled, thr = resolve_tail_threshold(
            self._tail_serial, int(frontier.size))
        # pack_degrees needs colors < 2^15 — frozen colors included (they can
        # exceed the CURRENT dmax + 1 bound after deletions shrink the graph).
        # Checked against wcap, matching the engine's tail_width guard.
        pack = _packed_gather_ok(wcap, int(colors0.max(initial=0)))
        # engine cache accounting: everything below that feeds a jit static
        # arg or an array shape.  A key this session has already presented
        # re-enters the jit cache; a fresh one forces a trace+compile.
        key = (n, ecap, wcap,
               tuple(c.shape[0] for c in classes), tuple(widths),
               tail_enabled, thr, pack, self._max_iters or n + 1)
        hit = key in self._engine_keys
        self._engine_keys.add(key)
        self._counters["engine_cache_hits" if hit else
                       "engine_cache_misses"] += 1
        # tail_width=wcap (not raw dmax): the serial-tail program's width is
        # a static jit arg, and deltas creep max_degree — pow2 rounding makes
        # that creep hit the cache; the extra gather slots are inert
        return run_ragged_engine(
            n=n, provider=provider, deg_ext=deg_ext, classes=classes,
            tile_widths=widths, acc_widths=widths, tail_width=wcap,
            mode=self._mode, heuristic=self._heuristic, kind=self._firstfit,
            use_kernel=self._use_kernel, coarsen=1, coarsen_lanes=None,
            tail_enabled=tail_enabled, tail_threshold=thr,
            max_iters=self._max_iters or n + 1, algorithm="dynamic_sgr",
            pack_degrees=pack, colors_init=jnp.asarray(colors0),
            stall_serializes_all=False, class_counts=counts,
            trace=self._trace,
        )

    # -- durability (§17) ----------------------------------------------------
    def _journal_append(self, kind: str, payload: dict) -> None:
        self._journal.append(kind, payload)
        self._records_since_snapshot += 1
        if (self._records_since_snapshot >= self._snapshot_every
                and not self._defer_maintenance):
            self.checkpoint()

    def checkpoint(self) -> None:
        """Write a full-state snapshot (DeltaCSR base + overlay, colors,
        dirty frontier, counters, engine options) into ``durable_dir``.

        Atomic (tmp + rename) and automatic every ``snapshot_every``
        journal records; ``restore()`` resumes from the latest snapshot
        plus the journal tail.  Raises unless the session was opened with
        ``durable_dir=``.
        """
        if self._journal is None:
            raise RuntimeError(
                "checkpoint() needs a durable session; open it with "
                "ColoringSession(..., durable_dir=path)")
        arrays = dict(self.delta.state_arrays())
        arrays["colors"] = np.asarray(self.colors, np.int32)
        arrays["dirty"] = self.frontier()
        meta = {
            "counters": {k: int(v) for k, v in self._counters.items()},
            "compactions": int(self.delta.compactions),
            "opts": {
                "heuristic": self._heuristic,
                "firstfit": self._firstfit,
                "mode": self._mode,
                "tiling": (list(self._tiling)
                           if isinstance(self._tiling, tuple)
                           else self._tiling),
                "tail_serial": self._tail_serial,
                "max_iters": self._max_iters,
                "compact_frac": self._compact_frac,
                "backend": self._backend,
                "trace": self._trace,
                "on_fail": self._on_fail,
                "snapshot_every": self._snapshot_every,
                "defer_maintenance": self._defer_maintenance,
            },
        }
        self._journal.write_snapshot(arrays, meta)
        self._records_since_snapshot = 0

    # -- pool hooks (§19): deferred maintenance + spill ----------------------
    def maintenance_due(self) -> dict:
        """Cheap poll: which deferred maintenance steps are owed.

        ``compact`` uses the session's CONFIGURED ``compact_frac`` even
        when ``defer_maintenance=True`` pinned the live DeltaCSR threshold
        to inf; ``snapshot`` mirrors the auto-checkpoint cadence the defer
        flag suppressed on the journal hot path.
        """
        return {
            "compact": self.delta.compaction_due(self._compact_frac),
            "snapshot": (self._journal is not None
                         and self._records_since_snapshot
                         >= self._snapshot_every),
        }

    def maintain(self) -> list[str]:
        """Run owed maintenance now (idle slot); returns actions performed.

        This is the off-hot-path half of ``defer_maintenance=True``: the
        pool calls it when a session has no queued work, so compaction and
        snapshot cost never lands inside a request's latency budget.
        """
        due = self.maintenance_due()
        done = []
        if due["compact"]:
            with span("compaction", overlay=self.delta.overlay_size,
                      deferred=True):
                self.delta.compact()
            done.append("compact")
        if due["snapshot"]:
            self.checkpoint()
            done.append("snapshot")
        return done

    def attach_durable(self, durable_dir: str) -> None:
        """Late-enable durability (§17) on a live session — the spill hook.

        Creates a fresh journal under ``durable_dir`` and writes a full
        snapshot, after which the in-memory object can be dropped and
        resumed bit-identically with ``restore(durable_dir)``.  A session
        that is already durable just checkpoints.
        """
        if self._journal is not None:
            self.checkpoint()
            return
        from repro.dynamic.journal import SessionJournal

        self._journal = SessionJournal(durable_dir, fresh=True)
        self._records_since_snapshot = 0
        self.checkpoint()

    @classmethod
    def restore(cls, durable_dir: str) -> "ColoringSession":
        """Resume a crashed (or closed) durable session, bit-identically.

        Loads the latest snapshot under ``durable_dir`` and replays every
        CRC-valid journal record after it through the normal
        ``apply_delta``/``recolor`` paths — the engines are deterministic,
        so the resulting colors match the uninterrupted session exactly.
        A torn journal tail (crash mid-write) stops the replay at the last
        good record; ``session.recovery`` reports the snapshot seq, the
        number of records replayed, and whether a truncated tail was
        dropped.
        """
        from repro.dynamic.delta import DeltaCSR
        from repro.dynamic.journal import SessionJournal

        journal = SessionJournal(durable_dir)
        snap = journal.load_snapshot()
        if snap is None:
            raise FileNotFoundError(
                f"no snapshot under {durable_dir!r}; restore() needs a "
                "session that was opened with durable_dir= (the opening "
                "checkpoint is written automatically)")
        arrays, meta = snap
        self = cls.__new__(cls)
        self.ingest_report = None
        opts = dict(meta["opts"])
        self._configure(**opts)
        self.delta = DeltaCSR.from_state(
            arrays, compact_frac=opts["compact_frac"],
            compactions=meta.get("compactions", 0))
        if self._defer_maintenance:
            self.delta.compact_frac = float("inf")
        self._counters = dict(meta["counters"])
        self.colors = np.asarray(arrays["colors"], np.int32)
        self.result = ColoringResult(
            self.colors.copy(), 0, 0, 0, True, "dynamic_sgr_restored")
        dirty = np.asarray(arrays["dirty"], np.int64)
        self._dirty = [dirty] if dirty.size else []
        # replay with journaling off (_configure left _journal=None): the
        # records being replayed are already on disk
        replayed = 0
        for rec in journal.records(after_seq=int(meta["seq"])):
            p = rec["payload"]
            if rec["kind"] == "delta":
                self.apply_delta(
                    add_vertices=p.get("add_vertices") or 0,
                    add_edges=_payload_edges(p.get("add_edges")),
                    remove_edges=_payload_edges(p.get("remove_edges")),
                    remove_vertices=p.get("remove_vertices"),
                )
            elif rec["kind"] == "recolor":
                self.recolor(full=bool(p.get("full")))
            replayed += 1
        self._journal = journal
        self._records_since_snapshot = replayed
        self.recovery = {
            "snapshot_seq": int(meta["seq"]),
            "replayed": replayed,
            "truncated": bool(getattr(journal, "truncated", False)),
        }
        return self

    # -- observability -------------------------------------------------------
    def metrics(self) -> dict:
        """Cumulative session counters (DESIGN.md §16).

        Lifetime totals since the cold coloring: mutation batches applied
        (``deltas``) and vertices they dirtied, committed/no-op/full
        recolors, summed frontier sizes, engine work items and super-steps,
        plus the engine-shape cache behaviour — ``engine_cache_hits`` counts
        frontier recolors whose (shape, static-arg) key repeated an earlier
        one (a jit cache hit; the pow2 padding in ``_recolor_frontier``
        exists to make steady-state churn land here) versus fresh keys that
        forced a trace+compile.  Overlay state comes from the live DeltaCSR.
        """
        out = dict(self._counters)
        out["overlay_size"] = int(self.delta.overlay_size)
        out["compactions"] = int(self.delta.compactions)
        out["n"] = int(self.n)
        out["num_colors"] = self.num_colors
        out["pending_frontier"] = int(self.frontier().size)
        if self._journal is not None:
            out["journal_seq"] = int(self._journal.seq)
            out["records_since_snapshot"] = int(self._records_since_snapshot)
        return out


@register("dynamic")
def color_dynamic(g: CSRGraph, **opts) -> ColoringResult:
    """Cold-start a ``ColoringSession`` on ``g`` and return its coloring.

    Registry adapter so the unified API (and benchmarks) can exercise the
    dynamic engine's cold path — identical colors to
    ``color(g, "fused", engine="ragged")``; keep the session itself
    (``open_session``) for actual streaming workloads.
    """
    return ColoringSession(g, **opts).result
