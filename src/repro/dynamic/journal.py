"""Write-ahead delta journal + snapshots for ``ColoringSession`` (§17).

A session that dies mid-churn used to lose its entire delta history — the
DeltaCSR overlay, the dirty frontier, and every committed recolor lived
only in process memory.  Durability here is the classic WAL pair:

* **journal.jsonl** — one CRC-guarded JSON record per mutation batch
  (``kind="delta"``, appended *before* the overlay mutates) and per
  committed recolor (``kind="recolor"``, appended after commit, so a crash
  between engine run and commit replays as "that recolor never happened" —
  exactly the state the dying process was in);
* **snapshot.npz / snapshot.json** — a full state checkpoint (DeltaCSR
  base + overlay keys, colors, dirty frontier, counters, engine options)
  written atomically (tmp + rename) by ``ColoringSession.checkpoint()``
  and automatically every ``snapshot_every`` journal records.

``ColoringSession.restore(dir)`` loads the latest snapshot and replays
every journal record after its sequence number through the normal
``apply_delta``/``recolor`` code paths — the engines are deterministic, so
the replayed state is **bit-identical** to the uninterrupted session
(tested in ``tests/test_faultlab.py``).  A torn or corrupted journal tail
(the crash wrote half a record; ``repro.faultlab.truncate_journal``
simulates it) fails its CRC and replay stops at the last good record — the
recovery report on the session says how far it got.
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

__all__ = ["SessionJournal", "JOURNAL_NAME", "SNAPSHOT_META", "SNAPSHOT_DATA"]

JOURNAL_NAME = "journal.jsonl"
SNAPSHOT_META = "snapshot.json"
SNAPSHOT_DATA = "snapshot.npz"


def _record_crc(seq: int, kind: str, payload: dict) -> int:
    body = json.dumps({"seq": seq, "kind": kind, "payload": payload},
                      sort_keys=True, separators=(",", ":"))
    return zlib.crc32(body.encode())


class SessionJournal:
    """Append-only CRC'd JSONL journal + atomic snapshot pair in one dir."""

    def __init__(self, dirpath: str, *, fresh: bool = False):
        self.dir = str(dirpath)
        os.makedirs(self.dir, exist_ok=True)
        self.path = os.path.join(self.dir, JOURNAL_NAME)
        if fresh:
            for name in (JOURNAL_NAME, SNAPSHOT_META, SNAPSHOT_DATA):
                p = os.path.join(self.dir, name)
                if os.path.exists(p):
                    os.remove(p)
        self._seq = self._last_seq()

    # -- journal -----------------------------------------------------------
    def _last_seq(self) -> int:
        last = 0
        for rec in self.records():
            last = rec["seq"]
        return last

    @property
    def seq(self) -> int:
        """Sequence number of the last appended (or recovered) record."""
        return self._seq

    def append(self, kind: str, payload: dict) -> int:
        """Durably append one record; returns its sequence number."""
        self._seq += 1
        rec = {"seq": self._seq, "kind": kind, "payload": payload,
               "crc": _record_crc(self._seq, kind, payload)}
        line = json.dumps(rec, sort_keys=True, separators=(",", ":"))
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        return self._seq

    def records(self, after_seq: int = 0):
        """Yield valid records with ``seq > after_seq``; stop at corruption.

        A record that fails to parse, fails its CRC, or breaks the
        monotone sequence marks the torn tail of a crashed write — it and
        everything after it are ignored (``self.truncated`` reports it).
        """
        self.truncated = False
        if not os.path.exists(self.path):
            return
        expect = None
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    ok = (rec.get("crc") == _record_crc(
                        rec["seq"], rec["kind"], rec["payload"]))
                except (ValueError, KeyError, TypeError):
                    ok = False
                if not ok or (expect is not None and rec["seq"] != expect):
                    self.truncated = True
                    return
                expect = rec["seq"] + 1
                if rec["seq"] > after_seq:
                    yield rec

    # -- snapshots -----------------------------------------------------------
    def write_snapshot(self, arrays: dict, meta: dict) -> None:
        """Atomically persist a full-state checkpoint at the current seq."""
        meta = dict(meta, seq=self._seq)
        tmp_npz = os.path.join(self.dir, SNAPSHOT_DATA + ".tmp")
        with open(tmp_npz, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_npz, os.path.join(self.dir, SNAPSHOT_DATA))
        tmp_meta = os.path.join(self.dir, SNAPSHOT_META + ".tmp")
        with open(tmp_meta, "w") as f:
            json.dump(meta, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_meta, os.path.join(self.dir, SNAPSHOT_META))

    def load_snapshot(self) -> tuple[dict, dict] | None:
        """The latest checkpoint as ``(arrays, meta)``, or None."""
        meta_path = os.path.join(self.dir, SNAPSHOT_META)
        data_path = os.path.join(self.dir, SNAPSHOT_DATA)
        if not (os.path.exists(meta_path) and os.path.exists(data_path)):
            return None
        with open(meta_path) as f:
            meta = json.load(f)
        with np.load(data_path) as z:
            arrays = {k: z[k] for k in z.files}
        return arrays, meta
