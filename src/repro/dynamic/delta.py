"""``DeltaCSR`` — a batched mutation overlay over the host CSR graph (§14).

CSR is the wrong structure to mutate in place (a row's length change shifts
every later offset), so mutations accumulate in an *overlay* against an
immutable compacted base:

* the base is a ``CSRGraph`` plus its sorted directed-edge key array
  ``(u << 32) | v`` (int64) — row-sorted CSR makes the keys sorted for free;
* ``_add`` holds keys present now but absent from the base,
  ``_del`` keys present in the base but deleted since — both sorted, both
  disjoint from each other, with ``_add ∩ base = ∅`` and ``_del ⊆ base``
  as maintained invariants, so the current edge set is always
  ``(base ∖ _del) ∪ _add`` and every membership question is a vectorized
  ``O(Δ log m)`` sorted-array operation;
* ``compact()`` folds the overlay back into a fresh base — a sorted
  set-merge, NOT an ``O(m log m)`` re-sort — and fires automatically once the
  overlay outgrows ``compact_frac`` of the base (the snapshot build the
  engine reads is ``O(m)`` either way, so an unbounded overlay only adds
  set-op cost, never corrupts anything).

Mutations are **batched and vectorized**: each call takes edge *arrays*
(symmetrized, self-loops dropped, duplicates ignored) and returns the vertex
ids whose neighborhoods actually changed — the dirty frontier the
``ColoringSession`` recolors.  Adding an edge that already exists, or
removing one that doesn't, is a no-op and dirties nobody.

Vertex semantics keep ids stable (colors are indexed by vertex id, so
renumbering would invalidate every frozen color): ``add_vertices`` appends
isolated vertices at the end of the id space, ``remove_vertices`` deletes
all incident edges and leaves the slot behind as an isolated (degree-0)
vertex.  The id space therefore only grows; compaction never renumbers.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph, _gather_ragged

__all__ = ["DeltaCSR"]

_LO32 = np.int64(0xFFFFFFFF)
_EMPTY_KEYS = np.zeros(0, np.int64)
_EMPTY_IDS = np.zeros(0, np.int32)


def _graph_keys(g: CSRGraph) -> np.ndarray:
    """Sorted directed-edge keys of a CSR graph (sorted rows => sorted keys)."""
    src, dst = g.edges()
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


def _ends(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    return (keys >> 32), (keys & _LO32)


def _clean_pairs(src, dst, n: int) -> np.ndarray:
    """Unique symmetrized directed keys of an edge batch (self-loops dropped)."""
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError(
            f"edge batch endpoint arrays differ in length: "
            f"{src.shape[0]} vs {dst.shape[0]}")
    if src.size == 0:
        return _EMPTY_KEYS
    lo = min(int(src.min()), int(dst.min()))
    hi = max(int(src.max()), int(dst.max()))
    if lo < 0 or hi >= n:
        raise ValueError(
            f"edge endpoint out of range [0, {n}): saw {lo if lo < 0 else hi}")
    keep = src != dst
    src, dst = src[keep], dst[keep]
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    return np.unique((u << 32) | v)


class DeltaCSR:
    """Mutable graph = immutable CSR base + sorted add/delete key overlay."""

    def __init__(self, base: CSRGraph, *, compact_frac: float = 0.25,
                 validate_input: str | None = None):
        self.ingest_report = None
        if validate_input is not None:
            # §17 front door: overlay invariants (sorted keys, symmetry,
            # no dups/loops) inherit from the base — a dirty base corrupts
            # every later membership query, so sanitize it on the way in
            from repro.ingest import sanitize_csr

            base, self.ingest_report = sanitize_csr(
                base, policy=validate_input)
        self._base = base
        self._base_keys = _graph_keys(base)
        self._n = base.n
        self._add = _EMPTY_KEYS
        self._del = _EMPTY_KEYS
        self._cache: CSRGraph | None = base
        self.compact_frac = float(compact_frac)
        self.compactions = 0

    @classmethod
    def from_edges(cls, n: int, src, dst, **kw) -> "DeltaCSR":
        from repro.core.csr import csr_from_edges

        return cls(csr_from_edges(n, src, dst), **kw)

    # -- durable state (§17 session checkpoints) -----------------------------
    def state_arrays(self) -> dict:
        """The full mutable state as named numpy arrays (snapshot format)."""
        return {
            "base_row_offsets": self._base.row_offsets.astype(np.int64),
            "base_col_indices": self._base.col_indices.astype(np.int32),
            "add_keys": self._add,
            "del_keys": self._del,
            "delta_n": np.asarray(self._n, np.int64),
        }

    @classmethod
    def from_state(cls, arrays: dict, *, compact_frac: float = 0.25,
                   compactions: int = 0) -> "DeltaCSR":
        """Rebuild a ``DeltaCSR`` from ``state_arrays()`` output."""
        base = CSRGraph(
            np.asarray(arrays["base_row_offsets"], np.int64),
            np.asarray(arrays["base_col_indices"], np.int32))
        d = cls(base, compact_frac=compact_frac)
        d._add = np.asarray(arrays["add_keys"], np.int64)
        d._del = np.asarray(arrays["del_keys"], np.int64)
        d._n = int(arrays["delta_n"])
        d.compactions = int(compactions)
        if d._add.size or d._del.size or d._n != base.n:
            d._cache = None
        return d

    # -- current-state views -------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        """Current directed edge count (2x undirected)."""
        return self._base_keys.size - self._del.size + self._add.size

    @property
    def overlay_size(self) -> int:
        return self._add.size + self._del.size

    def _current_keys(self) -> np.ndarray:
        kept = np.setdiff1d(self._base_keys, self._del, assume_unique=True)
        if self._add.size == 0:
            return kept
        return np.union1d(kept, self._add)  # disjoint sorted sets: pure merge

    def graph(self) -> CSRGraph:
        """The current graph as a (cached) host CSRGraph snapshot.

        The snapshot object is reused until the next mutation, so device
        views memoized on it (``_graph_device_cache``) survive across
        recolor calls on a quiet graph.
        """
        if self._cache is None:
            cur = self._current_keys()
            src, dst = _ends(cur)
            counts = np.bincount(src, minlength=self._n)
            row_offsets = np.zeros(self._n + 1, np.int64)
            np.cumsum(counts, out=row_offsets[1:])
            self._cache = CSRGraph(row_offsets, dst.astype(np.int32))
        return self._cache

    def compact(self) -> CSRGraph:
        """Fold the overlay into a fresh base; returns the compacted graph."""
        g = self.graph()
        if self.overlay_size or g is not self._base:
            self._base = g
            self._base_keys = _graph_keys(g)
            self._add = _EMPTY_KEYS
            self._del = _EMPTY_KEYS
            self.compactions += 1
        return self._base

    def compaction_due(self, frac: float | None = None) -> bool:
        """True once the overlay outgrows ``frac`` of the base (cheap poll).

        ``frac`` defaults to the live ``compact_frac``; a pooled session
        (§19) sets ``compact_frac=inf`` to suppress the inline compaction
        and polls this with its CONFIGURED fraction from an idle slot.
        """
        frac = self.compact_frac if frac is None else frac
        return self.overlay_size > frac * max(self._base_keys.size, 64)

    def _touched(self) -> None:
        self._cache = None
        if self.compaction_due():
            self.compact()

    # -- batched mutations (each returns the dirtied vertex ids) -------------
    def add_vertices(self, count: int) -> np.ndarray:
        """Append ``count`` isolated vertices; returns their (new) ids."""
        count = int(count)
        if count < 0:
            raise ValueError(f"cannot add {count} vertices")
        from repro.ingest import INDEX_MAX

        if self._n + count > INDEX_MAX:
            raise ValueError(
                f"adding {count} vertices would push n past the int32 "
                f"index capacity ({INDEX_MAX}); colors and worklists are "
                "int32 device arrays")
        ids = np.arange(self._n, self._n + count, dtype=np.int32)
        if count:
            self._n += count
            self._cache = None  # id space grew; edge overlay unchanged
        return ids

    def add_edges(self, src, dst) -> np.ndarray:
        """Insert an undirected edge batch; returns ids that gained neighbors."""
        k = _clean_pairs(src, dst, self._n)
        if k.size == 0:
            return _EMPTY_IDS
        in_base = np.isin(k, self._base_keys, assume_unique=True)
        in_del = np.isin(k, self._del, assume_unique=True)
        in_add = np.isin(k, self._add, assume_unique=True)
        new = ~((in_base & ~in_del) | in_add)
        if not new.any():
            return _EMPTY_IDS
        self._del = np.setdiff1d(self._del, k[new & in_del], assume_unique=True)
        self._add = np.union1d(self._add, k[new & ~in_base])
        self._touched()
        return np.unique(k[new] >> 32).astype(np.int32)

    def remove_edges(self, src, dst) -> np.ndarray:
        """Delete an undirected edge batch; returns ids that lost neighbors."""
        k = _clean_pairs(src, dst, self._n)
        if k.size == 0:
            return _EMPTY_IDS
        in_base = np.isin(k, self._base_keys, assume_unique=True)
        in_del = np.isin(k, self._del, assume_unique=True)
        in_add = np.isin(k, self._add, assume_unique=True)
        gone = (in_base & ~in_del) | in_add
        if not gone.any():
            return _EMPTY_IDS
        self._del = np.union1d(self._del, k[gone & in_base])
        self._add = np.setdiff1d(self._add, k[gone & in_add], assume_unique=True)
        self._touched()
        return np.unique(k[gone] >> 32).astype(np.int32)

    def remove_vertices(self, ids) -> np.ndarray:
        """Drop every edge incident to ``ids`` (slots stay, as isolated ids).

        Returns the dirtied ids: the removed vertices AND their ex-neighbors
        (whose neighborhoods shrank).
        """
        ids = np.unique(np.asarray(ids, dtype=np.int64).ravel())
        if ids.size == 0:
            return _EMPTY_IDS
        if ids[0] < 0 or ids[-1] >= self._n:
            raise ValueError(
                f"vertex id out of range [0, {self._n}): saw "
                f"{ids[0] if ids[0] < 0 else ids[-1]}")
        # directed keys with src ∈ ids: base rows (minus deletions) + overlay
        old = ids[ids < self._base.n]
        lens = (self._base.row_offsets[old + 1]
                - self._base.row_offsets[old]).astype(np.int64)
        nbr = _gather_ragged(self._base.row_offsets, self._base.col_indices,
                             old).astype(np.int64)
        base_inc = (np.repeat(old, lens) << 32) | nbr
        base_inc = np.setdiff1d(base_inc, self._del, assume_unique=True)
        add_inc = self._add[np.isin(self._add >> 32, ids)]
        inc = np.union1d(base_inc, add_inc)
        if inc.size == 0:
            return _EMPTY_IDS
        u, v = _ends(inc)
        partners = (v << 32) | u  # the symmetric halves stored under v's row
        all_inc = np.union1d(inc, partners)
        self._del = np.union1d(
            self._del,
            all_inc[np.isin(all_inc, self._base_keys, assume_unique=True)])
        self._add = np.setdiff1d(self._add, all_inc, assume_unique=True)
        self._touched()
        # dirty = ids that actually lost edges + their ex-neighbors; edge-less
        # members of ``ids`` were no-ops and dirty nobody (u ⊆ ids by
        # construction — they are the incident keys' source endpoints)
        return np.union1d(np.unique(u), np.unique(v)).astype(np.int32)
