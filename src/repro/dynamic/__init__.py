"""Streaming dynamic-graph coloring engine (DESIGN.md §14).

``DeltaCSR`` (batched edge/vertex insert+delete as an overlay over the CSR
base, with periodic compaction) + ``ColoringSession`` (incremental
recoloring of the dirty frontier on the §12 rotated super-step, all other
colors frozen as snapshot context).  Registered as algorithm ``"dynamic"``.
"""
from repro.dynamic.churn import churn_delta
from repro.dynamic.delta import DeltaCSR
from repro.dynamic.session import ColoringSession, color_dynamic, open_session

__all__ = ["ColoringSession", "DeltaCSR", "churn_delta", "color_dynamic",
           "open_session"]
