"""Shared churn-workload generator for the §14 streaming engine.

One implementation of the sliding-window edge stream used by the churn
benchmark (``benchmarks/dynamic.py``), the acceptance tests
(``tests/test_dynamic.py``), and the demo (``examples/stream_serve.py``) —
so the workload the CI gate measures is exactly the one the tests and the
example exercise.
"""
from __future__ import annotations

import numpy as np

__all__ = ["churn_delta"]


def churn_delta(g, frac: float, rng) -> tuple[tuple, tuple]:
    """One churn round: ``(remove_edges, add_edges)`` batches for ``g``.

    Deletes ``frac`` of the undirected edges (chosen by ``rng``) and draws
    the same number of uniform random pairs to insert (self-loops and
    duplicates are dropped by the ``DeltaCSR`` mutation layer, so the
    effective insert count is slightly below the delete count on dense
    graphs — the stream drifts sparse, like real churn).
    """
    src, dst = g.edges()
    und = src < dst
    es, ed = src[und], dst[und]
    k = max(1, int(frac * es.size))
    drop = rng.permutation(es.size)[:k]
    add = (rng.integers(0, g.n, k), rng.integers(0, g.n, k))
    return (es[drop], ed[drop]), add
