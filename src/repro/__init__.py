"""csrcolor-jax: speculative-greedy sparse graph coloring (Chen/Li/Yang 2016)
as a first-class feature of a multi-pod JAX/TPU framework.

Subpackages: core (the paper's coloring engine), graphs, kernels (Pallas),
models / configs / training / distributed / launch (the LM substrate and
multi-pod runtime).  See README.md and DESIGN.md.
"""

__version__ = "1.0.0"
