"""csrcolor-jax: speculative-greedy sparse graph coloring (Chen/Li/Yang 2016)
as a first-class feature of a multi-pod JAX/TPU framework.

Public entry point: ``repro.color`` / ``repro.color_batch`` (lazy re-exports
of ``repro.api``) — a registry-dispatched facade over every implementation.

Subpackages: core (the paper's coloring engine + batched multi-graph
engine), graphs, kernels (Pallas), models / configs / training /
distributed / launch (the LM substrate and multi-pod runtime).  See
README.md and DESIGN.md.
"""

__version__ = "1.2.0"

_API_NAMES = ("color", "color_batch", "algorithms", "get_algorithm",
              "register", "open_session")
_OPTIONS_NAMES = ("ColorOptions",)
_ERROR_NAMES = ("ReproError", "IngestError", "CapacityError",
                "NonConvergenceError", "Overloaded", "SessionEvicted")
_SERVICE_NAMES = ("ColoringService",)


def __getattr__(name):
    # keep `import repro` light: the api (and jax) load on first use only
    if name in _API_NAMES:
        from repro import api

        return getattr(api, name)
    if name in _OPTIONS_NAMES:
        from repro import options

        return getattr(options, name)
    if name in _ERROR_NAMES:
        from repro import errors

        return getattr(errors, name)
    if name in _SERVICE_NAMES:
        from repro.launch import coloring_service

        return getattr(coloring_service, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API_NAMES) + list(_OPTIONS_NAMES)
                  + list(_ERROR_NAMES) + list(_SERVICE_NAMES))
