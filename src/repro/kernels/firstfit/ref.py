"""Pure-jnp oracle for the bitset FirstFit kernel.

Deliberately *independent* of both the kernel and the production
``core.firstfit`` implementations: candidate membership is checked by direct
(quadratic) comparison, the most obviously-correct formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["firstfit_ref"]


def firstfit_ref(neigh_colors: jax.Array) -> jax.Array:
    """Smallest color in [1, W+1] not present among each row's neighbors."""
    w, W = neigh_colors.shape
    cand = jnp.arange(1, W + 2, dtype=neigh_colors.dtype)       # (C,)
    forbidden = (neigh_colors[:, None, :] == cand[None, :, None]).any(-1)
    return (jnp.argmax(~forbidden, axis=1) + 1).astype(jnp.int32)
