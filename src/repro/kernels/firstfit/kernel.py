"""Pallas TPU kernel: bitset FirstFit (paper §3.2 "Bitset Operation").

One grid step FirstFits ``block_n`` worklist vertices.  The forbidden-color
set lives as packed uint32 words in VMEM/VREGs — the TPU analogue of the
paper's register-resident bitmask — built by a vectorized fori-loop over the
padded neighbor lanes.  CUDA's ``__ffs`` has no TPU counterpart, so
find-first-set is computed structurally: expand each word against a 32-lane
bit iota, mask out positions beyond the greedy bound W+1, and take the min
position — shifts, compares and a min-reduce only, all native VPU ops (no
gather, no popcount — friendliest possible Mosaic lowering).

VMEM working set per grid step: the (block_n, W) neighbor-color tile plus
(block_n, nwords) bit words — ``block_n`` is chosen by ops.py so this stays
within a ~2 MiB budget, the thread-coarsening knob of DESIGN.md §3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["firstfit_kernel", "firstfit_pallas_call"]


def firstfit_kernel(nc_ref, out_ref, *, nwords: int):
    nc = nc_ref[...]  # (block_n, W) int32 neighbor colors; 0 = none
    block_n, W = nc.shape

    idx = nc - 1                      # bit position of each forbidden color
    valid = idx >= 0
    word_of = jnp.where(valid, idx >> 5, -1)
    bit = (jnp.where(valid, idx, 0) & 31).astype(jnp.uint32)
    bits = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))

    word_iota = lax.broadcasted_iota(jnp.int32, (block_n, nwords), 1)

    def accumulate(d, words):
        hit = word_iota == word_of[:, d][:, None]
        return words | jnp.where(hit, bits[:, d][:, None], jnp.uint32(0))

    words = lax.fori_loop(
        0, W, accumulate, jnp.zeros((block_n, nwords), jnp.uint32)
    )

    # find-first-set: min over (word, bit) of free positions <= W
    free = ~words                                              # (bn, nwords)
    bitpos = lax.broadcasted_iota(jnp.uint32, (block_n, nwords, 32), 2)
    is_free = ((free[:, :, None] >> bitpos) & jnp.uint32(1)) == jnp.uint32(1)
    pos = (
        lax.broadcasted_iota(jnp.int32, (block_n, nwords, 32), 1) * 32
        + bitpos.astype(jnp.int32)
    )
    big = jnp.int32(W + 2)
    pos = jnp.where(is_free & (pos <= W), pos, big)
    out_ref[...] = jnp.min(pos, axis=(1, 2)).astype(jnp.int32) + 1


def firstfit_pallas_call(w: int, W: int, block_n: int, interpret: bool):
    """Build the pallas_call for a (w, W) neighbor-color tile."""
    nwords = (W + 1 + 31) // 32
    grid = (pl.cdiv(w, block_n),)
    return pl.pallas_call(
        functools.partial(firstfit_kernel, nwords=nwords),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=interpret,
    )
