from repro.kernels.firstfit.ops import firstfit_bitset_tpu

__all__ = ["firstfit_bitset_tpu"]
