"""jit'd wrapper for the bitset FirstFit Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.firstfit.kernel import firstfit_pallas_call

__all__ = ["firstfit_bitset_tpu"]

_VMEM_BUDGET = 2 * 1024 * 1024  # bytes for the neighbor-color tile


def _pick_block_n(w: int, W: int) -> int:
    by_vmem = max(8, _VMEM_BUDGET // max(W * 4, 1))
    # round down to a multiple of 8 (sublane), cap at the row count
    bn = max(8, (min(by_vmem, 256, w) // 8) * 8)
    return bn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def _run(nc, *, block_n: int, interpret: bool):
    return firstfit_pallas_call(nc.shape[0], nc.shape[1], block_n, interpret)(nc)


def firstfit_bitset_tpu(
    neigh_colors: jax.Array,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """FirstFit over padded neighbor colors ``(w, W)`` -> colors ``(w,)``.

    ``interpret`` defaults to True off-TPU (CPU validation mode per the task
    contract) and False on real TPU backends.
    """
    w, W = neigh_colors.shape
    if w == 0:
        return jnp.zeros((0,), jnp.int32)
    interpret = (not _on_tpu()) if interpret is None else interpret
    block_n = block_n or _pick_block_n(w, W)
    return _run(neigh_colors.astype(jnp.int32), block_n=block_n, interpret=interpret)
