"""Backend dispatch for the engine fast paths (DESIGN.md §15).

One tiny resolver decides, for every engine entry point, whether the ragged
super-step runs through the fused Pallas kernel (``kernels/superstep``) or
the pure-JAX formulation.  Both produce bit-identical colors — the kernel
implements the exact same conflict rule and bitset FirstFit arithmetic — so
the choice is purely a performance policy and the resolver is the single
place that policy lives:

* ``backend=None``   — legacy: honor the per-call ``use_kernel`` knob
  (``use_kernel=True`` has always meant "route through the Pallas kernels").
* ``backend="jax"``  — force the pure-JAX engine.  Contradicting it with
  ``use_kernel=True`` raises instead of silently picking a side.
* ``backend="pallas"`` — force the kernel path.  On non-TPU backends the
  kernels run in ``interpret=True`` mode (see ``kernels/superstep/ops.py``),
  slow but bit-identical — which is what the differential test matrix runs
  in CI.
* ``backend="auto"`` — ``pallas`` when the default JAX backend is a TPU,
  ``jax`` otherwise (interpret mode is a debugging tool, not a fast path).

Engines that cannot host the kernel (the §13 multi-device sharded engine —
``shard_map`` bodies stay pure-JAX) treat ``backend="pallas"`` as an
automatic fallback to pure-JAX: bit-identity makes the fallback invisible
except in wall-clock.
"""
from __future__ import annotations

import jax

__all__ = ["resolve_backend", "BACKENDS"]

BACKENDS = ("jax", "pallas", "auto")


def resolve_backend(backend: str | None, use_kernel: bool = False) -> str:
    """Resolve the ``backend=`` option to ``"jax"`` or ``"pallas"``.

    ``use_kernel`` is the legacy per-call knob; it decides only when
    ``backend`` is None and conflicts loudly with ``backend="jax"``.
    """
    if backend is None:
        return "pallas" if use_kernel else "jax"
    if backend == "auto":
        return "pallas" if (use_kernel or jax.default_backend() == "tpu") \
            else "jax"
    if backend == "jax":
        if use_kernel:
            raise ValueError(
                "backend='jax' contradicts use_kernel=True; drop one of them "
                "(backend='pallas' is the kernel path)")
        return "jax"
    if backend == "pallas":
        return "pallas"
    raise ValueError(
        f"unknown backend {backend!r}; options: {', '.join(BACKENDS)}")
