"""Backend dispatch for the engine fast paths (DESIGN.md §15).

One tiny resolver decides, for every engine entry point, whether the ragged
super-step runs through the fused Pallas kernel (``kernels/superstep``) or
the pure-JAX formulation.  Both produce bit-identical colors — the kernel
implements the exact same conflict rule and bitset FirstFit arithmetic — so
the choice is purely a performance policy and the resolver is the single
place that policy lives:

* ``backend=None``   — legacy: honor the per-call ``use_kernel`` knob
  (``use_kernel=True`` has always meant "route through the Pallas kernels").
* ``backend="jax"``  — force the pure-JAX engine.  Contradicting it with
  ``use_kernel=True`` raises instead of silently picking a side.
* ``backend="pallas"`` — force the gathered-tile kernel path.  On non-TPU
  backends the kernels run in ``interpret=True`` mode (see
  ``kernels/superstep/ops.py``), slow but bit-identical — which is what
  the differential test matrix runs in CI.
* ``backend="pallas-csr"`` — force the CSR-resident fused kernel path
  (DESIGN.md §18): the kernel gathers straight from the DeviceCSR arrays,
  no materialized ``(w, W)`` tile in HBM.  Engines or configurations that
  can't feed it CSR arrays (dense batch layouts, multi-chunk classes,
  packed-word overflow) fall back to the gathered kernel — bit-identical,
  so the fallback is invisible except in wall-clock.
* ``backend="auto"`` — ``pallas-csr`` when the default JAX backend is a
  TPU, ``jax`` otherwise (interpret mode is a debugging tool, not a fast
  path); the legacy ``use_kernel=True`` knob keeps meaning the gathered
  kernel.

Engines that cannot host any kernel (the §13 multi-device sharded engine —
``shard_map`` bodies stay pure-JAX) treat both pallas backends as an
automatic fallback to pure-JAX: bit-identity makes the fallback invisible
except in wall-clock.
"""
from __future__ import annotations

import warnings

import jax

__all__ = ["resolve_backend", "kernel_mode", "BACKENDS"]

BACKENDS = ("jax", "pallas", "pallas-csr", "auto")


def resolve_backend(backend: str | None, use_kernel: bool = False) -> str:
    """Resolve ``backend=`` to ``"jax"``, ``"pallas"`` or ``"pallas-csr"``.

    ``use_kernel`` is the legacy per-call knob, DEPRECATED since §19: a
    True value warns and keeps meaning the gathered-kernel path for one
    more release (the compat shim), decides only when ``backend`` is None
    or "auto", and conflicts loudly with ``backend="jax"``.  The unified
    entry points translate it into ``backend=`` before reaching here
    (``repro.options.ColorOptions.normalize``); this shim covers direct
    engine calls.
    """
    if use_kernel:
        from repro.options import _DEPRECATION_MSG

        warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=3)
    if backend is None:
        return "pallas" if use_kernel else "jax"
    if backend == "auto":
        if use_kernel:
            return "pallas"
        return "pallas-csr" if jax.default_backend() == "tpu" else "jax"
    if backend == "jax":
        if use_kernel:
            raise ValueError(
                "backend='jax' contradicts use_kernel=True; drop one of them "
                "(backend='pallas' is the kernel path)")
        return "jax"
    if backend in ("pallas", "pallas-csr"):
        return backend
    raise ValueError(
        f"unknown backend {backend!r}; options: {', '.join(BACKENDS)}")


def kernel_mode(resolved: str):
    """Map a resolved backend to the engine-internal ``use_kernel`` value.

    ``False`` — pure JAX; ``True`` — gathered-tile Pallas kernel;
    ``"csr"`` — CSR-resident fused kernel (gathered fallback where the CSR
    arrays aren't available).  All three are hashable, so the value can sit
    in jit static args; ``"csr"`` is truthy, so boolean-ish "any kernel?"
    checks keep working.
    """
    return {"jax": False, "pallas": True, "pallas-csr": "csr"}[resolved]
