"""Pallas TPU kernels for the paper's compute hot spots.

* firstfit/ — bitset FirstFit (packed forbidden-color words + structural
  find-first-set), the paper's §3.2 "Bitset Operation" on the MXU-era VPU.
* conflict/ — ConflictResolve detection with the §3.2 degree heuristic.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper; interpret=True off-TPU) and ref.py (independent
pure-jnp oracle); tests/test_kernels.py sweeps shapes/dtypes/block sizes.
EXAMPLE.md documents the layer contract.
"""
