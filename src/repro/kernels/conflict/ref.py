"""Pure-jnp oracle for the conflict-detect kernel.

Re-derives the loser rule directly from the paper's text, independent of both
the kernel and ``core.heuristics`` (which is itself oracle-checked in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conflict_ref"]


def conflict_ref(ids, nid, my_c, nc, my_d, nd, heuristic: str) -> jax.Array:
    same = (nc == my_c[:, None]) & (my_c[:, None] > 0)
    if heuristic == "id":
        lose = same & (ids[:, None] < nid)
    elif heuristic == "degree":
        lose = same & (
            (nd > my_d[:, None]) | ((nd == my_d[:, None]) & (nid < ids[:, None]))
        )
    else:
        raise ValueError(heuristic)
    return jnp.any(lose, axis=1)
