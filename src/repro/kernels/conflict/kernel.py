"""Pallas TPU kernel: ConflictResolve detection (paper Alg. 5 + §3.2 heuristic).

One grid step decides, for ``block_n`` worklist vertices, whether each loses a
speculative conflict and must recolor.  The per-row scalars (vertex id, its
color, its degree) arrive packed in a ``(block_n, 3)`` int32 tile so every ref
is 2-D (TPU-native layout); the three ``(block_n, W)`` neighbor tiles (ids,
colors, degrees) stream HBM->VMEM via BlockSpec.  The loser rule is a pure
lane-wise compare + any-reduce — no gathers, no control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conflict_kernel", "conflict_pallas_call", "COL_ID", "COL_COLOR", "COL_DEG"]

COL_ID, COL_COLOR, COL_DEG = 0, 1, 2


def conflict_kernel(me_ref, nid_ref, nc_ref, nd_ref, out_ref, *, heuristic: str):
    me = me_ref[...]                # (bn, 3): [id, color, degree]
    nid = nid_ref[...]              # (bn, W) neighbor ids (sentinel in pads)
    nc = nc_ref[...]                # (bn, W) neighbor colors (0 in pads)
    nd = nd_ref[...]                # (bn, W) neighbor degrees (0 in pads)

    my_id = me[:, COL_ID][:, None]
    my_c = me[:, COL_COLOR][:, None]
    my_d = me[:, COL_DEG][:, None]

    same = (nc == my_c) & (my_c > 0)
    if heuristic == "id":
        lose_lane = same & (my_id < nid)
    else:  # degree: larger degree keeps; tie -> smaller id keeps
        lose_lane = same & ((nd > my_d) | ((nd == my_d) & (nid < my_id)))
    out_ref[...] = jnp.any(lose_lane, axis=1).astype(jnp.int32)


def conflict_pallas_call(w: int, W: int, block_n: int, heuristic: str, interpret: bool):
    grid = (pl.cdiv(w, block_n),)
    row_spec = pl.BlockSpec((block_n, W), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(conflict_kernel, heuristic=heuristic),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            row_spec,
            row_spec,
            row_spec,
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=interpret,
    )
