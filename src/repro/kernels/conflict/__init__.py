from repro.kernels.conflict.ops import conflict_tpu

__all__ = ["conflict_tpu"]
