"""jit'd wrapper for the conflict-detect Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.conflict.kernel import conflict_pallas_call

__all__ = ["conflict_tpu"]

_VMEM_BUDGET = 2 * 1024 * 1024


def _pick_block_n(w: int, W: int) -> int:
    by_vmem = max(8, _VMEM_BUDGET // max(W * 4 * 3, 1))
    return max(8, (min(by_vmem, 256, w) // 8) * 8)


@partial(jax.jit, static_argnames=("heuristic", "block_n", "interpret"))
def _run(me, nid, nc, nd, *, heuristic, block_n, interpret):
    return conflict_pallas_call(
        me.shape[0], nid.shape[1], block_n, heuristic, interpret
    )(me, nid, nc, nd)


def conflict_tpu(
    ids: jax.Array,
    neigh_ids: jax.Array,
    my_colors: jax.Array,
    neigh_colors: jax.Array,
    my_deg: jax.Array,
    neigh_deg: jax.Array,
    heuristic: str = "degree",
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Loser flags (bool, (w,)) for speculative conflicts; kernel-backed."""
    w, W = neigh_ids.shape
    if w == 0:
        return jnp.zeros((0,), bool)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    block_n = block_n or _pick_block_n(w, W)
    me = jnp.stack(
        [ids.astype(jnp.int32), my_colors.astype(jnp.int32), my_deg.astype(jnp.int32)],
        axis=1,
    )
    lose = _run(
        me,
        neigh_ids.astype(jnp.int32),
        neigh_colors.astype(jnp.int32),
        neigh_deg.astype(jnp.int32),
        heuristic=heuristic,
        block_n=block_n,
        interpret=interpret,
    )
    return lose.astype(bool)
