"""jit'd wrapper for the fused super-step Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.superstep.kernel import superstep_pallas_call

__all__ = ["superstep_tpu"]

# VMEM budget for one grid step's working set; see _pick_block_n
_VMEM_BUDGET = 2 * 1024 * 1024


def _pick_block_n(w: int, W: int, *, tiles: int = 3) -> int:
    """Largest block_n (multiple of 8, capped at 256) fitting _VMEM_BUDGET.

    The per-row working set is ``tiles`` int32 ``(block_n, W)`` tiles
    (gathered kernel: neighbor ids/colors/degrees; CSR kernel adds the
    packed-gather tile, hence ``tiles=4``) PLUS the FirstFit state the
    kernel allocates per row: ``nwords`` uint32 bitset words and the
    ``(nwords, 32)`` int32 position expansion the min-reduce scans.
    """
    nwords = (W + 1 + 31) // 32
    per_row = tiles * W * 4 + nwords * 4 + nwords * 32 * 4
    by_vmem = max(8, _VMEM_BUDGET // max(per_row, 1))
    return max(8, (min(by_vmem, 256, w) // 8) * 8)


@partial(jax.jit, static_argnames=("heuristic", "block_n", "interpret"))
def _run(me, nid, nc, nd, *, heuristic, block_n, interpret):
    return superstep_pallas_call(
        me.shape[0], nid.shape[1], block_n, heuristic, interpret
    )(me, nid, nc, nd)


def superstep_tpu(
    ids: jax.Array,
    neigh_ids: jax.Array,
    my_colors: jax.Array,
    neigh_colors: jax.Array,
    my_deg: jax.Array,
    neigh_deg: jax.Array,
    heuristic: str = "degree",
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused conflict-check + FirstFit over one ``(w, W)`` neighbor tile.

    Returns ``(new_colors, need)``: the post-step color per worklist row and
    a bool flag marking rows that were recolored (and so need re-verification
    next super-step).  Sentinel masking is the caller's job — the kernel has
    no notion of the vertex count.
    """
    w, W = neigh_ids.shape
    if w == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    block_n = block_n or _pick_block_n(w, W)
    me = jnp.stack(
        [ids.astype(jnp.int32), my_colors.astype(jnp.int32),
         my_deg.astype(jnp.int32)],
        axis=1,
    )
    newc, need = _run(
        me,
        neigh_ids.astype(jnp.int32),
        neigh_colors.astype(jnp.int32),
        neigh_deg.astype(jnp.int32),
        heuristic=heuristic,
        block_n=block_n,
        interpret=interpret,
    )
    return newc, need.astype(bool)
