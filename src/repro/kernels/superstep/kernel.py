"""Pallas TPU kernel: the fused super-step (DESIGN.md §12).

One grid step runs BOTH phases of the rotated SGR super-step for ``block_n``
worklist vertices over a single resident neighbor tile:

* **ConflictResolve** — does my current speculative color survive against my
  neighbors (paper Alg. 5 loser rule / §3.2 degree heuristic)?  A lane-wise
  compare + any-reduce over the tile.
* **FirstFit** — if it does not (or I am uncolored), the smallest permissible
  color from the same tile, via the §3.2 bitset: forbidden colors packed into
  uint32 words that live in VREGs for the whole kernel, find-first-set
  computed structurally (bit-iota + min-reduce, no ``__ffs`` on TPU).

The classic engine ran these as two kernels with two HBM round trips of the
``(w, W)`` neighbor tiles; here the tiles stream HBM->VMEM once and both
phases consume the same registers — the kernel-level half of the "one gather
per iteration" contract (`core/coloring.py` provides the gather-level half).

Layout matches the conflict kernel: per-row scalars packed as a
``(block_n, 3)`` int32 tile ``[id, color, degree]``; neighbor ids/colors/
degrees as three ``(block_n, W)`` tiles.  Outputs are the new color per row
and an int32 "needs re-verification" flag (1 where the row was recolored).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["superstep_kernel", "superstep_pallas_call",
           "COL_ID", "COL_COLOR", "COL_DEG"]

COL_ID, COL_COLOR, COL_DEG = 0, 1, 2


def superstep_kernel(me_ref, nid_ref, nc_ref, nd_ref, newc_ref, need_ref, *,
                     nwords: int, heuristic: str):
    me = me_ref[...]                # (bn, 3): [id, color, degree]
    nid = nid_ref[...]              # (bn, W) neighbor ids (sentinel in pads)
    nc = nc_ref[...]                # (bn, W) neighbor colors (0 in pads)
    nd = nd_ref[...]                # (bn, W) neighbor degrees (0 in pads)
    block_n, W = nc.shape

    my_id = me[:, COL_ID][:, None]
    my_c = me[:, COL_COLOR][:, None]
    my_d = me[:, COL_DEG][:, None]

    # ---- phase 1: conflict detection on the current speculative colors ----
    same = (nc == my_c) & (my_c > 0)
    if heuristic == "id":
        lose_lane = same & (my_id < nid)
    else:  # degree: larger degree keeps; tie -> smaller id keeps
        lose_lane = same & ((nd > my_d) | ((nd == my_d) & (nid < my_id)))
    need = jnp.any(lose_lane, axis=1) | (me[:, COL_COLOR] == 0)

    # ---- phase 2: bitset FirstFit from the SAME tile (words stay in VREGs) --
    # same-color lanes I beat are provably recoloring too — refit as if they
    # were already cleared (the classic engine's clear-then-refit dynamics)
    nc = jnp.where(same & ~lose_lane, 0, nc)
    idx = nc - 1                      # bit position of each forbidden color
    valid = idx >= 0
    word_of = jnp.where(valid, idx >> 5, -1)
    bit = (jnp.where(valid, idx, 0) & 31).astype(jnp.uint32)
    bits = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))

    word_iota = lax.broadcasted_iota(jnp.int32, (block_n, nwords), 1)

    def accumulate(d, words):
        hit = word_iota == word_of[:, d][:, None]
        return words | jnp.where(hit, bits[:, d][:, None], jnp.uint32(0))

    words = lax.fori_loop(
        0, W, accumulate, jnp.zeros((block_n, nwords), jnp.uint32)
    )

    free = ~words                                              # (bn, nwords)
    bitpos = lax.broadcasted_iota(jnp.uint32, (block_n, nwords, 32), 2)
    is_free = ((free[:, :, None] >> bitpos) & jnp.uint32(1)) == jnp.uint32(1)
    pos = (
        lax.broadcasted_iota(jnp.int32, (block_n, nwords, 32), 1) * 32
        + bitpos.astype(jnp.int32)
    )
    big = jnp.int32(W + 2)
    pos = jnp.where(is_free & (pos <= W), pos, big)
    ff = jnp.min(pos, axis=(1, 2)).astype(jnp.int32) + 1

    newc_ref[...] = jnp.where(need, ff, me[:, COL_COLOR]).astype(jnp.int32)
    need_ref[...] = need.astype(jnp.int32)


def superstep_pallas_call(w: int, W: int, block_n: int, heuristic: str,
                          interpret: bool):
    """Build the fused super-step pallas_call for a (w, W) neighbor tile."""
    nwords = (W + 1 + 31) // 32
    grid = (pl.cdiv(w, block_n),)
    row_spec = pl.BlockSpec((block_n, W), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(superstep_kernel, nwords=nwords, heuristic=heuristic),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            row_spec,
            row_spec,
            row_spec,
        ],
        out_specs=(
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.int32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=interpret,
    )
