"""Fused FirstFit+Conflict super-step Pallas kernel (DESIGN.md §12)."""
from repro.kernels.superstep.ops import superstep_tpu
from repro.kernels.superstep.ref import superstep_ref

__all__ = ["superstep_tpu", "superstep_ref"]
