"""Pure-jnp oracle for the fused super-step kernel.

Deliberately independent of both the kernel and the production engine:
FirstFit candidacy is checked by direct quadratic comparison (as in
``kernels/firstfit/ref.py``) and the loser rule is written out lane-wise,
the most obviously-correct formulations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["superstep_ref"]


def superstep_ref(
    ids: jax.Array,
    neigh_ids: jax.Array,
    my_colors: jax.Array,
    neigh_colors: jax.Array,
    my_deg: jax.Array,
    neigh_deg: jax.Array,
    heuristic: str = "degree",
) -> tuple[jax.Array, jax.Array]:
    """(new_colors, need) for one rotated super-step over a padded tile."""
    w, W = neigh_colors.shape
    my_c = my_colors[:, None]
    same = (neigh_colors == my_c) & (my_c > 0)
    if heuristic == "id":
        lose_lane = same & (ids[:, None] < neigh_ids)
    else:
        dv = my_deg[:, None]
        lose_lane = same & (
            (neigh_deg > dv) | ((neigh_deg == dv) & (neigh_ids < ids[:, None]))
        )
    need = jnp.any(lose_lane, axis=1) | (my_colors == 0)

    # neighbors I provably beat refit too — their colors are not forbidden
    ff_colors = jnp.where(same & ~lose_lane, 0, neigh_colors)
    cand = jnp.arange(1, W + 2, dtype=jnp.int32)                 # (C,)
    forbidden = (ff_colors[:, None, :] == cand[None, :, None]).any(-1)
    ff = (jnp.argmax(~forbidden, axis=1) + 1).astype(jnp.int32)

    new_c = jnp.where(need, ff, my_colors.astype(jnp.int32))
    return new_c, need
