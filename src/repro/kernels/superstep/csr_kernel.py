"""Pallas TPU kernel: the CSR-resident fused super-step (DESIGN.md §18).

The gathered kernel (``kernel.py``) consumes dense ``(w, W)`` neighbor
tiles that ``core/coloring.py`` materializes in HBM first — every gather
cell is written by the host-side gather AND read back by the kernel, twice
the traffic the paper's memory-bound analysis (§3) budgets for.  This
variant eliminates the intermediate tile entirely: it takes the
``DeviceCSR`` arrays (row offsets ``R``, column ids ``C``) plus a packed
``color | degree << 16`` table and gathers each worklist row's neighbors
into VMEM *itself*, then runs ConflictResolve + bitset FirstFit from the
same registers and writes only ``(new_color, need)`` back.

Layout (``pltpu.PrefetchScalarGridSpec``):

* scalar prefetch — the compacted worklist ids ``wl (w,)`` and their
  pre-gathered row offsets ``starts (w,) = R[clip(wl, 0, n-1)]``; both are
  resident in SMEM before the grid runs, so the kernel can issue its
  per-row dynamic slices of ``C`` without a host round trip.
* ANY-space operands — ``C`` (``col_padded``, sentinel slack at the end so
  a full-width slice at the last row never reads out of bounds) and the
  ``(n + 1,)`` packed word table (slot ``n`` holds 0, keeping sentinel
  lanes inert exactly like the extended color array).
* per-block VMEM scratch — one ``(block_n, W)`` neighbor-id tile, loaded
  row-by-row with ``pl.ds`` and consumed vectorized.

Bit-identity: lanes past a row's degree are masked to the sentinel ``n``
(whose packed word is 0 → color 0, degree 0), which reproduces the exact
inputs ``DeviceCSR.gather_rows`` + the packed pure-JAX gather would feed
the gathered kernel; the conflict + FirstFit arithmetic below is copied
verbatim from ``superstep_kernel``.  ``interpret=True`` keeps the kernel
testable on CPU CI.

The grid=1 sequential variant at the bottom fuses the §12 serial tail
on-device: clear the worklist's colors, then FirstFit each vertex in the
given (``order_tail``) order against the LIVE aliased color array — the
canonical sequential greedy ``serial_tail_step`` computes, as one kernel
instead of a ``fori_loop`` of per-vertex gather/scatter dispatches.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.superstep.ops import _pick_block_n

__all__ = [
    "superstep_csr_kernel",
    "superstep_csr_pallas_call",
    "superstep_csr_tpu",
    "serial_tail_csr_kernel",
    "serial_tail_csr_pallas_call",
    "serial_tail_csr_tpu",
]


def superstep_csr_kernel(wl_ref, starts_ref, col_ref, packed_ref,
                         newc_ref, need_ref, nid_s, *,
                         block_n: int, W: int, nwords: int, n: int,
                         heuristic: str):
    i = pl.program_id(0)
    base = i * block_n

    # ---- the fused gather: one (block_n, W) neighbor-id tile into VMEM ----
    def load_row(r, _):
        nid_s[r, :] = col_ref[pl.ds(starts_ref[base + r], W)]
        return 0

    lax.fori_loop(0, block_n, load_row, 0)

    my_id = wl_ref[pl.ds(base, block_n)]          # (bn,) worklist ids (SMEM)
    mypk = packed_ref[my_id]                      # sentinel n -> word 0
    my_c = mypk & jnp.int32(0xFFFF)
    my_d = mypk >> 16
    lane = lax.broadcasted_iota(jnp.int32, (block_n, W), 1)
    # lanes past my degree read the NEXT row's entries in C — mask them to
    # the sentinel n, whose packed word is 0 (color 0 / degree 0, inert)
    nid = jnp.where(lane < my_d[:, None], nid_s[...], jnp.int32(n))
    npk = packed_ref[nid]                         # (bn, W) packed gather
    nc = npk & jnp.int32(0xFFFF)
    nd = npk >> 16

    # ---- identical arithmetic to superstep_kernel (bit-identity bar) ------
    my_id2 = my_id[:, None]
    my_c2 = my_c[:, None]
    same = (nc == my_c2) & (my_c2 > 0)
    if heuristic == "id":
        lose_lane = same & (my_id2 < nid)
    else:  # degree: larger degree keeps; tie -> smaller id keeps
        lose_lane = same & ((nd > my_d[:, None])
                            | ((nd == my_d[:, None]) & (nid < my_id2)))
    need = jnp.any(lose_lane, axis=1) | (my_c == 0)

    nc = jnp.where(same & ~lose_lane, 0, nc)
    idx = nc - 1
    valid = idx >= 0
    word_of = jnp.where(valid, idx >> 5, -1)
    bit = (jnp.where(valid, idx, 0) & 31).astype(jnp.uint32)
    bits = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))

    word_iota = lax.broadcasted_iota(jnp.int32, (block_n, nwords), 1)

    def accumulate(d, words):
        hit = word_iota == word_of[:, d][:, None]
        return words | jnp.where(hit, bits[:, d][:, None], jnp.uint32(0))

    words = lax.fori_loop(
        0, W, accumulate, jnp.zeros((block_n, nwords), jnp.uint32)
    )

    free = ~words
    bitpos = lax.broadcasted_iota(jnp.uint32, (block_n, nwords, 32), 2)
    is_free = ((free[:, :, None] >> bitpos) & jnp.uint32(1)) == jnp.uint32(1)
    pos = (
        lax.broadcasted_iota(jnp.int32, (block_n, nwords, 32), 1) * 32
        + bitpos.astype(jnp.int32)
    )
    big = jnp.int32(W + 2)
    pos = jnp.where(is_free & (pos <= W), pos, big)
    ff = jnp.min(pos, axis=(1, 2)).astype(jnp.int32) + 1

    newc_ref[...] = jnp.where(need, ff, my_c).astype(jnp.int32)
    need_ref[...] = need.astype(jnp.int32)


def superstep_csr_pallas_call(w: int, W: int, block_n: int, n: int,
                              heuristic: str, interpret: bool):
    """Build the CSR-resident super-step call for a width-``W`` class.

    ``w`` must be a multiple of ``block_n`` (the wrapper pads the worklist
    with sentinels) — scalar-prefetch reads have no out-of-bounds block
    padding, unlike dense BlockSpec operands.
    """
    nwords = (W + 1 + 31) // 32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # wl, starts
        grid=(w // block_n,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # col_padded
            pl.BlockSpec(memory_space=pltpu.ANY),  # packed color|deg table
        ],
        out_specs=(
            pl.BlockSpec((block_n,), lambda i, *_: (i,)),
            pl.BlockSpec((block_n,), lambda i, *_: (i,)),
        ),
        scratch_shapes=[pltpu.VMEM((block_n, W), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(
            superstep_csr_kernel, block_n=block_n, W=W, nwords=nwords,
            n=n, heuristic=heuristic,
        ),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((w,), jnp.int32),
            jax.ShapeDtypeStruct((w,), jnp.int32),
        ),
        interpret=interpret,
    )


@partial(jax.jit,
         static_argnames=("W", "heuristic", "n", "block_n", "interpret"))
def _run_csr(row_starts, col_padded, packed, wl, *, W, heuristic, n,
             block_n, interpret):
    w = wl.shape[0]
    pad = (-w) % block_n
    if pad:
        wl = jnp.concatenate([wl, jnp.full((pad,), n, wl.dtype)])
    starts = row_starts[jnp.clip(wl, 0, max(n - 1, 0))]
    newc, need = superstep_csr_pallas_call(
        w + pad, W, block_n, n, heuristic, interpret
    )(wl, starts, col_padded, packed)
    return newc[:w], need[:w]


def superstep_csr_tpu(
    row_starts: jax.Array,
    col_padded: jax.Array,
    packed: jax.Array,
    wl: jax.Array,
    W: int,
    heuristic: str = "degree",
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused gather + conflict-check + FirstFit straight from CSR storage.

    ``row_starts``/``col_padded`` are the ``DeviceCSR`` arrays; ``packed``
    is the ``(n + 1,)`` ``color | degree << 16`` table (slot ``n`` = 0) and
    ``W`` the degree-class tile width.  Returns ``(new_colors, need)`` for
    the worklist ``wl`` — sentinel masking (``wl < n``) is the caller's
    job, matching ``superstep_tpu``.  Requires the packed-word capacity
    predicate (``repro.ingest.packed_gather_ok``); callers fall back to
    the gathered kernel when it fails.
    """
    w = wl.shape[0]
    n = row_starts.shape[0] - 1
    if w == 0 or n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool)
    interpret = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    block_n = block_n or _pick_block_n(w, W, tiles=4)
    newc, need = _run_csr(
        row_starts, col_padded, packed.astype(jnp.int32),
        wl.astype(jnp.int32),
        W=int(W), heuristic=heuristic, n=n, block_n=block_n,
        interpret=interpret,
    )
    return newc, need.astype(bool)


# --------------------------------------------------------------------------
# the §12 serial tail as one grid=1 sequential kernel (on-device fusion)
# --------------------------------------------------------------------------

def serial_tail_csr_kernel(wl_ref, starts_ref, degs_ref, col_ref,
                           colors_in_ref, colors_ref, *,
                           T: int, W: int, n: int):
    """Clear-then-sequential-FirstFit over the LIVE aliased color array.

    Exactly ``serial_tail_step``'s schedule: worklist colors cleared up
    front (sentinel entries write the always-zero slot ``n``), then each
    vertex in worklist order refits to the smallest color its neighbors'
    *current* colors permit — later vertices observe earlier writes through
    the aliased output ref, so the pass is conflict-free by construction.
    The smallest-free-color scan is candidate-based (colors 1..W+1 vs the
    ≤W forbidden neighbor colors); every FirstFit ``kind`` computes that
    same value, so the kernel is bit-identical to all of them.
    """
    del colors_in_ref  # aliased to colors_ref; the live view is the output

    def clear(i, _):
        colors_ref[wl_ref[i]] = 0
        return 0

    lax.fori_loop(0, T, clear, 0)

    cand = lax.broadcasted_iota(jnp.int32, (W + 1, 1), 0)[:, 0] + 1

    def fit(i, _):
        v = wl_ref[i]
        raw = col_ref[pl.ds(starts_ref[i], W)]
        lane = lax.broadcasted_iota(jnp.int32, (W, 1), 0)[:, 0]
        nbr = jnp.where(lane < degs_ref[i], raw, jnp.int32(n))
        ncol = colors_ref[nbr]                   # LIVE state, earlier writes
        forbidden = jnp.any(cand[:, None] == ncol[None, :], axis=1)
        ff = jnp.min(jnp.where(forbidden, jnp.int32(W + 2), cand))
        colors_ref[v] = jnp.where(v < n, ff, 0).astype(jnp.int32)
        return 0

    lax.fori_loop(0, T, fit, 0)


def serial_tail_csr_pallas_call(T: int, W: int, n: int, interpret: bool):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # wl, starts, degs
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # col_padded
            pl.BlockSpec(memory_space=pltpu.ANY),  # colors_ext (aliased)
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
    )
    return pl.pallas_call(
        functools.partial(serial_tail_csr_kernel, T=T, W=W, n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n + 1,), jnp.int32),
        # operand index counts the 3 scalar-prefetch args: colors_ext is #4
        input_output_aliases={4: 0},
        interpret=interpret,
    )


@partial(jax.jit, static_argnames=("W", "n", "interpret"))
def _run_tail(row_starts, col_padded, deg_ext, colors_ext, wl, *,
              W, n, interpret):
    starts = row_starts[jnp.clip(wl, 0, max(n - 1, 0))]
    degs = deg_ext[jnp.clip(wl, 0, n)]
    return serial_tail_csr_pallas_call(
        wl.shape[0], W, n, interpret
    )(wl, starts, degs, col_padded, colors_ext)


def serial_tail_csr_tpu(
    row_starts: jax.Array,
    col_padded: jax.Array,
    deg_ext: jax.Array,
    colors_ext: jax.Array,
    wl: jax.Array,
    W: int,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """``serial_tail_step`` fused into one device kernel over CSR arrays.

    ``wl`` arrives pre-ordered (``order_tail``); ``W`` is the full gather
    width (>= every worklist degree).  Returns the updated ``colors_ext``.
    """
    n = row_starts.shape[0] - 1
    if wl.shape[0] == 0 or n == 0:
        return colors_ext
    interpret = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    return _run_tail(
        row_starts, col_padded, deg_ext, colors_ext.astype(jnp.int32),
        wl.astype(jnp.int32), W=int(W), n=n, interpret=interpret,
    )
