"""Pallas TPU kernel for the distance-2 bitset FirstFit (DESIGN.md §11)."""
from repro.kernels.d2.ops import d2_firstfit_bitset_tpu
from repro.kernels.d2.ref import d2_firstfit_ref

__all__ = ["d2_firstfit_bitset_tpu", "d2_firstfit_ref"]
