"""Pure-jnp oracle for the distance-2 bitset FirstFit kernel.

Deliberately independent of the kernel and of ``core.firstfit``: candidate
membership is checked by direct (quadratic) comparison over the union of
both tiles, the most obviously-correct formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["d2_firstfit_ref"]


def d2_firstfit_ref(nc1: jax.Array, nc2: jax.Array) -> jax.Array:
    """Smallest color in [1, W1+W2+1] absent from both tiles, per row."""
    nc = jnp.concatenate([nc1, nc2], axis=1)
    w, W = nc.shape
    cand = jnp.arange(1, W + 2, dtype=nc.dtype)                 # (C,)
    forbidden = (nc[:, None, :] == cand[None, :, None]).any(-1)
    return (jnp.argmax(~forbidden, axis=1) + 1).astype(jnp.int32)
