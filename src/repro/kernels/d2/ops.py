"""jit'd wrapper for the distance-2 bitset FirstFit Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.d2.kernel import d2_firstfit_pallas_call

__all__ = ["d2_firstfit_bitset_tpu"]

_VMEM_BUDGET = 2 * 1024 * 1024  # bytes for the two neighbor-color tiles


def _pick_block_n(w: int, W1: int, W2: int) -> int:
    by_vmem = max(8, _VMEM_BUDGET // max((W1 + W2) * 4, 1))
    # round down to a multiple of 8 (sublane), cap at the row count
    return max(8, (min(by_vmem, 256, w) // 8) * 8)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def _run(nc1, nc2, *, block_n: int, interpret: bool):
    return d2_firstfit_pallas_call(
        nc1.shape[0], nc1.shape[1], nc2.shape[1], block_n, interpret
    )(nc1, nc2)


def d2_firstfit_bitset_tpu(
    nc1: jax.Array,
    nc2: jax.Array,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """FirstFit over hop-1 ``(w, W1)`` + hop-2 ``(w, W2)`` color tiles.

    Returns colors ``(w,)`` in ``[1, W1+W2+1]``.  ``interpret`` defaults to
    True off-TPU (CPU validation mode) and False on real TPU backends.
    """
    w = nc1.shape[0]
    if w == 0:
        return jnp.zeros((0,), jnp.int32)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    block_n = block_n or _pick_block_n(w, nc1.shape[1], nc2.shape[1])
    return _run(
        nc1.astype(jnp.int32), nc2.astype(jnp.int32),
        block_n=block_n, interpret=interpret,
    )
