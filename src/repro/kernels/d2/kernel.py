"""Pallas TPU kernel: bitset FirstFit over a TWO-LEVEL neighborhood (§11).

The distance-2 on-the-fly path gathers two color tiles per worklist vertex
— ``nc1`` (block_n, W1), the direct neighbors, and ``nc2`` (block_n, W2),
the two-hop neighbors — and the forbidden set is their UNION.  Building the
packed uint32 bit words from both tiles inside one kernel keeps the
combined forbidden set register-resident instead of materializing the
``(w, W1 + W2)`` concatenation in HBM, which is the whole point at two-hop
widths (W2 grows like W²).

Find-first-set is computed structurally exactly as in
``kernels/firstfit/kernel.py``: expand each word against a 32-lane bit
iota, mask positions beyond the greedy bound W1+W2+1, min-reduce — shifts,
compares and a min only, the friendliest Mosaic lowering (no gather, no
popcount).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["d2_firstfit_kernel", "d2_firstfit_pallas_call"]


def _accumulate_tile(nc, words, word_iota):
    """OR the forbidden bits of one neighbor-color tile into ``words``."""
    idx = nc - 1                      # bit position of each forbidden color
    valid = idx >= 0
    word_of = jnp.where(valid, idx >> 5, -1)
    bit = (jnp.where(valid, idx, 0) & 31).astype(jnp.uint32)
    bits = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))

    def body(d, words):
        hit = word_iota == word_of[:, d][:, None]
        return words | jnp.where(hit, bits[:, d][:, None], jnp.uint32(0))

    return lax.fori_loop(0, nc.shape[1], body, words)


def d2_firstfit_kernel(nc1_ref, nc2_ref, out_ref, *, nwords: int):
    nc1 = nc1_ref[...]  # (block_n, W1) int32 hop-1 colors; 0 = none
    nc2 = nc2_ref[...]  # (block_n, W2) int32 hop-2 colors; 0 = none
    block_n = nc1.shape[0]
    bound = nc1.shape[1] + nc2.shape[1]  # colors 1..bound can be forbidden

    word_iota = lax.broadcasted_iota(jnp.int32, (block_n, nwords), 1)
    words = jnp.zeros((block_n, nwords), jnp.uint32)
    words = _accumulate_tile(nc1, words, word_iota)
    words = _accumulate_tile(nc2, words, word_iota)

    # find-first-set: min over (word, bit) of free positions <= bound
    free = ~words                                              # (bn, nwords)
    bitpos = lax.broadcasted_iota(jnp.uint32, (block_n, nwords, 32), 2)
    is_free = ((free[:, :, None] >> bitpos) & jnp.uint32(1)) == jnp.uint32(1)
    pos = (
        lax.broadcasted_iota(jnp.int32, (block_n, nwords, 32), 1) * 32
        + bitpos.astype(jnp.int32)
    )
    big = jnp.int32(bound + 2)
    pos = jnp.where(is_free & (pos <= bound), pos, big)
    out_ref[...] = jnp.min(pos, axis=(1, 2)).astype(jnp.int32) + 1


def d2_firstfit_pallas_call(w: int, W1: int, W2: int, block_n: int,
                            interpret: bool):
    """Build the pallas_call for (w, W1) + (w, W2) neighbor-color tiles."""
    nwords = (W1 + W2 + 1 + 31) // 32
    grid = (pl.cdiv(w, block_n),)
    return pl.pallas_call(
        functools.partial(d2_firstfit_kernel, nwords=nwords),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, W1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, W2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=interpret,
    )
