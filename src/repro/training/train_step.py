"""Train state + jit-able train step (donated, sharding-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "make_train_step"]

# TrainState is a plain dict pytree: {"params", "opt": {"m","v"}, "step"}
TrainState = dict


def init_train_state(model, rng) -> TrainState:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params), "step": jnp.int32(0)}


def make_train_step(model, opt_cfg: AdamWConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    Pure function of (state, batch): jit it with donate_argnums=(0,) and the
    in/out shardings of your mesh (see launch/dryrun.py and launch/train.py).
    """

    def train_step(state: TrainState, batch):
        def loss_fn(params):
            return model.loss(params, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        new_params, new_opt, stats = adamw_update(
            state["params"], grads, state["opt"], state["step"], opt_cfg
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = {**metrics, **stats, "loss": loss}
        return new_state, metrics

    return train_step
