"""Deterministic synthetic data pipeline.

Stateless in the step index: ``batch(step)`` is a pure function of
(seed, step), which is what makes checkpoint/restart and elastic re-sharding
exact — a restored run consumes the identical stream with no cursor files.

The token stream has learnable structure (a noisy affine-recurrence language)
so smoke-training shows a decreasing loss: token_{t+1} = (a*token_t + b) mod V
with probability 1-noise, else uniform.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticConfig", "SyntheticData"]


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab: int
    seq_len: int
    batch_size: int
    family: str = "dense"        # matches ModelConfig.family
    d_frontend: int = 0
    n_patches: int = 0
    noise: float = 0.1
    seed: int = 0


class SyntheticData:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        self.a = 3
        self.b = 7

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab
        if cfg.family == "encoder":
            frames = rng.standard_normal((B, S, cfg.d_frontend), dtype=np.float32)
            # frame labels = a quantization of the first frontend channel
            labels = ((frames[..., 0] - frames[..., 0].min()) * 7).astype(np.int64)
            return {"frames": frames, "labels": (labels % V).astype(np.int32)}
        start = rng.integers(0, V, size=(B, 1))
        toks = np.zeros((B, S), dtype=np.int64)
        toks[:, :1] = start
        for t in range(1, S):
            nxt = (self.a * toks[:, t - 1] + self.b) % V
            flip = rng.random(B) < cfg.noise
            toks[:, t] = np.where(flip, rng.integers(0, V, size=B), nxt)
        batch = {"tokens": toks.astype(np.int32), "labels": toks.astype(np.int32)}
        if cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (B, cfg.n_patches, cfg.d_frontend), dtype=np.float32
            )
        return batch

    @classmethod
    def for_model(cls, mcfg, batch_size: int, seq_len: int, seed: int = 0):
        s_text = seq_len - (mcfg.n_patches if mcfg.family == "vlm" else 0)
        return cls(
            SyntheticConfig(
                vocab=mcfg.vocab,
                seq_len=s_text,
                batch_size=batch_size,
                family=mcfg.family,
                d_frontend=mcfg.d_frontend,
                n_patches=mcfg.n_patches,
                seed=seed,
            )
        )
