"""Fault-tolerant checkpointing: atomic, last-k retention, mesh-elastic.

Format: one ``.npz`` (all leaves, keyed by flattened path) + ``meta.json``
per step directory, written to ``<dir>/tmp.step_N`` and atomically renamed to
``<dir>/step_N`` — a half-written checkpoint is never visible.  Restore is
sharding-aware: pass a pytree of ``NamedSharding`` (for *any* mesh, not just
the one that saved) and each leaf is ``device_put`` directly to its shards —
this is the elastic-scaling path (restore a 256-chip checkpoint onto 512
chips or onto 1 CPU).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic visibility
    # retention
    for s in list_steps(ckpt_dir)[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            steps.append(int(name.split("_", 1)[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays/specs).

    ``shardings``: optional matching pytree of ``jax.sharding.Sharding`` —
    each leaf goes straight to its (possibly different-mesh) shards.
    """
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None
        else [None] * len(leaves_like)
    )
    out = []
    for (pathspec, leaf), shard in zip(leaves_like, shard_leaves):
        key = _SEP.join(_path_str(p) for p in pathspec)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard is not None:
            arr = jax.device_put(arr, shard)
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )
