"""Fault-injection harness for the §17 robustness layer.

Every guarantee in DESIGN.md §17 is only as good as the test that breaks
it on purpose.  This module holds the breakage: small, deterministic
injectors that corrupt exactly one invariant each, so ``tests/test_faultlab.py``
can assert that (a) the matching detector fires and (b) the matching
recovery path restores a valid coloring.

Injectors
---------

``corrupt_colors``
    Context manager that patches the ``repro.api`` algorithm registry so
    every run's returned colors are corrupted *after* the engine finishes
    (a deterministic subset of vertices copies a neighbor's color —
    guaranteed monochromatic edges).  Models a device-memory fault or a
    bad kernel landing between the super-step and the commit.  Detector:
    ``is_valid_coloring`` / the ``ensure_valid=True`` ladder.

``poison_halo_words``
    Pure function that flips a deterministic subset of packed
    ``id << 16 | color`` halo words into garbage (negative words,
    out-of-range ids, corrupt colors).  Models a torn halo exchange.
    Detector: ``repro.ingest.check_halo_words``.

``truncate_journal``
    Tears the tail of a durable session's write-ahead journal — either
    mid-record (a crash half-way through a ``write``) or by appending a
    record whose CRC cannot match.  Detector: ``SessionJournal.records``
    stops at the tear and ``ColoringSession.restore`` reports
    ``recovery["truncated"] = True`` while still restoring the last
    consistent state.

``starved_opts``
    The forced-non-convergence scenario: engine options (one iteration,
    no serial tail) under which the speculative engines cannot converge
    on any graph with conflicts.  Recovery: the guarantee ladder
    (``ensure_valid=True`` / ``on_fail="ladder"``).

``ADVERSARIAL_GRAPHS``
    The shared corpus of malformed CSR inputs (asymmetric, self-loops,
    duplicates, unsorted rows, negative / out-of-range indices, broken
    indptr, empty) used by both the ingest tests and the differential
    engine × backend matrix.  Each entry maps a name to raw
    ``(row_offsets, col_indices)`` arrays — *raw*, because building a
    ``CSRGraph`` through the normal constructors would fix them.
"""
from __future__ import annotations

from contextlib import contextmanager

import numpy as np

__all__ = [
    "corrupt_colors",
    "poison_halo_words",
    "truncate_journal",
    "starved_opts",
    "ADVERSARIAL_GRAPHS",
]


# --------------------------------------------------------------------------
# scenario 1: colors corrupted between engine and commit
# --------------------------------------------------------------------------

def _corrupt(g, colors: np.ndarray, fraction: float, seed: int) -> np.ndarray:
    """Copy a neighbor's color onto a deterministic vertex subset.

    Touched vertices with at least one neighbor are guaranteed to sit on a
    monochromatic edge afterwards, so the corruption is always *detectable*
    (never a silently-still-valid perturbation).
    """
    out = np.asarray(colors, dtype=np.int32).copy()
    n = g.n
    if n == 0:
        return out
    rng = np.random.default_rng(seed)
    k = max(1, int(fraction * n))
    victims = rng.choice(n, size=min(k, n), replace=False)
    R, C = g.row_offsets, g.col_indices
    for v in victims:
        lo, hi = R[v], R[v + 1]
        if hi > lo:
            out[v] = out[C[lo]]  # first neighbor's color: conflict by design
    return out


@contextmanager
def corrupt_colors(fraction: float = 0.05, seed: int = 0):
    """Patch the algorithm registry: every result's colors come back corrupt.

    The engine runs untouched; corruption lands on the *result*, modeling a
    fault between the device computation and the host commit.  Restores the
    registry on exit, even on error.
    """
    from repro import api

    api._ensure_registered()
    saved = dict(api._REGISTRY)

    def wrap(fn):
        def corrupted(g, **opts):
            result = fn(g, **opts)
            result.colors = _corrupt(g, result.colors, fraction, seed)
            return result

        return corrupted

    try:
        for name, fn in saved.items():
            api._REGISTRY[name] = wrap(fn)
        yield
    finally:
        api._REGISTRY.clear()
        api._REGISTRY.update(saved)


# --------------------------------------------------------------------------
# scenario 2: poisoned packed halo words
# --------------------------------------------------------------------------

def poison_halo_words(words: np.ndarray, n: int, *, fraction: float = 0.1,
                      seed: int = 0) -> np.ndarray:
    """Flip a deterministic subset of packed halo words into garbage.

    Three poison flavors, round-robin over the victims: a negative word
    (bit-flipped sign), an out-of-range vertex id (``>= n``), and a color
    field larger than any proper coloring of ``n`` vertices can produce.
    All three are exactly what ``repro.ingest.check_halo_words`` rejects.
    """
    words = np.asarray(words, dtype=np.int32).copy()
    if words.size == 0:
        return words
    rng = np.random.default_rng(seed)
    k = max(1, int(fraction * words.size))
    victims = rng.choice(words.size, size=min(k, words.size), replace=False)
    for i, v in enumerate(victims):
        flavor = i % 3
        if flavor == 0:
            words[v] = np.int32(-1)
        elif flavor == 1:
            words[v] = np.int32(((n + 1 + i) << 16) | 1)
        else:
            words[v] = np.int32((0 << 16) | min(n + 1 + i, 0xFFFF))
    return words


# --------------------------------------------------------------------------
# scenario 3: torn write-ahead journal
# --------------------------------------------------------------------------

def truncate_journal(durable_dir: str, *, mode: str = "tear",
                     records: int = 1) -> int:
    """Damage the tail of a durable session's journal; returns bytes removed.

    ``mode="tear"`` cuts the file mid-way through the final record — the
    classic crash-during-write artifact (the last line fails to parse).
    ``mode="drop"`` removes the last ``records`` complete records — a crash
    after the engine ran but before the journal flush reached the disk.
    ``mode="garbage"`` appends a record-shaped line whose CRC is wrong — a
    bit-rotted tail.  All three must stop replay at the last good record.
    """
    import os

    from repro.dynamic.journal import JOURNAL_NAME

    path = os.path.join(str(durable_dir), JOURNAL_NAME)
    with open(path, "rb") as f:
        data = f.read()
    lines = data.splitlines(keepends=True)
    if mode == "tear":
        if not lines:
            return 0
        cut = max(1, len(lines[-1]) // 2)
        with open(path, "wb") as f:
            f.write(data[: len(data) - cut])
        return cut
    if mode == "drop":
        keep = lines[: max(0, len(lines) - records)]
        with open(path, "wb") as f:
            f.writelines(keep)
        return len(data) - sum(len(line) for line in keep)
    if mode == "garbage":
        junk = (b'{"seq": 999999, "kind": "delta", "payload": {}, '
                b'"crc": 12345}\n')
        with open(path, "ab") as f:
            f.write(junk)
        return -len(junk)
    raise ValueError(f"unknown mode {mode!r}; options: tear, drop, garbage")


# --------------------------------------------------------------------------
# scenario 4: forced non-convergence
# --------------------------------------------------------------------------

def starved_opts() -> dict:
    """Engine options under which speculation cannot finish: one super-step,
    no serial tail.  Any graph with at least one conflict after the first
    speculative round leaves the run unconverged — the deterministic
    trigger for the §17 guarantee ladder."""
    return {"max_iters": 1, "tail_serial": False}


# --------------------------------------------------------------------------
# shared adversarial-input corpus (raw CSR arrays — deliberately malformed)
# --------------------------------------------------------------------------

def _adversarial_graphs() -> dict:
    i64 = np.int64
    i32 = np.int32
    return {
        # vertex 0 lists 1, but 1 does not list 0
        "asymmetric": (np.array([0, 1, 1, 1], i64), np.array([1], i32)),
        # 0-1 edge plus a 0-0 self loop
        "self_loop": (np.array([0, 2, 3], i64), np.array([0, 1, 0], i32)),
        # 0 lists 1 twice
        "dup_edge": (np.array([0, 2, 3], i64), np.array([1, 1, 0], i32)),
        # negative column index
        "negative_index": (np.array([0, 2, 3], i64), np.array([-1, 1, 0], i32)),
        # column index >= n
        "out_of_range": (np.array([0, 2, 3], i64), np.array([1, 5, 0], i32)),
        # row 1's neighbor list is unsorted (valid edges, wrong order)
        "unsorted_row": (np.array([0, 2, 4, 6], i64),
                         np.array([1, 2, 2, 0, 0, 1], i32)),
        # indptr decreases mid-way
        "nonmonotone_indptr": (np.array([0, 2, 1, 3], i64),
                               np.array([1, 2, 0], i32)),
        # empty graph: n = 0, m = 0 — must sail through untouched
        "empty": (np.array([0], i64), np.array([], i32)),
    }


ADVERSARIAL_GRAPHS = _adversarial_graphs()
