"""Validating CSR ingest — the front door for untrusted graphs (DESIGN.md §17).

Every engine in the repo *trusts* its CSR input: sorted rows feed the
sorted-key DeltaCSR overlay, symmetry underpins the §14 cascade-confinement
argument AND the sharded partition plan, and two packed-word fast paths
silently corrupt past hard bit budgets (the ``id << 16 | color`` halo word
needs ids in 15 bits; the ``color | deg << 16`` packed-gather word needs
degrees AND colors in 15/16 bits).  ``sanitize_csr`` checks all of it up
front and either *refuses* with a structured report (``policy="strict"``)
or *repairs* — symmetrize, deduplicate, strip self-loops, drop out-of-range
columns, re-sort rows — recording every action taken so the caller can see
exactly how far the input was from the contract:

    g, report = sanitize_csr(rows, cols, policy="repair")
    color(g, ...)                       # engines now run on contract input

or, wired through the API:

    color(g, validate_input="strict")   # raise IngestError on any defect
    color(g, validate_input="repair")   # fix + record on result.degradations

The capacity helpers (``packed_halo_ok`` / ``packed_gather_ok``) are the
single source of truth for the packed-word bit budgets — the engines'
pack-mode gates (``core/coloring.py``, ``core/distributed.py``,
``core/batch.py``, ``d2/coloring.py``, ``dynamic/session.py``) all route
through them, and ``run_ragged_engine`` / the sharded step builder *refuse*
a packed mode whose operands cannot fit rather than corrupting colors
(tested at exactly 2^15−1 / 2^15 / 2^16 in ``tests/test_ingest.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csr import CSRGraph, csr_from_edges
from repro.errors import ReproError

__all__ = [
    "IngestError",
    "IngestReport",
    "sanitize_csr",
    "packed_halo_ok",
    "packed_gather_ok",
    "pack_halo_words",
    "unpack_halo_words",
    "check_halo_words",
    "PACKED_HALO_MAX_N",
    "PACKED_GATHER_MAX_DEG",
    "INDEX_MAX",
]

# --------------------------------------------------------------------------
# packed-word capacity budgets (the dtype-overflow hazards)
# --------------------------------------------------------------------------

# §13 halo exchange ships one int32 word ``id << 16 | color`` per boundary
# vertex: the id must fit 15 bits (bit 31 is the int32 sign bit) and the
# color 16.  Colors are bounded by n on the sharded engine, so ``n < 2^15``
# covers both operands.
PACKED_HALO_MAX_N = 2**15

# §12 packed gather fuses colors and degrees into one int32 word
# ``color | deg << 16``: the degree must fit 15 bits and the color 16.
# Greedy colors are bounded by ``dmax + 1``, so the engines gate on
# ``dmax < 2^15 - 1`` (the -1 keeps ``dmax + 1`` colors inside the budget);
# the dynamic engine additionally checks live colors (frozen colors can
# exceed the CURRENT degree bound after deletions shrink the graph).
PACKED_GATHER_MAX_DEG = 2**15 - 1

# vertex ids and edge counts live in int32 device arrays everywhere
INDEX_MAX = 2**31 - 1


def packed_halo_ok(n: int) -> bool:
    """True iff the §13 packed halo word can represent every (id, color)."""
    return 0 <= int(n) < PACKED_HALO_MAX_N


def packed_gather_ok(dmax: int, color_bound: int | None = None) -> bool:
    """True iff the §12 packed-gather word can hold (color, degree).

    ``color_bound`` (when known, e.g. frozen colors on the dynamic engine)
    must fit the 16-bit color field with the same safety margin the degree
    field gets; omitted means colors are degree-bounded (static coloring).
    """
    if not 0 <= int(dmax) < PACKED_GATHER_MAX_DEG:
        return False
    if color_bound is not None and not 0 <= int(color_bound) < PACKED_GATHER_MAX_DEG:
        return False
    return True


def pack_halo_words(ids: np.ndarray, colors: np.ndarray) -> np.ndarray:
    """Host mirror of the §13 halo packing: ``id << 16 | color`` (int32)."""
    ids = np.asarray(ids, dtype=np.int64)
    colors = np.asarray(colors, dtype=np.int64)
    return ((ids << 16) | colors).astype(np.int32)


def unpack_halo_words(words: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of ``pack_halo_words``: ``(ids, colors)`` int32 arrays."""
    words = np.asarray(words, dtype=np.int32)
    return (words >> 16).astype(np.int32), (words & 0xFFFF).astype(np.int32)


def check_halo_words(words: np.ndarray, n: int) -> np.ndarray:
    """Indices of halo words that cannot be legitimate ``(id, color)`` pairs.

    A well-formed word unpacks to ``0 <= id <= n`` (``n`` is the inert
    sentinel the exchange pads with) and ``0 <= color <= n`` (greedy colors
    never exceed the vertex count).  Anything else — negative word (sign bit
    set by an id >= 2^15), out-of-range id, impossible color — is poison;
    the §17 fault harness injects exactly these and asserts detection.
    """
    ids, colors = unpack_halo_words(words)
    words = np.asarray(words, dtype=np.int32)
    bad = (words < 0) | (ids > n) | (colors > n) | ((ids == n) & (colors != 0))
    return np.nonzero(bad)[0].astype(np.int64)


# --------------------------------------------------------------------------
# structured report + error
# --------------------------------------------------------------------------

@dataclasses.dataclass
class IngestReport:
    """What ``sanitize_csr`` found (and, under ``repair``, what it did).

    ``issues`` maps defect kind to occurrence count; ``repairs`` is the
    ordered ``(action, count)`` log of fixes applied (empty under
    ``strict`` or on clean input); ``hazards`` records capacity facts that
    are not defects but disable packed fast paths (the engines consult the
    same predicates and fall back to unpacked arithmetic).
    """

    n: int
    m: int
    policy: str
    issues: dict = dataclasses.field(default_factory=dict)
    repairs: tuple = ()
    hazards: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.issues

    def summary(self) -> str:
        if self.ok:
            return f"clean CSR (n={self.n}, m={self.m})"
        parts = ", ".join(f"{k}={v}" for k, v in sorted(self.issues.items()))
        fixed = (" — repaired: "
                 + ", ".join(f"{a}({c})" for a, c in self.repairs)
                 if self.repairs else "")
        return f"CSR defects (n={self.n}, m={self.m}): {parts}{fixed}"

    def degradations(self) -> tuple:
        """The repair log as ``ColoringResult.degradations`` entries."""
        return tuple(
            {"stage": "ingest_repair", "action": action, "count": int(count)}
            for action, count in self.repairs
        )


class IngestError(ReproError, ValueError):
    """Strict-policy refusal; ``.report`` carries the structured findings.

    Based on ``repro.errors.ReproError`` (§19) so the serving layer can map
    it to a structured response; still a ``ValueError`` for pre-§19
    ``except`` clauses.
    """

    def __init__(self, report: IngestReport):
        self.report = report
        super().__init__(report.summary())

    def _fields(self) -> dict:
        return {"issues": dict(self.report.issues),
                "repairs": [[a, int(c)] for a, c in self.report.repairs]}


# --------------------------------------------------------------------------
# sanitize_csr
# --------------------------------------------------------------------------

def _row_ids(row_offsets: np.ndarray, m: int) -> np.ndarray:
    """Source vertex per CSR slot, from (already monotone) offsets."""
    counts = np.diff(row_offsets)
    return np.repeat(np.arange(counts.shape[0], dtype=np.int64), counts)


def sanitize_csr(graph_or_offsets, col_indices=None, *,
                 policy: str = "strict",
                 require_symmetric: bool = True) -> tuple[CSRGraph, IngestReport]:
    """Validate (and optionally repair) a CSR graph for the engines.

    Accepts a ``CSRGraph`` or raw ``(row_offsets, col_indices)`` arrays.
    Detects: non-monotone / mis-anchored indptr, negative and out-of-range
    column indices, self-loops, duplicate edges, unsorted rows, asymmetry
    (unless ``require_symmetric=False`` — bipartite halves are directed),
    and int32 index-capacity overflow (never repairable).

    ``policy="strict"``  — raise ``IngestError`` carrying an
    ``IngestReport`` when any defect is present.
    ``policy="repair"``  — rebuild a clean graph (drop bad columns, strip
    self-loops, symmetrize, deduplicate, sort rows), recording every action
    in ``report.repairs``.  Repairing a clean graph returns it unchanged.

    Packed-word capacity *hazards* (§13 halo / §12 packed gather) are
    recorded on ``report.hazards`` in both policies; they are legal inputs
    — the engines fall back to unpacked arithmetic — not defects.
    """
    if policy not in ("strict", "repair"):
        raise ValueError(f"unknown policy {policy!r}; options: strict, repair")
    if isinstance(graph_or_offsets, CSRGraph):
        if col_indices is not None:
            raise TypeError("pass either a CSRGraph or raw arrays, not both")
        row_offsets = np.asarray(graph_or_offsets.row_offsets)
        cols = np.asarray(graph_or_offsets.col_indices)
        original: CSRGraph | None = graph_or_offsets
    else:
        row_offsets = np.asarray(graph_or_offsets)
        cols = np.asarray(col_indices)
        original = None
    if row_offsets.ndim != 1 or cols.ndim != 1 or row_offsets.shape[0] < 1:
        raise IngestError(IngestReport(
            n=0, m=int(cols.size), policy=policy,
            issues={"indptr_shape": 1}))
    if not (np.issubdtype(row_offsets.dtype, np.integer)
            and np.issubdtype(cols.dtype, np.integer)):
        raise IngestError(IngestReport(
            n=max(int(row_offsets.shape[0]) - 1, 0), m=int(cols.size),
            policy=policy, issues={"non_integer_dtype": 1}))

    n = int(row_offsets.shape[0]) - 1
    m = int(cols.shape[0])
    report = IngestReport(n=n, m=m, policy=policy)
    issues = report.issues

    # -- capacity: int32 index space (unrepairable — refuse in BOTH policies)
    if n > INDEX_MAX or m > INDEX_MAX:
        issues["index_overflow"] = 1
        raise IngestError(report)

    offsets = row_offsets.astype(np.int64)
    # -- indptr structure
    diffs = np.diff(offsets)
    nonmono = int((diffs < 0).sum())
    if nonmono:
        issues["indptr_nonmonotone"] = nonmono
    if offsets[0] != 0:
        issues["indptr_first_nonzero"] = 1
    if offsets[-1] != m:
        issues["indptr_last_mismatch"] = 1
    if (offsets.clip(0, m) != offsets).any():
        issues.setdefault("indptr_out_of_range",
                          int(((offsets < 0) | (offsets > m)).sum()))

    # a usable monotone offset view for per-row analysis (repair view; also
    # used to *localise* defects when the raw indptr is broken)
    fixed_offsets = np.maximum.accumulate(offsets.clip(0, m))
    fixed_offsets[0] = 0
    if fixed_offsets[-1] != m:
        # rows cannot account for every column slot; the trailing slots are
        # treated as belonging to the last row for repair purposes
        fixed_offsets[-1] = m
        fixed_offsets = np.maximum.accumulate(fixed_offsets)

    cols64 = cols.astype(np.int64)
    neg = int((cols64 < 0).sum())
    oob = int((cols64 >= n).sum())
    if neg:
        issues["col_negative"] = neg
    if oob:
        issues["col_out_of_range"] = oob

    src = _row_ids(fixed_offsets, m)
    in_range = (cols64 >= 0) & (cols64 < n)
    vsrc, vdst = src[in_range], cols64[in_range]
    loops = int((vsrc == vdst).sum())
    if loops:
        issues["self_loop"] = loops
    keep = vsrc != vdst
    esrc, edst = vsrc[keep], vdst[keep]
    keys = (esrc << 32) | edst
    sorted_keys = np.sort(keys)
    dups = int((sorted_keys[1:] == sorted_keys[:-1]).sum())
    if dups:
        issues["duplicate_edge"] = dups
    # unsorted rows: a decreasing adjacent pair *within* a row (use the raw
    # columns so the defect is observed exactly as the engines would)
    if m > 1:
        same_row = src[1:] == src[:-1]
        unsorted = int((same_row & (cols64[1:] < cols64[:-1])).sum())
        if unsorted:
            issues["row_unsorted"] = unsorted
    if require_symmetric and keys.size:
        uniq = np.unique(keys)
        rev = ((uniq & 0xFFFFFFFF) << 32) | (uniq >> 32)
        asym = int((~np.isin(rev, uniq)).sum())
        if asym:
            issues["asymmetric"] = asym

    # -- packed-word capacity hazards (facts, not defects)
    deg = np.diff(fixed_offsets)
    dmax = int(deg.max(initial=0))
    report.hazards = {
        "packed_halo_ok": packed_halo_ok(n),
        "packed_gather_ok": packed_gather_ok(dmax),
        "max_degree": dmax,
    }

    if not issues:
        clean = original if original is not None else CSRGraph(
            offsets, cols.astype(np.int32))
        return clean, report

    if policy == "strict":
        raise IngestError(report)

    # -- repair: rebuild from the surviving edge list
    repairs = []
    if ("indptr_nonmonotone" in issues or "indptr_first_nonzero" in issues
            or "indptr_last_mismatch" in issues
            or "indptr_out_of_range" in issues):
        repairs.append(("rebuilt_indptr", nonmono
                        + issues.get("indptr_first_nonzero", 0)
                        + issues.get("indptr_last_mismatch", 0)))
    if neg or oob:
        repairs.append(("dropped_out_of_range", neg + oob))
    if loops:
        repairs.append(("stripped_self_loops", loops))
    if dups:
        repairs.append(("deduplicated", dups))
    if issues.get("row_unsorted"):
        repairs.append(("sorted_rows", issues["row_unsorted"]))
    if issues.get("asymmetric"):
        repairs.append(("symmetrized", issues["asymmetric"]))
    clean = csr_from_edges(n, esrc, edst,
                           symmetrize=require_symmetric, dedup=True)
    report.repairs = tuple(repairs)
    report.hazards["max_degree"] = clean.max_degree
    report.hazards["packed_gather_ok"] = packed_gather_ok(clean.max_degree)
    return clean, report
