"""Schedule an MoE expert-dispatch all-to-all with graph coloring.

The classical collective-scheduling application: transfers (src, dst) of a
full all-to-all conflict when they share an endpoint; edge-coloring the
communication graph with the paper's engine yields conflict-free rounds.
Compares the greedy-colored schedule against the optimal round-robin
(P-1 rounds) and simulates both on a store-and-forward link model.

    PYTHONPATH=src python examples/chromatic_a2a.py --devices 8
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.scheduling import all_to_all_rounds  # noqa: E402


def simulate(rounds, msg_us=10.0):
    """Each round costs one message time (all transfers in parallel)."""
    return len(rounds) * msg_us


def round_robin(P):
    return [[(i, (i + r) % P) for i in range(P)] for r in range(1, P)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    P = args.devices

    colored = all_to_all_rounds(P)
    optimal = round_robin(P)
    print(f"all-to-all among {P} devices: {P*(P-1)} transfers")
    print(f"  greedy-colored schedule: {len(colored)} rounds "
          f"({simulate(colored):.0f}us simulated)")
    print(f"  optimal round-robin:     {len(optimal)} rounds "
          f"({simulate(optimal):.0f}us simulated)")
    print(f"  efficiency: {len(optimal)/len(colored):.2%}")
    for i, rnd in enumerate(colored[:4]):
        print(f"  round {i}: {sorted(rnd)}")
    if len(colored) > 4:
        print(f"  ... {len(colored) - 4} more rounds")


if __name__ == "__main__":
    main()
