"""Color the Table-1 benchmark suite and use the coloring for chromatic
scheduling of a Gauss-Seidel sweep (the paper's HPC use case: same-color rows
update concurrently because they share no edge).

    PYTHONPATH=src python examples/color_suite.py [--scale 0.1]
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import color_data_driven, is_valid_coloring  # noqa: E402
from repro.core.scheduling import phases, schedule_quality  # noqa: E402
from repro.graphs import build_suite  # noqa: E402


def gauss_seidel_chromatic(g, colors, sweeps=2):
    """Jacobi-within-color Gauss-Seidel on the graph Laplacian: every phase
    updates an independent set, so updates within a phase are safe in
    parallel — the concurrency the coloring 'discovered'."""
    n = g.n
    deg = np.maximum(g.degrees, 1).astype(np.float64)
    x = np.zeros(n)
    b = np.ones(n)
    src, dst = g.edges()
    for _ in range(sweeps):
        for phase in phases(colors):
            # x_i <- (b_i + sum_{j in N(i)} x_j) / (deg_i + 1): vectorized
            acc = np.zeros(n)
            np.add.at(acc, src, x[dst])
            x[phase] = (b[phase] + acc[phase]) / (deg[phase] + 1.0)
    return x


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    args = ap.parse_args()

    print(f"{'graph':15s} {'n':>8s} {'m':>9s} {'colors':>6s} {'iters':>5s} "
          f"{'parallelism':>11s} {'time':>8s}")
    for name, g in build_suite(args.scale).items():
        t0 = time.perf_counter()
        r = color_data_driven(g, coarsen_lanes=16384)
        dt = time.perf_counter() - t0
        assert is_valid_coloring(g, r.colors)
        sq = schedule_quality(r.colors)
        print(f"{name:15s} {g.n:8d} {g.m:9d} {r.num_colors:6d} "
              f"{r.iterations:5d} {sq['mean_parallelism']:11.0f} "
              f"{dt*1e3:7.1f}ms")

    # chromatic scheduling demo on one graph
    g = build_suite(args.scale, ["G3_circuit"])["G3_circuit"]
    r = color_data_driven(g)
    x = gauss_seidel_chromatic(g, r.colors)
    print(f"\nchromatic Gauss-Seidel on G3_circuit: {r.num_colors} phases, "
          f"residual mean={x.mean():.4f} (finite={np.isfinite(x).all()})")


if __name__ == "__main__":
    main()
