"""Serving-path demo: a ColoringService micro-batching a request stream.

    PYTHONPATH=src python examples/batch_serve.py [--requests 24] [--batch 16]

The ROADMAP serving scenario, served for real (§19): many users submit
graphs to a shared ``ColoringService``; its worker drains the bounded
request queue in micro-batches, buckets requests by ``(pow2 shape class,
ColorOptions)``, and colors every bucket with ONE jitted device program
(``core/batch.py``).  Every response is validated and bit-identical to
the per-request fused path, steady traffic stays inside the jit cache
(zero misses after the first wave), and a closing flood shows the
backpressure contract: a full queue rejects with a structured
``Overloaded`` instead of growing without bound.

Telemetry comes from the service itself (§16 x §19): ``service.metrics()``
(micro-batch and jit-cache accounting) and ``take_spans()`` (per-request /
per-micro-batch spans from the worker loop), plus one untimed traced
re-run of the first requests for the per-super-step table.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import repro  # noqa: E402
from repro.core import is_valid_coloring  # noqa: E402
from repro.core.batch import color_batch_fused  # noqa: E402
from repro.errors import Overloaded  # noqa: E402
from repro.graphs import serving_mix  # noqa: E402
from repro.launch.coloring_service import ColoringService  # noqa: E402
from repro.obs.report import format_result, format_trace  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--waves", type=int, default=3)
    args = ap.parse_args()

    graphs = serving_mix(args.requests, scale=0.25)
    print(f"{args.requests} coloring requests/wave x {args.waves} waves, "
          f"micro-batch window B={args.batch}\n")

    # ---- reference: warm per-request loop, one device program each ----------
    for g in graphs:
        repro.color(g, "fused")    # warm every shape's jit cache
    t0 = time.perf_counter()
    loop_results = [repro.color(g, "fused") for g in graphs]
    t_loop = time.perf_counter() - t0

    with ColoringService(queue_limit=max(64, 2 * args.requests),
                         max_batch=args.batch, trace=True) as svc:
        # ---- warmup wave: presents every (bucket, pow2 B) key once ----------
        for t in [svc.color(g, wait=False) for g in graphs]:
            t.wait(120)
        warm_misses = svc.metrics()["bucket_jit_misses"]

        # ---- steady waves: async bursts drain as bucketed micro-batches -----
        t0 = time.perf_counter()
        svc_results = []
        for _ in range(args.waves):
            tickets = [svc.color(g, wait=False) for g in graphs]
            svc_results.append([t.wait(120) for t in tickets])
        t_svc = (time.perf_counter() - t0) / args.waves
        m = svc.metrics()
        spans = svc.take_spans()

        # ---- overload: flood far past queue_limit, catch the rejections -----
        accepted, shed = [], 0
        for _ in range(4 * svc.metrics()["queue_limit"]):
            try:
                accepted.append(svc.color(graphs[0], wait=False))
            except Overloaded as e:
                shed += 1
                retry_after = e.retry_after
        for t in accepted:
            t.wait(120)

    ok = all(is_valid_coloring(g, r.colors)
             for wave in svc_results for g, r in zip(graphs, wave))
    identical = all((a.colors == b.colors).all()
                    for wave in svc_results
                    for a, b in zip(loop_results, wave))
    print(f"per-request loop : {t_loop * 1e3:8.1f} ms/wave   "
          f"{len(graphs) / t_loop:7.1f} graphs/sec")
    print(f"service          : {t_svc * 1e3:8.1f} ms/wave   "
          f"{len(graphs) / t_svc:7.1f} graphs/sec "
          f"(admission + batching + validation included)")
    print(f"all proper={ok}  bit-identical to loop={identical}")
    colors = sorted(r.num_colors for r in svc_results[0])
    print(f"colors used per graph: min={colors[0]} max={colors[-1]}")

    # ---- service telemetry (§19) --------------------------------------------
    mb = [e for e in spans if e.name == "serve_microbatch"]
    steady_misses = m["bucket_jit_misses"] - warm_misses
    print(f"\nservice: {m['microbatches']} micro-batches for "
          f"{m['batched_requests']} batched requests across "
          f"{len(m['buckets'])} buckets; jit misses after the warmup "
          f"wave: {steady_misses} (the §19 contract: steady traffic "
          "re-presents warm keys)")
    if mb:
        sizes = sorted(e.meta["B"] for e in mb)
        print(f"micro-batch sizes: min={sizes[0]} max={sizes[-1]} "
              f"({len(mb)} dispatches)")
    print(f"overload flood: {len(accepted)} accepted, {shed} shed with "
          f"structured Overloaded (retry_after~{retry_after:.3f}s); the "
          "queue never grew past its limit")

    # ---- per-super-step table: untimed traced re-run (§16) ------------------
    traced = color_batch_fused(graphs[: min(4, len(graphs))], trace=True)
    print("\nfirst requests, per request:")
    for i, r in enumerate(traced):
        print("  " + format_result(f"request[{i}]", r))
    print("\nrequest[0], per super-step:")
    print(format_trace(traced[0].trace))


if __name__ == "__main__":
    main()
