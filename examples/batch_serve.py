"""Serving-path demo: color a stream of graphs in batches via the unified API.

    PYTHONPATH=src python examples/batch_serve.py [--requests 24] [--batch 8]

Simulates the ROADMAP serving scenario: many users each submit a graph; the
server groups requests into batches of B and colors every batch with ONE
jitted device program (``repro.color_batch`` -> ``core/batch.py``), then
compares throughput against the naive per-request loop.  Every response is
validated and bit-identical to what the per-request fused path would return.

Per-request summaries and the closing per-super-step table come from
``repro.obs`` (§16): one untimed traced re-run of the first batch feeds
``format_result`` / ``format_trace``, so the demo shows the same telemetry
the benchmarks export without perturbing the timed comparison.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import repro  # noqa: E402
from repro.core import is_valid_coloring  # noqa: E402
from repro.core.batch import color_batch_fused  # noqa: E402
from repro.graphs import serving_mix  # noqa: E402
from repro.obs.report import format_result, format_trace  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    graphs = serving_mix(args.requests, scale=0.25)
    print(f"{args.requests} coloring requests, batch size B={args.batch}\n")

    # ---- naive loop: one fused device program per request -------------------
    for g in graphs:
        repro.color(g, "fused")    # warm every shape's jit cache (all unique)
    t0 = time.perf_counter()
    loop_results = [repro.color(g, "fused") for g in graphs]
    t_loop = time.perf_counter() - t0

    # ---- batched serving: one device program per width-homogeneous group ----
    # the list path width-buckets each batch (§12 batch-level load balancing)
    # so one skewed request cannot force its Δmax padding onto the others
    batches = [graphs[i : i + args.batch]
               for i in range(0, len(graphs), args.batch)]
    for bs in batches:
        color_batch_fused(bs)                         # warm the jit caches
    t0 = time.perf_counter()
    batch_results = []
    for bs in batches:
        batch_results.extend(color_batch_fused(bs))
    t_batch = time.perf_counter() - t0

    ok = all(is_valid_coloring(g, r.colors)
             for g, r in zip(graphs, batch_results))
    identical = all((a.colors == b.colors).all()
                    for a, b in zip(loop_results, batch_results))
    print(f"per-request loop : {t_loop * 1e3:8.1f} ms   "
          f"{len(graphs) / t_loop:7.1f} graphs/sec")
    print(f"batched serving  : {t_batch * 1e3:8.1f} ms   "
          f"{len(graphs) / t_batch:7.1f} graphs/sec")
    print(f"speedup          : {t_loop / t_batch:8.2f}x")
    print(f"all proper={ok}  bit-identical to loop={identical}")
    colors = sorted(r.num_colors for r in batch_results)
    print(f"colors used per graph: min={colors[0]} max={colors[-1]}")

    # ---- telemetry: untimed traced re-run of the first batch (§16) ----------
    traced = color_batch_fused(batches[0], trace=True)
    print("\nfirst batch, per request:")
    for i, r in enumerate(traced):
        print("  " + format_result(f"request[{i}]", r))
    print("\nrequest[0], per super-step:")
    print(format_trace(traced[0].trace))


if __name__ == "__main__":
    main()
