"""Quickstart: color a sparse graph with every implementation and compare.

    PYTHONPATH=src python examples/quickstart.py [--scale 0.2]

Reproduces the paper's headline result in one screen: the data-driven
speculative-greedy implementation matches serial greedy quality while the
MIS/multi-hash (csrcolor) baseline burns several times more colors.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    color_data_driven,
    color_jp,
    color_multihash,
    color_threestep,
    color_topology,
    greedy_serial,
    is_valid_coloring,
    num_colors,
)
from repro.graphs import rmat  # noqa: E402
from repro.graphs.rmat import RMAT_G  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--degree", type=float, default=10.0)
    args = ap.parse_args()

    g = rmat(args.n, args.degree, RMAT_G, seed=0)
    print(f"graph: n={g.n} m={g.m} dbar={g.avg_degree:.1f} "
          f"maxdeg={g.max_degree}\n")

    t0 = time.perf_counter()
    serial = greedy_serial(g)
    t_serial = time.perf_counter() - t0
    print(f"{'algorithm':28s} {'colors':>6s} {'iters':>5s} {'time':>8s} "
          f"{'speedup':>7s} valid")

    def report(name, colors, iters, t):
        ok = is_valid_coloring(g, colors)
        print(f"{name:28s} {num_colors(colors):6d} {iters:5d} {t*1e3:7.1f}ms "
              f"{t_serial/t:7.2f} valid={ok}")

    report("serial greedy (oracle)", serial, g.n, t_serial)
    for name, fn in [
        ("proposed-opt (SGR)", lambda: color_data_driven(g, coarsen_lanes=16384)),
        ("proposed-base (SGR)", lambda: color_data_driven(
            g, heuristic="id", firstfit="scan")),
        ("topology-driven", lambda: color_topology(g)),
        ("3-step GM analogue", lambda: color_threestep(g)),
        ("JP (MIS)", lambda: color_jp(g)),
        ("csrcolor multi-hash (MIS)", lambda: color_multihash(g, 2)),
    ]:
        r = fn()  # warmup/compile
        t0 = time.perf_counter()
        r = fn()
        report(name, r.colors, r.iterations, time.perf_counter() - t0)


if __name__ == "__main__":
    main()
