"""Jacobian compression quickstart: the repro.d2 bipartite workload.

    PYTHONPATH=src python examples/jacobian_compression.py [--n 4000 --band 3]

Colors the columns of a sparse Jacobian pattern into structurally-orthogonal
groups (no two columns in a group share a row), then demonstrates the
payoff: the whole Jacobian is recovered from ``num_groups`` forward-mode
products ``J @ seed`` instead of ``n_cols`` — on a banded pattern, exactly
the optimal ``2*band+1`` groups.  Also runs a distance-2 coloring of a mesh
graph, the other classic compression workload (Hessians / grid stencils).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.d2 import (  # noqa: E402
    color_distance2,
    compress_jacobian_pattern,
    greedy_serial_d2,
    validate_bipartite,
    validate_d2,
)
from repro.graphs import grid2d, jacobian_band, jacobian_tall_skinny  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--band", type=int, default=3)
    args = ap.parse_args()

    # --- banded Jacobian: the finite-difference stencil case ---------------
    bg = jacobian_band(args.n, band=args.band)
    t0 = time.perf_counter()
    cr = compress_jacobian_pattern(bg, mode="fused")
    dt = time.perf_counter() - t0
    optimal = 2 * args.band + 1
    print(f"banded {args.n}x{args.n} (band={args.band}): "
          f"{bg.n_cols} columns -> {cr.num_groups} groups "
          f"(optimal {optimal}) in {dt*1e3:.1f}ms  "
          f"valid={validate_bipartite(bg, cr.coloring.colors)}")
    print(f"  compression ratio {bg.n_cols / cr.num_groups:.1f}x; "
          f"seed matrix {cr.seed_matrix().shape}")

    # --- tall-skinny random pattern: least-squares style --------------------
    bg = jacobian_tall_skinny(args.n * 2, 256, nnz_per_row=3, seed=0)
    cr = compress_jacobian_pattern(bg, mode="fused")
    print(f"tall-skinny {bg.n_rows}x{bg.n_cols}: {cr.num_groups} groups "
          f"({bg.n_cols / cr.num_groups:.1f}x compression), "
          f"valid={validate_bipartite(bg, cr.coloring.colors)}")

    # --- distance-2 on a mesh: the Hessian/stencil compression case ---------
    g = grid2d(int(np.sqrt(args.n)), int(np.sqrt(args.n)), diagonals=True)
    t0 = time.perf_counter()
    r = color_distance2(g, mode="fused")
    dt = time.perf_counter() - t0
    oracle = int(greedy_serial_d2(g).max())
    print(f"distance-2 on {g.n}-vertex mesh: {r.num_colors} colors "
          f"(serial oracle {oracle}) in {dt*1e3:.1f}ms  "
          f"valid={validate_d2(g, r.colors)}")


if __name__ == "__main__":
    main()
