"""End-to-end driver: train a ~100M-parameter LM on the synthetic pipeline.

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 10m  --steps 300   # CPU-friendly

Uses the same launcher/optimizer/checkpoint path as the production configs;
--resume auto continues from the last checkpoint after any interruption.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402

PRESETS = {
    # ~104M params: emb 2*32768*512=34M + 16L*(4*512^2 + 3*512*2048)=67M
    "100m": dict(n_layers=16, d_model=512, n_heads=8, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=32768),
    # ~10M params: quick CPU demonstration
    "10m": dict(n_layers=6, d_model=192, n_heads=6, n_kv_heads=2,
                head_dim=32, d_ff=768, vocab=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="10m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen3-4b"),     # dense GQA family
        name=f"qwen3-example-{args.preset}",
        qk_norm=True,
        param_dtype="float32",
        act_dtype="float32",
        vocab_pad_to=256,
        logits_chunk=256,
        attn_q_chunk=256,
        **PRESETS[args.preset],
    )
    total, _ = cfg.params_estimate()
    print(f"[train_lm] {cfg.name}: ~{total/1e6:.0f}M params")
    out = train_loop(
        cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 1),
        resume=args.resume, log_every=10,
    )
    print(f"[train_lm] loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"in {out['steps']} steps ({out['wall_s']:.0f}s)")


if __name__ == "__main__":
    main()
