"""Streaming-serve demo: a service-hosted session over a mutating graph.

    PYTHONPATH=src python examples/stream_serve.py [--rounds 8] [--churn 0.01]

The ROADMAP streaming scenario, served for real (§19): a long-lived user
graph lives as a pooled session inside a ``ColoringService``; each round
a batch of edge updates (the churn fraction deleted, the same number
inserted) goes through ``service.apply_delta`` and a frontier-sized
``service.recolor`` repairs the coloring, while a naive server re-runs
the cold fused engine from scratch.  Both are validated every round and
the work/wall ratios are reported.  Compaction stays off the hot path
(deferred maintenance) and runs in one explicit ``service.maintain()``
lull at the end.

Reporting goes through ``repro.obs`` (§16): per-round lines come from
``format_result``, the closing blocks are ``service.session_metrics()``
via ``format_metrics`` plus the service's own counters, and the worker's
per-request spans are rendered with ``format_spans``.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import color_data_driven, is_valid_coloring  # noqa: E402
from repro.dynamic import churn_delta  # noqa: E402
from repro.graphs import build_graph  # noqa: E402
from repro.launch.coloring_service import ColoringService  # noqa: E402
from repro.obs.report import format_metrics, format_result  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="G3_circuit")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--churn", type=float, default=0.01)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    g = build_graph(args.graph, args.scale)
    svc = ColoringService(pool_size=4, queue_limit=64, trace=True)
    sid = "user-0"
    opened = svc.open_session(sid, g)
    print(f"{args.graph}: n={g.n} m={g.m // 2} edges, "
          f"{args.churn:.1%} churn x {args.rounds} rounds "
          f"(session {sid!r}, pool {svc.metrics()['pool_occupancy']}/"
          f"{svc.metrics()['pool_size']})\n")
    print(f"cold start: {opened['num_colors']} colors, "
          f"converged={opened['converged']}\n")

    # the cold comparator recolors the same mutating graph from scratch;
    # track it on a live session handle so both sides see identical deltas
    live = svc._touch(sid)

    t_inc = t_cold = 0.0
    for r in range(args.rounds):
        rem, add = churn_delta(live.graph, args.churn, rng)

        t0 = time.perf_counter()
        td = svc.apply_delta(sid, remove_edges=rem, add_edges=add,
                             wait=False)
        inc = svc.recolor(sid)            # client waits for the repair
        dirty = td.wait()
        t_inc += time.perf_counter() - t0

        t0 = time.perf_counter()
        cold = color_data_driven(live.graph, mode="fused")
        t_cold += time.perf_counter() - t0

        ok = (is_valid_coloring(live.graph, np.asarray(svc.colors(sid)))
              and is_valid_coloring(live.graph, cold.colors))
        print(f"round {r}: frontier={dirty.size:5d}  valid={ok}")
        print("  " + format_result("inc ", inc))
        print("  " + format_result("cold", cold))

    print(f"\nwall: incremental={t_inc * 1e3:.0f} ms  "
          f"cold={t_cold * 1e3:.0f} ms  "
          f"speedup={t_cold / max(t_inc, 1e-9):.1f}x")

    # lull-time maintenance: compaction/snapshots deferred off the hot path
    done = svc.maintain(sid)
    print(f"maintenance at the lull: {done[sid] or 'nothing due'}")

    print(format_metrics(svc.session_metrics(sid), "\nsession metrics:"))
    m = svc.metrics()
    print(f"\nservice: {m['admitted']} admitted, {m['completed']} completed, "
          f"{m['rejected']} rejected, queue peak depth <= "
          f"{m['queue_limit']}, engine cache "
          f"{m['session_engine_cache_hits']} hits / "
          f"{m['session_engine_cache_misses']} misses")
    spans = svc.take_spans()
    kinds = {}
    for e in spans:
        kinds[e.name] = kinds.get(e.name, 0) + 1
    print("worker spans: " + ", ".join(f"{k} x{v}"
                                       for k, v in sorted(kinds.items())))
    svc.shutdown()


if __name__ == "__main__":
    main()
