"""Streaming-serve demo: keep a live coloring over a mutating graph (§14).

    PYTHONPATH=src python examples/stream_serve.py [--rounds 8] [--churn 0.01]

Simulates the ROADMAP streaming scenario: a long-lived user graph receives
batches of edge updates (the churn fraction of its edges is deleted and the
same number of fresh edges inserted each round).  A ``ColoringSession``
absorbs each delta with a frontier-sized incremental ``recolor()`` while a
naive server re-runs the cold fused engine from scratch; both are validated
every round and the work/wall ratios are reported.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import repro  # noqa: E402
from repro.core import color_data_driven, is_valid_coloring  # noqa: E402
from repro.dynamic import churn_delta  # noqa: E402
from repro.graphs import build_graph  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="G3_circuit")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--churn", type=float, default=0.01)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    g = build_graph(args.graph, args.scale)
    session = repro.open_session(g)
    print(f"{args.graph}: n={g.n} m={g.m // 2} edges, "
          f"{args.churn:.1%} churn x {args.rounds} rounds\n")
    print(f"cold start: {session.result.num_colors} colors, "
          f"work={session.result.work_items}\n")

    t_inc = t_cold = 0.0
    w_inc = w_cold = 0
    for r in range(args.rounds):
        rem, add = churn_delta(session.graph, args.churn, rng)
        dirty = session.apply_delta(remove_edges=rem, add_edges=add)

        t0 = time.perf_counter()
        inc = session.recolor()
        t_inc += time.perf_counter() - t0

        t0 = time.perf_counter()
        cold = color_data_driven(session.graph, mode="fused")
        t_cold += time.perf_counter() - t0

        ok = session.validate() and is_valid_coloring(session.graph,
                                                      cold.colors)
        w_inc += inc.work_items
        w_cold += cold.work_items
        print(f"round {r}: frontier={dirty.size:5d}  "
              f"inc work={inc.work_items:7d} ({inc.num_colors} colors)  "
              f"cold work={cold.work_items:7d} ({cold.num_colors} colors)  "
              f"valid={ok}")

    print(f"\ntotal work : incremental={w_inc}  cold={w_cold}  "
          f"ratio={w_cold / max(w_inc, 1):.1f}x")
    print(f"wall       : incremental={t_inc * 1e3:.0f} ms  "
          f"cold={t_cold * 1e3:.0f} ms  "
          f"speedup={t_cold / max(t_inc, 1e-9):.1f}x")
    print(f"overlay    : {session.delta.overlay_size} pending keys, "
          f"{session.delta.compactions} compactions")


if __name__ == "__main__":
    main()
