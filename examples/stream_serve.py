"""Streaming-serve demo: keep a live coloring over a mutating graph (§14).

    PYTHONPATH=src python examples/stream_serve.py [--rounds 8] [--churn 0.01]

Simulates the ROADMAP streaming scenario: a long-lived user graph receives
batches of edge updates (the churn fraction of its edges is deleted and the
same number of fresh edges inserted each round).  A ``ColoringSession``
absorbs each delta with a frontier-sized incremental ``recolor()`` while a
naive server re-runs the cold fused engine from scratch; both are validated
every round and the work/wall ratios are reported.

Reporting goes through ``repro.obs`` (§16): the session is opened with
``trace=True``, per-round lines come from ``format_result``, the closing
block is ``session.metrics()`` via ``format_metrics``, and the last round's
per-super-step table and phase spans are rendered with ``format_trace`` /
``format_spans``.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import repro  # noqa: E402
from repro.core import color_data_driven, is_valid_coloring  # noqa: E402
from repro.dynamic import churn_delta  # noqa: E402
from repro.graphs import build_graph  # noqa: E402
from repro.obs.report import (  # noqa: E402
    format_metrics,
    format_result,
    format_spans,
    format_trace,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="G3_circuit")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--churn", type=float, default=0.01)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    g = build_graph(args.graph, args.scale)
    session = repro.open_session(g, trace=True)
    print(f"{args.graph}: n={g.n} m={g.m // 2} edges, "
          f"{args.churn:.1%} churn x {args.rounds} rounds\n")
    print(format_result("cold start", session.result) + "\n")

    t_inc = t_cold = 0.0
    last = None
    for r in range(args.rounds):
        rem, add = churn_delta(session.graph, args.churn, rng)
        dirty = session.apply_delta(remove_edges=rem, add_edges=add)

        t0 = time.perf_counter()
        inc = session.recolor()
        t_inc += time.perf_counter() - t0

        t0 = time.perf_counter()
        cold = color_data_driven(session.graph, mode="fused")
        t_cold += time.perf_counter() - t0

        ok = session.validate() and is_valid_coloring(session.graph,
                                                      cold.colors)
        if inc.trace is not None and inc.trace.iterations:
            last = inc
        print(f"round {r}: frontier={dirty.size:5d}  valid={ok}")
        print("  " + format_result("inc ", inc))
        print("  " + format_result("cold", cold))

    m = session.metrics()
    print(f"\nwall: incremental={t_inc * 1e3:.0f} ms  "
          f"cold={t_cold * 1e3:.0f} ms  "
          f"speedup={t_cold / max(t_inc, 1e-9):.1f}x")
    print(format_metrics(m, "\nsession metrics:"))
    if last is not None:
        print("\nlast recolor, per super-step:")
        print(format_trace(last.trace, last=8))
        print("\n" + format_spans(last.trace.spans))


if __name__ == "__main__":
    main()
