"""One benchmark per paper table/figure (see DESIGN.md §8 for the mapping).

Every function returns rows (name, us_per_call, derived).  Quality numbers
(colors, iterations) are hardware-independent and reproduce the paper's
claims directly; runtimes are CPU-host wall-clock (the serial oracle runs on
the same host, so the *ratios* are the meaningful quantity, as in the paper).

This module IS wired into the harness (audited for PR §16): ``run.py``'s
CSV matrix iterates ``ALL_BENCHES`` on every non ``--json-only`` run, and
the weekly CI job (``--scale small`` without ``--json-only``) executes the
full set.  Step-level telemetry for these runs lives in the schema-6 JSON
documents (``trace`` sections + the ``_trace.json`` Chrome export), not in
the CSV rows.
"""
from __future__ import annotations

import numpy as np

from benchmarks.batch import bench_batch_throughput
from benchmarks.common import SCALE, row, timeit
from repro.core import (
    color_data_driven,
    color_jp,
    color_multihash,
    color_threestep,
    color_topology,
    greedy_serial,
    is_valid_coloring,
    num_colors,
)
from repro.graphs import build_graph, build_suite, rmat
from repro.graphs.rmat import RMAT_ER, RMAT_G

# representative subset used by per-figure micro benches (full suite: fig8/9)
CORE_GRAPHS = ("rmat-er", "rmat-g", "G3_circuit", "cage15", "europe.osm")

_CACHE: dict = {}


def _graph(name, scale=None):
    key = (name, scale or SCALE)
    if key not in _CACHE:
        _CACHE[key] = build_graph(name, scale or SCALE)
    return _CACHE[key]


def _serial_time(g):
    t, colors = timeit(lambda: greedy_serial(g))
    return t, colors


# --------------------------------------------------------------------------
def bench_fig1_motivation():
    """Fig. 1: 3-step GM vs csrcolor(multi-hash MIS): speed AND quality."""
    rows = []
    for name in ("rmat-er", "rmat-g", "G3_circuit"):
        g = _graph(name)
        ts, base = _serial_time(g)
        t3, r3 = timeit(lambda: color_threestep(g))
        tm, rm = timeit(lambda: color_multihash(g, 2))
        rows.append(row(f"fig1/{name}/threestep_speedup", t3, round(ts / t3, 2)))
        rows.append(row(f"fig1/{name}/multihash_speedup", tm, round(ts / tm, 2)))
        rows.append(row(f"fig1/{name}/colors_serial", ts, num_colors(base)))
        rows.append(row(f"fig1/{name}/colors_threestep", t3, r3.num_colors))
        rows.append(row(f"fig1/{name}/colors_multihash", tm, rm.num_colors))
    return rows


def bench_table1_suite():
    """Table 1: the benchmark-graph suite (scaled stand-ins) + stats."""
    rows = []
    for name, g in build_suite(SCALE).items():
        rows.append(row(
            f"table1/{name}", 0.0,
            f"n={g.n};m={g.m};dbar={g.avg_degree:.1f};sigma={g.degree_std:.1f}",
        ))
    return rows


def bench_fig3_mapping():
    """Fig. 3: topology-driven vs data-driven runtime (normalized to serial)."""
    rows = []
    for name in CORE_GRAPHS:
        g = _graph(name)
        ts, _ = _serial_time(g)
        tt, rt = timeit(lambda: color_topology(g, heuristic="id"))
        td, rd = timeit(lambda: color_data_driven(g, heuristic="id"))
        rows.append(row(f"fig3/{name}/topo_speedup", tt, round(ts / tt, 2)))
        rows.append(row(f"fig3/{name}/data_speedup", td, round(ts / td, 2)))
        rows.append(row(f"fig3/{name}/work_ratio_topo_over_data", 0.0,
                        round(rt.work_items / max(rd.work_items, 1), 2)))
    return rows


def bench_fig4_heuristic():
    """Fig. 4: iterations to converge, id-rule vs degree-heuristic."""
    rows = []
    for name in CORE_GRAPHS:
        g = _graph(name)
        tb, rb = timeit(lambda: color_data_driven(g, heuristic="id"))
        th, rh = timeit(lambda: color_data_driven(g, heuristic="degree"))
        rows.append(row(f"fig4/{name}/iters_baseline", tb, rb.iterations))
        rows.append(row(f"fig4/{name}/iters_heuristic", th, rh.iterations))
        rows.append(row(f"fig4/{name}/speedup_over_baseline", th,
                        round(tb / th, 2)))
    return rows


def bench_fig5_coarsening():
    """Fig. 5: thread coarsening on FirstFit (TC-ff), ConflictResolve (TC-cr), both."""
    rows = []
    for name in ("G3_circuit", "cage15", "rmat-g"):
        g = _graph(name)
        t0, _ = timeit(lambda: color_data_driven(g))
        for label, kw in (
            ("tc_ff", dict(coarsen_ff=4)),
            ("tc_cr", dict(coarsen_cr=4)),
            ("tc_both", dict(coarsen_ff=4, coarsen_cr=4)),
            ("tc_lanes16k", dict(coarsen_lanes=16384)),
        ):
            t, r = timeit(lambda: color_data_driven(g, **kw))
            rows.append(row(f"fig5/{name}/{label}_speedup", t,
                            round(t0 / t, 2)))
            rows.append(row(f"fig5/{name}/{label}_iters", t, r.iterations))
    return rows


def bench_fig6_bitset():
    """Fig. 6: FirstFit operator — colorMask scan vs sort vs bitset (+Pallas)."""
    rows = []
    for name in ("rmat-er", "rmat-g", "thermal2"):
        g = _graph(name)
        t_scan, _ = timeit(lambda: color_data_driven(g, firstfit="scan"))
        t_sort, _ = timeit(lambda: color_data_driven(g, firstfit="sort"))
        t_bit, _ = timeit(lambda: color_data_driven(g, firstfit="bitset"))
        rows.append(row(f"fig6/{name}/bitset_vs_scan", t_bit,
                        round(t_scan / t_bit, 2)))
        rows.append(row(f"fig6/{name}/bitset_vs_sort", t_bit,
                        round(t_sort / t_bit, 2)))
    # isolated kernel comparison on a fixed padded worklist (interpret mode)
    import jax.numpy as jnp
    from repro.core.firstfit import FF_FUNCS
    from repro.kernels.firstfit.ops import firstfit_bitset_tpu

    rng = np.random.default_rng(0)
    nc = jnp.asarray(rng.integers(0, 40, size=(4096, 32)).astype(np.int32))
    for kind, fn in FF_FUNCS.items():
        t, _ = timeit(lambda: fn(nc).block_until_ready())
        rows.append(row(f"fig6/kernel_{kind}", t, "jnp"))
    t, _ = timeit(lambda: firstfit_bitset_tpu(nc).block_until_ready())
    rows.append(row("fig6/kernel_bitset_pallas_interp", t, "interpret=True"))
    return rows


def bench_fig7_common():
    """Fig. 7: kernel fusion (fused device loop), __ldg (N/A on TPU — VMEM
    staging is explicit), and Merrill-style load balancing (degree buckets).

    Fusion (the single-device-program mode) is timed on regular graphs only:
    on this CPU host its full-capacity super-steps are slow for skewed graphs
    (on TPU the wide vector lanes are the point); load balancing is timed on
    the skewed graphs where it matters.
    """
    rows = []
    for name in ("rmat-er", "thermal2"):
        g = _graph(name)
        t0, _ = timeit(lambda: color_data_driven(g))
        tf, _ = timeit(lambda: color_data_driven(g, mode="fused"))
        rows.append(row(f"fig7/{name}/fusion_speedup", tf, round(t0 / tf, 2)))
    for name in ("rmat-g", "cage15", "kkt_power"):
        g = _graph(name)
        t0, _ = timeit(lambda: color_data_driven(g))
        tl, rl = timeit(lambda: color_data_driven(g, buckets=(16, 128)))
        rows.append(row(f"fig7/{name}/loadbalance_speedup", tl,
                        round(t0 / tl, 2)))
    rows.append(row("fig7/ldg", 0.0, "N/A-on-TPU(BlockSpec-VMEM-staging)"))
    return rows


def bench_fig8_quality():
    """Fig. 8: total colors assigned per implementation per graph."""
    rows = []
    for name, g in build_suite(SCALE).items():
        rows.append(row(f"fig8/{name}/serial", 0.0, num_colors(greedy_serial(g))))
        for label, fn in (
            ("proposed_opt", lambda: color_data_driven(g)),
            ("proposed_base", lambda: color_data_driven(
                g, heuristic="id", firstfit="scan")),
            ("jp", lambda: color_jp(g)),
            ("csrcolor_multihash", lambda: color_multihash(g, 2)),
        ):
            r = fn()
            assert is_valid_coloring(g, r.colors), (name, label)
            rows.append(row(f"fig8/{name}/{label}", 0.0, r.num_colors))
    return rows


def bench_fig9_speedup():
    """Fig. 9: end-to-end runtime speedup over Serial, all implementations."""
    rows = []
    speedups = {"proposed_base": [], "proposed_opt": [], "csrcolor": [],
                "threestep": []}
    for name, g in build_suite(SCALE).items():
        ts, _ = _serial_time(g)
        for label, fn in (
            ("proposed_base", lambda: color_data_driven(
                g, heuristic="id", firstfit="scan")),
            ("proposed_opt", lambda: color_data_driven(
                g, heuristic="degree", firstfit="bitset",
                coarsen_lanes=16384, buckets=(16, 128))),
            ("csrcolor", lambda: color_multihash(g, 2)),
            ("threestep", lambda: color_threestep(g)),
        ):
            t, _ = timeit(fn)
            s = ts / t
            speedups[label].append(s)
            rows.append(row(f"fig9/{name}/{label}", t, round(s, 2)))
    for label, vals in speedups.items():
        rows.append(row(f"fig9/geomean/{label}", 0.0,
                        round(float(np.exp(np.mean(np.log(vals)))), 2)))
    return rows


def bench_fig10_scaling():
    """Fig. 10: |V| sweep at fixed dbar=10 (rmat-er), speedup vs serial."""
    rows = []
    for logn in (13, 14, 15, 16):
        g = rmat(1 << logn, 10.0, RMAT_ER, seed=42)
        ts, _ = _serial_time(g)
        t, r = timeit(lambda: color_data_driven(g))
        rows.append(row(f"fig10/n=2^{logn}", t, round(ts / t, 2)))
    return rows


def bench_fig11_density():
    """Fig. 11: average-degree sweep at fixed |V| (rmat-er)."""
    rows = []
    n = 16384
    for dbar in (2, 5, 10, 20, 40):
        g = rmat(n, float(dbar), RMAT_ER, seed=43)
        ts, _ = _serial_time(g)
        to, ro = timeit(lambda: color_data_driven(g))
        tb, _ = timeit(lambda: color_data_driven(g, heuristic="id",
                                                 firstfit="scan"))
        rows.append(row(f"fig11/dbar={dbar}/opt", to, round(ts / to, 2)))
        rows.append(row(f"fig11/dbar={dbar}/base", tb, round(ts / tb, 2)))
        rows.append(row(f"fig11/dbar={dbar}/iters", 0.0, ro.iterations))
    return rows


def bench_fig12_ragged_engine():
    """§12: the ragged CSR-native super-step engine vs the classic two-phase.

    ``superstep_speedup`` is the acceptance metric — wall time of ONE
    degree-tiled fused super-step (one gather pair, one dispatch) vs one
    classic FirstFit+ConflictResolve super-step (two gather pairs) on the
    same full worklist, post-warmup.  ``engine_speedup`` is end-to-end; on
    the cascading circuit graphs the adaptive tail-serialization collapses
    hundreds of super-steps into ~4.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.coloring import (_resolve_classes, provider_tiled_superstep,
                                     sgr_step)
    from repro.core.csr import DeviceCSR

    rows = []
    for name in ("rmat-g", "rmat-er"):
        g = _graph(name)
        n = g.n
        dcsr = DeviceCSR.from_csr(g)
        adj = jnp.asarray(g.padded_adjacency())
        deg_ext = jnp.asarray(
            np.concatenate([g.degrees, np.zeros(1, np.int32)]).astype(np.int32))
        colors = jnp.where(
            jnp.arange(n + 1, dtype=jnp.int32) < n, 1, 0).astype(jnp.int32)
        wl = jnp.arange(n, dtype=jnp.int32)
        classes, widths = _resolve_classes(g.degrees, (), "auto")
        wls = tuple(jnp.asarray(c) for c in classes)
        t_cl, _ = timeit(lambda: jax.block_until_ready(
            sgr_step(adj, deg_ext, colors, wl,
                     heuristic="degree", kind="bitset")))
        t_rg, _ = timeit(lambda: jax.block_until_ready(
            provider_tiled_superstep(
                dcsr, deg_ext, colors, wls, widths=tuple(widths),
                heuristic="degree", kind="bitset", use_kernel=False,
                chunks=(1,) * len(wls))))
        rows.append(row(f"fig12/{name}/superstep_speedup", t_rg,
                        round(t_cl / t_rg, 2)))
        # classic step: 2 adjacency + 2 color + 1 degree tile at full width;
        # rotated step: 1 adjacency + 1 packed color|degree tile per class
        rows.append(row(f"fig12/{name}/superstep_gather_cells_ratio", 0.0,
                        round(5 * n * g.max_degree /
                              max(2 * sum(len(c) * w for c, w in
                                          zip(classes, widths)), 1), 2)))
    for name in ("rmat-g", "G3_circuit", "thermal2", "europe.osm"):
        g = _graph(name)
        tc, rc = timeit(lambda: color_data_driven(g, engine="classic"))
        tr, rr = timeit(lambda: color_data_driven(g))
        assert is_valid_coloring(g, rr.colors), name
        rows.append(row(f"fig12/{name}/engine_speedup", tr,
                        round(tc / tr, 2)))
        rows.append(row(f"fig12/{name}/iters_classic_vs_ragged", tr,
                        f"{rc.iterations}->{rr.iterations}"))
        rows.append(row(f"fig12/{name}/colors_classic_vs_ragged", tr,
                        f"{rc.num_colors}->{rr.num_colors}"))
    return rows


ALL_BENCHES = [
    bench_fig1_motivation,
    bench_table1_suite,
    bench_fig3_mapping,
    bench_fig4_heuristic,
    bench_fig5_coarsening,
    bench_fig6_bitset,
    bench_fig7_common,
    bench_fig8_quality,
    bench_fig9_speedup,
    bench_fig10_scaling,
    bench_fig11_density,
    bench_fig12_ragged_engine,
    bench_batch_throughput,
]
