import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing harness: lower+compile a cell under config variants and
report the roofline-term deltas vs the recorded baseline.

    PYTHONPATH=src python benchmarks/perf_experiments.py \
        --arch deepseek-v2-236b --shape train_4k --mesh single \
        --set moe_remat=True --set moe_dispatch=scatter

Appends records to dryrun_perf.json (variant name = the --set list).
"""
import argparse
import dataclasses
import json
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from repro.configs import get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from benchmarks.roofline import roofline_terms  # noqa: E402


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def run_variant(arch, shape, mesh_kind, overrides, out_path):
    cfg = dataclasses.replace(get_config(arch), **overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
    variant = ",".join(f"{k}={v}" for k, v in overrides.items()) or "baseline"

    import time
    import traceback
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "variant": variant}
    info = dryrun.SHAPES[shape]
    n_total, n_active = cfg.params_estimate()
    tokens = info["batch"] * (info["seq"] if info["mode"] != "decode" else 1)
    rec["model_flops"] = float(
        (6 if info["mode"] == "train" else 2) * n_active * tokens)
    try:
        t0 = time.time()
        lowered = dryrun.build_lowered(arch, shape, mesh, cfg=cfg)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = dryrun.memory_stats(compiled)
        text = compiled.as_text()
        from repro.launch.hlo_analysis import analyze_hlo
        hc = analyze_hlo(text)
        rec["analysis"] = {
            "flops": hc.flops, "traffic_bytes": hc.traffic,
            "collective_bytes": hc.collective_bytes,
            "collectives": hc.collectives,
        }
        rec["ok"] = True
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
    records = []
    if os.path.exists(out_path):
        records = json.load(open(out_path))
    records.append(rec)
    json.dump(records, open(out_path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. moe_remat=True")
    ap.add_argument("--out", default="dryrun_perf.json")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    rec = run_variant(args.arch, args.shape, args.mesh, overrides, args.out)
    if rec.get("ok"):
        r = rec["roofline"]
        print(f"VARIANT {rec['variant']}")
        print(f"  compute_s={r['compute_s']:.3f} memory_s={r['memory_s']:.3f} "
              f"collective_s={r['collective_s']:.3f} bound={r['bottleneck']} "
              f"MFU_bound={r['model_mfu_bound']:.4f}")
        print(f"  temp_GB={rec['memory'].get('temp_size_in_bytes', 0)/1e9:.2f} "
              f"compile_s={rec['compile_s']}")
    else:
        print("FAIL", rec.get("error"))


if __name__ == "__main__":
    main()
