"""Batched multi-graph throughput (beyond-paper; DESIGN.md §4).

Measures the serving-path win of ``core/batch.py``: coloring B heterogeneous
graphs with ONE jitted batched ``while_loop`` versus looping the B=1 fused
driver.  Reported ``derived`` is graphs/sec; the batched call amortizes
dispatch overhead across the batch exactly like Rokos/Bogle amortize it
across subdomains, so its throughput should meet or beat the loop.

Three rows per batch size:

* ``loop_b1``        — B sequential ``color_data_driven(mode="fused")`` calls
                       (each re-packs its graph, the naive serving loop)
* ``batched``        — one ``color_batch_fused`` call on a pre-packed
                       ``GraphBatch`` (packing amortized across requests, the
                       steady-state serving path)
* ``batched_e2e``    — batched including per-call packing (worst case)
"""
from __future__ import annotations

from benchmarks.common import SCALE, row, timeit
from repro.core import GraphBatch, color_batch_fused, color_data_driven
from repro.core.validate import is_valid_coloring
from repro.graphs import serving_mix


def bench_batch_throughput():
    """graphs/sec: one batched device program vs the B=1 fused loop."""
    rows = []
    for B in (8, 16):
        graphs = serving_mix(B, SCALE)

        t_loop, res_loop = timeit(
            lambda: [color_data_driven(g, mode="fused") for g in graphs]
        )
        batch = GraphBatch.from_graphs(graphs)   # packed once, served many
        t_bat, res_bat = timeit(lambda: color_batch_fused(batch))
        t_e2e, _ = timeit(
            lambda: color_batch_fused(GraphBatch.from_graphs(graphs))
        )
        # width-bucketed sub-batches (§12 batch-level load balancing): the
        # list path groups graphs by pow2 max degree before packing
        t_lb, res_lb = timeit(lambda: color_batch_fused(graphs))

        for g, r_l, r_b, r_lb in zip(graphs, res_loop, res_bat, res_lb):
            assert is_valid_coloring(g, r_b.colors)
            assert (r_b.colors == r_l.colors).all()  # serving == loop, bitwise
            assert (r_lb.colors == r_l.colors).all()  # grouping is perf-only

        rows.append(row(f"batch/B{B}/loop_b1", t_loop, round(B / t_loop, 1)))
        rows.append(row(f"batch/B{B}/batched", t_bat, round(B / t_bat, 1)))
        rows.append(row(f"batch/B{B}/batched_e2e", t_e2e, round(B / t_e2e, 1)))
        rows.append(row(f"batch/B{B}/batched_lb", t_lb, round(B / t_lb, 1)))
        rows.append(row(f"batch/B{B}/speedup", t_bat, round(t_loop / t_bat, 2)))
        rows.append(row(f"batch/B{B}/speedup_lb", t_lb, round(t_loop / t_lb, 2)))
    return rows
