"""Serving benchmark: Poisson traffic against ``ColoringService`` (§19).

    PYTHONPATH=src python benchmarks/serve.py --scale tiny

Drives the session-pool serving layer with an open-loop Poisson arrival
process over a heterogeneous request mix — one-shot ``color()`` calls on
the ``serving_mix`` graphs plus streaming churn (``apply_delta`` +
``recolor``) on pooled sessions — and writes ``BENCH_serving.json``
(schema 9: the ``serve`` section; REPRO_BENCH_JSON env overrides the
path), gated in CI by ``benchmarks/check_regression.py``:

* ``steady``: latency percentiles (p50/p99 wall ms a client observes,
  submit→finish), rejection rate, and ``jit_misses_after_warmup`` — the
  micro-batcher's bucket accounting; ZERO after warmup is the §19
  jit-cache-stability contract (steady-state traffic re-presents warm
  ``(bucket, pow2 batch)`` keys only).
* ``overload``: a full-speed burst past the queue limit MUST produce
  structured ``Overloaded`` rejections while the queue stays bounded —
  backpressure is load-shedding, not unbounded growth.

The steady arrival rate self-calibrates to ~15% of the measured warmup
service capacity so the gate's p99 ≤ 3×p50 bound reflects queueing
discipline rather than host speed; ``--rate`` overrides it (Hz).
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import random
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

JSON_PATH = os.environ.get("REPRO_BENCH_JSON", "BENCH_serving.json")

# mirrors benchmarks/run.py SCALE_PRESETS' JSON scale column
SCALE_PRESETS = {"tiny": 0.01, "small": 0.02, "paper": 0.02}


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile of an ascending list (q in [0, 100])."""
    if not sorted_vals:
        return 0.0
    k = max(0, min(len(sorted_vals) - 1,
                   int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[k]


def _latency_summary(lat_s: list[float]) -> dict:
    lat = sorted(lat_s)
    n = len(lat)
    return {
        "requests": n,
        "p50_ms": round(_percentile(lat, 50) * 1e3, 3),
        "p90_ms": round(_percentile(lat, 90) * 1e3, 3),
        "p99_ms": round(_percentile(lat, 99) * 1e3, 3),
        "max_ms": round((lat[-1] if lat else 0.0) * 1e3, 3),
        "mean_ms": round(sum(lat) / n * 1e3, 3) if n else 0.0,
    }


def bench_serving(scale: float, *, pool_size: int = 4, queue_limit: int = 32,
                  max_batch: int = 8, n_graphs: int = 6, sessions: int = 6,
                  steady_requests: int = 240, overload_requests: int = 96,
                  rate_hz: float | None = None, seed: int = 0) -> dict:
    """One full serving run (warmup → steady Poisson → overload burst)."""
    import numpy as np

    import repro
    from repro.errors import Overloaded
    from repro.graphs.suite import serving_mix

    rng = random.Random(seed)
    nprng = np.random.default_rng(seed)
    graphs = serving_mix(n_graphs, scale)
    churn_graphs = serving_mix(sessions, scale)

    svc = repro.ColoringService(pool_size=pool_size, queue_limit=queue_limit,
                                max_batch=max_batch)
    doc: dict = {
        "config": {
            "pool_size": pool_size, "queue_limit": queue_limit,
            "max_batch": max_batch, "n_graphs": n_graphs,
            "sessions": min(sessions, pool_size),
            "steady_requests": steady_requests,
            "overload_requests": overload_requests, "seed": seed,
        },
    }

    # -- warmup: open the pool, then churn until the jitted shape-key sets
    # saturate — both the micro-batch buckets AND the per-session frontier
    # engine keys (steady-state deltas re-present pow2-padded shapes the
    # warmup rounds below have already compiled)
    t0 = time.perf_counter()
    sids = []
    for i, g in enumerate(churn_graphs[:pool_size]):
        sid = f"churn-{i}"
        svc.open_session(sid, g)
        sids.append(sid)

    # Balanced churn: every transaction adds a fresh edge batch and retires
    # the batch added two transactions earlier, so a long-lived session's
    # m / max-degree stay bounded near their opening values — sustained
    # serving churn, not monotone graph growth (which legitimately
    # recompiles every time a pow2 capacity doubles).
    added: dict[str, list] = {sid: [] for sid in sids}

    def churn_delta(sid: str, n: int, edges: int) -> dict:
        batch = (nprng.integers(0, n, edges), nprng.integers(0, n, edges))
        kw = {"add_edges": batch}
        pending = added[sid]
        pending.append(batch)
        if len(pending) > 2:
            kw["remove_edges"] = pending.pop(0)
        return kw

    def churn_round(edges: int):
        for g in graphs:
            svc.color(g)
        for sid in sids:
            g = churn_graphs[int(sid.split("-")[1])]
            svc.apply_delta(sid, **churn_delta(sid, g.n, edges))
            svc.recolor(sid)

    def color_burst(copies: int):
        # async burst: queued colors drain as micro-batches, presenting the
        # pow2 BATCH-size axis of each bucket's jit key (steady traffic
        # batches too — synchronous warmup alone only compiles batch=1)
        for g in graphs:  # per graph: stays within the queue limit
            ts = [svc.color(g, wait=False) for _ in range(copies)]
            for t in ts:
                t.wait(120)

    def miss_count():
        m = svc.metrics()
        return (m["bucket_jit_misses"] + m["session_engine_cache_misses"])

    for edges in (1, 2, 4, 8):  # cover the pow2 frontier pads steady uses
        churn_round(edges)
    for copies in (1, 2, 4, 8):  # cover the pow2 micro-batch sizes
        color_burst(copies)
    prev, stable = miss_count(), 0
    for _ in range(12):  # until full rounds stop presenting fresh keys
        churn_round(4)
        color_burst(4)
        cur = miss_count()
        stable = stable + 1 if cur == prev else 0
        if stable >= 2:
            break
        prev = cur
    warm = svc.metrics()
    doc["warmup"] = {
        "seconds": round(time.perf_counter() - t0, 3),
        "requests": warm["admitted"],
        "jit_misses": warm["bucket_jit_misses"],
        "session_engine_misses": warm["session_engine_cache_misses"],
    }

    # -- capacity probe: best of three warm synchronous rounds (min is
    # robust to a straggler round absorbing one last compile).  GC stays
    # off through the steady phase so collector pauses don't masquerade
    # as serving tail latency.
    svc.maintain()  # start the probe/steady phases from compacted sessions
    gc.collect()
    gc.disable()
    probe_reqs = len(graphs) + 2 * len(sids)
    cap_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        churn_round(4)
        cap_s = min(cap_s, (time.perf_counter() - t0) / probe_reqs)
    cap_s = max(cap_s, 1e-4)
    doc["warmup"]["probe_request_seconds"] = round(cap_s, 6)

    # -- steady phase: open-loop Poisson arrivals at ~12% of OP capacity.
    # ``cap_s`` is the warm per-op service time, but one arrival is a
    # TRANSACTION — 60% are a single color op, 40% are a churn pair
    # (delta + recolor), a mean of 1.4 ops per arrival — so divide the op
    # budget by that mix or the true utilisation quietly runs 40% hot and
    # the queueing tail stretches p99 past the gate.  12% rather than 15%
    # because the min-of-3 probe reports the FASTEST warm op: with any
    # service-time variance the realised utilisation runs above the
    # target, and on a shared CI host that optimism is what pushes the
    # queueing tail against the 3x gate.  Three independent phases; the
    # MEDIAN phase (by p99/p50 ratio) is reported, so one
    # scheduler/noisy-neighbour hiccup on a shared CI host cannot fail
    # the latency gate, and one lucky phase cannot mask a regression.
    ops_per_arrival = 0.6 * 1 + 0.4 * 2
    rate = rate_hz if rate_hz is not None else 0.12 / (ops_per_arrival * cap_s)

    def steady_phase() -> dict:
        # Latency is CLIENT-CENTRIC: one request = one client-visible
        # outcome.  A churn transaction (apply_delta + recolor enqueued
        # back-to-back so the repair sees exactly this delta's frontier —
        # the steady-state shape warmup compiled) is ONE request measured
        # delta-submit → recolor-done: the client is waiting for the
        # repaired coloring, not the mutation ack.
        phase_start = svc.metrics()
        requests = []  # (first ticket enqueued, last ticket awaited)
        orphans = []   # delta legs whose recolor leg was shed
        rejected = 0
        queue_peak = 0
        next_at = time.perf_counter()
        submitted = 0
        while submitted < steady_requests:
            next_at += rng.expovariate(rate)
            delay = next_at - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                if rng.random() < 0.6:
                    t = svc.color(graphs[submitted % len(graphs)],
                                  wait=False)
                    requests.append((t, t))
                else:
                    sid = sids[submitted % len(sids)]
                    g = churn_graphs[int(sid.split("-")[1])]
                    kw = churn_delta(sid, g.n, 4)
                    td = svc.apply_delta(sid, wait=False, **kw)
                    try:
                        requests.append((td, svc.recolor(sid, wait=False)))
                    except Overloaded:
                        orphans.append(td)  # mutation landed, repair shed
                        raise
            except Overloaded:
                rejected += 1
            submitted += 1
            if submitted % 8 == 0:
                queue_peak = max(queue_peak, svc.metrics()["queue_depth"])
        for _, last in requests:
            last.wait(120)
        for t in orphans:
            t.wait(120)
        steady = _latency_summary(
            [last.done_at - first.enqueued_at for first, last in requests])
        steady.update({
            "submitted": submitted,
            "completed": len(requests),
            "rejected": rejected,
            "rejection_rate": round(rejected / max(submitted, 1), 4),
            "rate_hz": round(rate, 2),
            "queue_peak": queue_peak,
            "jit_misses_after_warmup": (svc.metrics()["bucket_jit_misses"]
                                        - phase_start["bucket_jit_misses"]),
        })
        return steady

    phases = []
    for _ in range(3):
        phases.append(steady_phase())
        # lull-time maintenance between phases: compaction keeps the
        # session overlays (and so recolor cost) from creeping across the
        # run — the same call a real deployment makes in traffic windows
        svc.maintain()
    gc.enable()
    ranked = sorted(phases, key=lambda s: s["p99_ms"] / max(s["p50_ms"], 1e-9))
    doc["steady"] = ranked[1]  # median phase by tail ratio
    doc["steady_phases"] = phases
    # misses in ANY phase gate: the jit-stability contract has no noise
    doc["steady"]["jit_misses_after_warmup"] = sum(
        s["jit_misses_after_warmup"] for s in phases)

    # -- overload burst: full-speed flood past the queue limit --------------
    burst_tickets = []
    burst_rejected = 0
    burst_peak = 0
    for i in range(overload_requests):
        try:
            burst_tickets.append(
                svc.color(graphs[i % len(graphs)], wait=False))
        except Overloaded as e:
            burst_rejected += 1
            burst_peak = max(burst_peak, e.queue_depth)
    for t in burst_tickets:
        t.wait(120)
    doc["overload"] = {
        "submitted": overload_requests,
        "completed": len(burst_tickets),
        "rejected": burst_rejected,
        "rejection_rate": round(burst_rejected / max(overload_requests, 1),
                                4),
        "queue_peak": max(burst_peak, svc.metrics()["queue_depth"]),
        "queue_limit": queue_limit,
    }

    final = svc.metrics()
    svc.shutdown()
    doc["metrics"] = {
        k: final[k] for k in
        ("admitted", "rejected", "completed", "failed", "evictions",
         "spills", "restores", "maintenance", "microbatches",
         "batched_requests", "slow_requests", "bucket_jit_hits",
         "bucket_jit_misses", "session_engine_cache_hits",
         "session_engine_cache_misses", "pool_occupancy")}
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", choices=sorted(SCALE_PRESETS), default=None,
                    help="preset for the serving_mix graph sizes")
    ap.add_argument("--rate", type=float, default=None,
                    help="steady arrival rate in Hz (default: self-"
                         "calibrated to ~15%% of warmup capacity)")
    ap.add_argument("--requests", type=int, default=240,
                    help="steady-phase request count")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("-o", "--output", default=JSON_PATH)
    args = ap.parse_args()
    scale = SCALE_PRESETS[args.scale] if args.scale else float(
        os.environ.get("REPRO_BENCH_JSON_SCALE", "0.01"))

    serve = bench_serving(scale, steady_requests=args.requests,
                          rate_hz=args.rate, seed=args.seed)
    doc = {"schema": 9, "scale": scale, "backend": "jax", "serve": serve}
    with open(args.output, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    s = serve["steady"]
    o = serve["overload"]
    print(f"steady: {s['requests']} reqs @ {s['rate_hz']} Hz  "
          f"p50 {s['p50_ms']} ms  p99 {s['p99_ms']} ms  "
          f"rejected {s['rejected']}  "
          f"jit misses after warmup {s['jit_misses_after_warmup']}")
    print(f"overload: {o['rejected']}/{o['submitted']} rejected "
          f"(queue peak {o['queue_peak']}/{o['queue_limit']})")
    print(f"wrote {args.output}")


if __name__ == "__main__":
    main()
