"""Run every paper benchmark. Prints ``name,us_per_call,derived`` CSV.

Scale via REPRO_BENCH_SCALE (default 0.15); see benchmarks/common.py.
The roofline table (§Roofline) is separate: ``python -m benchmarks.roofline``
consumes the dry-run JSON produced by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.paper import ALL_BENCHES

    print("name,us_per_call,derived", flush=True)
    for bench in ALL_BENCHES:
        t0 = time.time()
        try:
            rows = bench()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{bench.__name__},0,ERROR:{type(e).__name__}:{e}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {bench.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
