"""Run every paper benchmark. Prints ``name,us_per_call,derived`` CSV and
writes ``BENCH_coloring.json`` — the machine-readable perf trajectory.

Scale via ``--scale {tiny,small,paper}`` or REPRO_BENCH_SCALE (default 0.15);
see benchmarks/common.py.  The roofline table (§Roofline) is separate:
``python -m benchmarks.roofline`` consumes the dry-run JSON produced by
``repro.launch.dryrun``.

``BENCH_coloring.json`` records per-algorithm colors + wall-clock on a small
fixed suite (REPRO_BENCH_JSON_SCALE, default 0.02) so CI and future PRs can
diff quality/perf without parsing the CSV.  Timing method (schema 2+):
``seconds`` is the MEDIAN of post-warmup calls and ``compile_seconds`` the
separately-measured one-time jit cost — single-shot numbers used to charge
compilation to the algorithm.  ``--json-only`` skips the CSV matrix.

Schema 3 adds ``--engine {ragged,padded,classic,sharded}``: the chosen
engine is threaded through the algorithms that take one (``data_driven``,
``fused``; ``distance2`` for ragged/sharded), the document carries a
top-level ``engine`` field plus per-record ``engine`` /
``halo_bytes_per_step`` (§13 halo traffic; 0 off the sharded engine).  Run
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise
``sharded`` on simulated devices — CI's sharded bench-smoke artifact is
``BENCH_coloring_sharded.json``.  ``--engine sharded`` REFUSES to run on a
single-device host (the engine would silently fall back to ``ragged`` and
the recorded numbers would come from the wrong engine).

Schema 4 adds ``--engine dynamic`` (§14): instead of the algorithm matrix
the document carries a ``dynamic`` section of churn records — per suite
graph, incremental ``session.recolor()`` vs cold re-color work/wall under
1% streaming edge churn (``benchmarks/dynamic.py``).  CI's artifact is
``BENCH_coloring_dynamic.json``; ``benchmarks/check_regression.py`` gates
every produced document against ``benchmarks/baseline_tiny.json``.

Schema 5 adds ``--backend {jax,pallas}`` (§15): the chosen backend is
threaded through the algorithms that take one (``data_driven``, ``fused``,
``distance2``, ``dynamic``), the document carries a top-level ``backend``
field, and every record whose result reports per-degree-class work counters
(``ColoringResult.class_cells``) embeds a ``roofline`` section — bytes
moved and achieved bytes/s per degree class (``benchmarks/roofline.py``'s
coloring model).  Colors are bit-identical across backends, so the pallas
document gates against the SAME baseline; CI's artifact is
``BENCH_coloring_pallas.json``.

Schema 6 adds the §16 telemetry: every record of an algorithm that takes
the ``trace=`` knob (``BACKEND_ALGS``) carries a ``trace`` section — the
``RunTrace.summary()`` per-step series (live/retired/conflicts/max_color/
cells), superstep count, and tail-trigger step — captured from one extra
UNTIMED traced call so the timed numbers stay on the untraced (bit-
identical, zero-cost) path.  ``--engine dynamic`` records gain
``rounds_detail`` (per churn round: frontier, work, supersteps, tail step,
jit cache hit) and a ``jit`` hits/misses section from
``session.metrics()``.  Alongside the document a Chrome-trace
(Perfetto-loadable) export of the same runs is written to
``<JSON_PATH stem>_trace.json`` (so CI's ``BENCH_coloring*.json`` artifact
glob picks it up); ``python -m repro.obs.report <either file>`` re-renders
both.  ``benchmarks/check_regression.py`` gates the new sections: missing
trace, superstep-count regressions, earlier tail triggers, broken row
invariants, and dynamic jit-miss growth all fail CI.

Schema 8 adds ``--backend pallas-csr`` (§18, the CSR-resident fused
kernel) and an honest per-backend roofline traffic model: the legacy
``pallas`` backend is charged its REAL traffic — the host-side gather
materializes split-size tiles in HBM and the kernel reads them back
(24 B/cell) — while ``pallas-csr`` gathers id + packed word straight
from the CSR arrays (8 B/cell).  Every roofline class entry now carries
its own ``bytes_per_cell`` and the section a ``mode`` field, so the
pallas vs pallas-csr delta is visible per degree class.  Colors stay
bit-identical across all backends; CI's artifact is
``BENCH_coloring_pallas_csr.json``, gated against the same baseline.
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # so `python benchmarks/run.py` finds `benchmarks.*`

JSON_PATH = os.environ.get("REPRO_BENCH_JSON", "BENCH_coloring.json")
JSON_GRAPHS = ("rmat-er", "rmat-g", "G3_circuit", "europe.osm", "thermal2")

# --scale presets: (CSV-matrix scale, JSON-suite scale).  ``tiny`` is the CI
# smoke configuration — its JSON scale is pinned at 0.01 so the uploaded
# BENCH_coloring.json artifacts stay comparable across CI runs (the file
# itself is a generated artifact, gitignored); ``paper`` matches the default
# full matrix.
SCALE_PRESETS = {
    "tiny": (0.02, 0.01),
    "small": (0.05, 0.02),
    "paper": (0.15, 0.02),
}


def _engine_opts(alg: str, engine: str) -> dict:
    """The engine kwargs ``alg`` understands (empty when it takes none)."""
    if alg in ("data_driven", "fused"):
        return {"engine": engine}
    if alg == "distance2" and engine in ("ragged", "sharded"):
        return {"engine": engine}
    return {}


# algorithms that accept the §15 backend= knob (kernel vs pure-JAX superstep)
BACKEND_ALGS = ("data_driven", "fused", "distance2", "dynamic")
BACKENDS = ("jax", "pallas", "pallas-csr")

# roofline traffic model per backend (schema 8): the gathered-tile pallas
# path materializes split tiles in HBM and reads them back; the CSR kernel
# reads id + packed word once from R/C; pure JAX uses the packed gather
_ROOFLINE_MODE = {"pallas": "pallas", "pallas-csr": "csr"}


def _backend_opts(alg: str, backend: str) -> dict:
    """The backend kwarg for ``alg`` (empty when it takes none)."""
    return {"backend": backend} if alg in BACKEND_ALGS else {}


def bench_coloring_json(path: str = JSON_PATH, engine: str = "ragged",
                        backend: str = "jax") -> dict:
    """Per-algorithm colors + wall-clock on the small suite, as JSON."""
    from benchmarks.common import timeit_median
    from benchmarks.roofline import coloring_roofline
    from repro import api
    from repro.core import is_valid_coloring
    from repro.d2 import compress_jacobian_pattern, validate_bipartite
    from repro.graphs import build_graph, jacobian_band

    json_scale = float(os.environ.get("REPRO_BENCH_JSON_SCALE", "0.02"))
    graphs = {name: build_graph(name, json_scale) for name in JSON_GRAPHS}
    doc = {
        "schema": 8,
        "scale": json_scale,
        "engine": engine,
        "backend": backend,
        "graphs": {
            name: {"n": g.n, "m": g.m, "max_degree": g.max_degree}
            for name, g in graphs.items()
        },
        "algorithms": {},
        "bipartite": {},
    }
    chrome_runs = {}
    for alg in api.algorithms():
        if alg == "bipartite":  # needs a BipartiteGraph; measured below
            continue
        opts = {**_engine_opts(alg, engine), **_backend_opts(alg, backend)}
        per_graph = {}
        for name, g in graphs.items():
            try:
                seconds, compile_s, r = timeit_median(
                    lambda: api.color(g, algorithm=alg, **opts))
            except Exception as e:  # keep the harness going
                per_graph[name] = {"error": f"{type(e).__name__}: {e}"}
                continue
            rec = {
                "colors": r.num_colors,
                "seconds": round(seconds, 6),
                "compile_seconds": round(compile_s, 6),
                "iterations": r.iterations,
                "valid": bool(is_valid_coloring(g, r.colors)),
                "engine": opts.get("engine", "-"),
                "backend": opts.get("backend", "-"),
                "halo_bytes_per_step": round(
                    getattr(r, "halo_bytes_per_step", 0.0), 1),
                # §17 robustness ledger: non-empty means the run left the
                # clean fast path; the CI gate fails on unexpected stages
                "degradations": [dict(d) for d in
                                 getattr(r, "degradations", ())],
            }
            if getattr(r, "class_cells", ()):
                rec["roofline"] = coloring_roofline(
                    r, seconds, mode=_ROOFLINE_MODE.get(backend, "packed"))
            if alg in BACKEND_ALGS:
                # one extra UNTIMED traced call (schema 6): the timed
                # numbers above stay on the untraced zero-cost path
                rt = api.color(g, algorithm=alg, trace=True, **opts).trace
                if rt is not None:
                    rec["trace"] = rt.summary()
                    chrome_runs[f"{alg}/{name}"] = rt
            per_graph[name] = rec
        doc["algorithms"][alg] = per_graph
    band = 2
    bg = jacobian_band(int(20000 * json_scale) or 64, band=band)
    seconds, compile_s, cr = timeit_median(
        lambda: compress_jacobian_pattern(bg, mode="fused"))
    doc["bipartite"][f"banded_b{band}"] = {
        "groups": cr.num_groups,
        "optimal": 2 * band + 1,
        "seconds": round(seconds, 6),
        "compile_seconds": round(compile_s, 6),
        "valid": bool(validate_bipartite(bg, cr.coloring.colors)),
        "degradations": [dict(d) for d in
                         getattr(cr.coloring, "degradations", ())],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    if chrome_runs:
        _write_chrome_trace(path, chrome_runs)
    return doc


def _write_chrome_trace(json_path: str, runs: dict) -> str:
    """Perfetto-loadable sibling of a BENCH document (schema 6).

    Named ``<stem>_trace.json`` so CI's ``BENCH_coloring*.json`` artifact
    glob uploads it alongside the document it mirrors.
    """
    from repro.obs.export import export_chrome_trace

    stem = json_path[:-5] if json_path.endswith(".json") else json_path
    trace_path = f"{stem}_trace.json"
    export_chrome_trace(trace_path, runs)
    print(f"# wrote {trace_path} ({len(runs)} traced runs)", file=sys.stderr)
    return trace_path


ENGINES = ("ragged", "padded", "classic", "sharded", "dynamic")


def bench_dynamic_json_doc(path: str = JSON_PATH,
                           backend: str = "jax") -> dict:
    """The ``--engine dynamic`` document: §14 churn records, no matrix."""
    from benchmarks.dynamic import bench_dynamic_json

    json_scale = float(os.environ.get("REPRO_BENCH_JSON_SCALE", "0.02"))
    records, runs = bench_dynamic_json(json_scale, backend=backend)
    doc = {
        "schema": 8,
        "scale": json_scale,
        "engine": "dynamic",
        "backend": backend,
        "dynamic": records,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    if runs:
        _write_chrome_trace(path, runs)
    return doc


def main() -> None:
    args = sys.argv[1:]
    if "--scale" in args:
        tail = args[args.index("--scale") + 1:]
        preset = tail[0] if tail else None
        if preset not in SCALE_PRESETS:
            raise SystemExit(
                f"unknown --scale {preset!r}; options: {sorted(SCALE_PRESETS)}")
        csv_scale, json_scale = SCALE_PRESETS[preset]
        # set BEFORE benchmarks.common/paper are imported (they read at import)
        os.environ["REPRO_BENCH_SCALE"] = str(csv_scale)
        os.environ["REPRO_BENCH_JSON_SCALE"] = str(json_scale)
    engine = "ragged"
    if "--engine" in args:
        tail = args[args.index("--engine") + 1:]
        engine = tail[0] if tail else None
        if engine not in ENGINES:
            raise SystemExit(
                f"unknown --engine {engine!r}; options: {list(ENGINES)}")
    backend = "jax"
    if "--backend" in args:
        tail = args[args.index("--backend") + 1:]
        backend = tail[0] if tail else None
        if backend not in BACKENDS:
            raise SystemExit(
                f"unknown --backend {backend!r}; options: {list(BACKENDS)}")
    if engine == "sharded":
        # the api would silently fall back to the single-device ragged
        # engine — refuse instead, so recorded bench numbers can never come
        # from the wrong engine (CI forces a simulated fleet via XLA_FLAGS)
        import jax

        if jax.device_count() <= 1:
            raise SystemExit(
                "--engine sharded needs a multi-device host but only "
                f"{jax.device_count()} device is visible; run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8 (or on "
                "real multi-device hardware) so the sharded engine actually "
                "executes instead of falling back to ragged")
    json_only = "--json-only" in args
    if not json_only:
        from benchmarks.d2 import D2_BENCHES
        from benchmarks.dynamic import DYNAMIC_BENCHES
        from benchmarks.paper import ALL_BENCHES

        print("name,us_per_call,derived", flush=True)
        for bench in (list(ALL_BENCHES) + list(D2_BENCHES)
                      + list(DYNAMIC_BENCHES)):
            t0 = time.time()
            try:
                rows = bench()
            except Exception as e:  # keep the harness going; report the failure
                print(f"{bench.__name__},0,ERROR:{type(e).__name__}:{e}")
                continue
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
            print(f"# {bench.__name__} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
    if engine == "dynamic":
        bench_dynamic_json_doc(backend=backend)
    else:
        bench_coloring_json(engine=engine, backend=backend)
    print(f"# wrote {JSON_PATH} (engine={engine}, backend={backend})",
          file=sys.stderr)


if __name__ == "__main__":
    main()
