"""CI quality/perf regression gate over ``BENCH_coloring*.json`` documents.

Before this gate, the bench-smoke CI step only ``cat``-ed the JSON — an
invalid coloring or a color-count regression sailed through green.  Now:

    python benchmarks/check_regression.py BENCH_coloring.json [more.json ...] \
        --baseline benchmarks/baseline_tiny.json

fails (exit 1) when any produced record

* carries ``"valid": false`` — a broken coloring is never acceptable;
* carries an ``"error"`` — an algorithm that crashed used to pass silently;
* uses MORE colors (or Jacobian ``groups``) than the checked-in baseline
  records for the same (algorithm, graph) — quality regression;
* is a ``dynamic`` churn record whose ``work_ratio`` falls below the
  baseline's ``min_work_ratio`` floor — the §14 frontier-proportionality
  guarantee regressed to n-proportional work;
* is a schema-5 document missing its ``backend`` field, or carries a
  ``roofline`` section whose per-class bytes are non-positive, fail to sum
  to ``bytes_total``, or report a non-positive achieved bytes/s — the §15
  bytes-moved model drifted from the engine's work accounting;
* is a schema-6 record of a traced algorithm (``TRACED_ALGS``) missing its
  ``trace`` section, or carrying one with mismatched series lengths,
  negative entries, or rows violating ``retired + conflicts == live`` —
  the §16 telemetry substrate broke;
* records MORE supersteps than the baseline for the same (algorithm,
  graph), or triggers the serial tail EARLIER (``tail_step`` with ``-1``
  meaning never) — the convergence schedule regressed;
* is a schema-6 ``dynamic`` record whose ``jit.misses`` exceeds the
  baseline's ``max_jit_misses`` — the §14/§15 jit-cache-stability
  contract (pow2-padded shapes keep churn rounds on compiled code)
  regressed to per-round retracing;
* carries a non-empty ``degradations`` list (schema 7, §17) whose stages
  are not whitelisted by the baseline record's ``allowed_degradations``
  — a bench run that silently left the clean fast path (ingest repairs
  firing on a supposedly-clean suite graph, or the guarantee ladder
  escalating a run that should converge on its own) is a robustness
  regression even when the colors come out right;
* is a schema-9 ``serve`` document (``benchmarks/serve.py``, §19) whose
  steady phase shows tail-latency blowup (``p99_ms`` above the
  baseline's ``max_p99_over_p50`` × ``p50_ms``), sheds load at steady
  rate (rejection rate above ``max_steady_rejection_rate``), or leaves
  the jit cache after warmup (``jit_misses_after_warmup`` above
  ``max_jit_misses_after_warmup`` — the §19 bucketed micro-batching
  contract); or whose overload burst FAILED to produce structured
  rejections / let the queue grow past its limit — backpressure that
  does not reject under flood is an unbounded queue.

Color comparisons only apply when the document's ``scale`` matches the
baseline's (the weekly ``--scale small`` run still gets validity/error
checking); records missing from the baseline are reported as notes, not
failures, so adding an algorithm never blocks CI.  Refresh the baseline
after an intentional quality change with::

    python benchmarks/check_regression.py --write-baseline \
        BENCH_coloring.json BENCH_coloring_dynamic.json \
        -o benchmarks/baseline_tiny.json

Pure stdlib (no jax/numpy) so the gate itself is unit-testable in
milliseconds (``tests/test_regression_gate.py``).
"""
from __future__ import annotations

import json
import sys

DEFAULT_BASELINE = "benchmarks/baseline_tiny.json"
MIN_WORK_RATIO = 3.0  # conservative CI floor; the §14 test asserts >= 5
# schema-9 serving gates (§19); the baseline's "serve" entry can override
MAX_P99_OVER_P50 = 3.0
MAX_STEADY_REJECTION_RATE = 0.02
MAX_JIT_MISSES_AFTER_WARMUP = 0
# algorithms whose schema-6 records must carry a trace section (mirrors
# benchmarks/run.py BACKEND_ALGS; hardcoded to keep this gate stdlib-only)
TRACED_ALGS = ("data_driven", "fused", "distance2", "dynamic")
_TRACE_SERIES = ("live", "retired", "conflicts", "max_color", "cells")


def _check_trace_section(where: str, t: dict, fails: list[str]) -> None:
    """Schema/row-invariant integrity of one record's ``trace`` section."""
    missing = [k for k in _TRACE_SERIES + ("supersteps", "tail_step",
                                           "series_from") if k not in t]
    if missing:
        fails.append(f"{where}: trace section missing {missing}")
        return
    lens = {k: len(t[k]) for k in _TRACE_SERIES}
    if len(set(lens.values())) > 1:
        fails.append(f"{where}: trace series lengths differ: {lens}")
        return
    if t["supersteps"] < 0 or (t["live"] and t["supersteps"] == 0):
        fails.append(f"{where}: trace supersteps {t['supersteps']} "
                     "inconsistent with non-empty series")
    for i, (li, re, co) in enumerate(zip(t["live"], t["retired"],
                                         t["conflicts"])):
        if li < 0 or re < 0 or co < 0:
            fails.append(f"{where}: trace row {i} has a negative entry")
            break
        if re + co != li:
            fails.append(
                f"{where}: trace row {i} breaks retired + conflicts == live "
                f"({re} + {co} != {li})")
            break


def _tail_norm(step) -> float:
    """Tail-trigger step ordered for regression checks: -1 (never) sorts
    as +inf, so 'tail now fires where it previously never did' and 'tail
    fires earlier than before' both compare as regressions."""
    return float("inf") if step is None or step < 0 else float(step)


def check(doc: dict, baseline: dict) -> tuple[list[str], list[str]]:
    """(failures, notes) for one produced BENCH document vs the baseline."""
    fails: list[str] = []
    notes: list[str] = []
    same_scale = doc.get("scale") == baseline.get("scale")
    if not same_scale:
        notes.append(
            f"scale {doc.get('scale')} != baseline {baseline.get('scale')}: "
            "validity checked, color counts not compared")
    if doc.get("schema", 0) >= 5 and "backend" not in doc:
        fails.append("schema-5 document missing its 'backend' field")

    def roofline_ok(where: str, rec: dict):
        rl = rec.get("roofline")
        if rl is None:
            return
        total = rl.get("bytes_total", 0)
        if total <= 0:
            fails.append(f"{where}: roofline bytes_total {total} <= 0")
            return
        by_class = sum(c.get("bytes", 0) for c in rl.get("classes", []))
        if by_class != total:
            fails.append(
                f"{where}: roofline class bytes sum {by_class} != "
                f"bytes_total {total}")
        if any(c.get("bytes", 0) <= 0 for c in rl.get("classes", [])):
            fails.append(f"{where}: roofline class with bytes <= 0")
        if "achieved_bytes_per_s" in rl and rl["achieved_bytes_per_s"] <= 0:
            fails.append(
                f"{where}: roofline achieved_bytes_per_s "
                f"{rl['achieved_bytes_per_s']} <= 0")

    def quality(kind: str, alg: str, name: str, rec: dict, field: str,
                base_rec: dict | None):
        where = f"{kind} {alg + '/' if alg else ''}{name}"
        if "error" in rec:
            fails.append(f"{where}: errored: {rec['error']}")
            return
        if rec.get("valid") is False:
            fails.append(f"{where}: INVALID coloring")
        degr = rec.get("degradations") or []
        if degr:
            allowed = set((base_rec or {}).get("allowed_degradations", []))
            stages = sorted({d.get("stage", "?") for d in degr})
            unexpected = [s for s in stages if s not in allowed]
            if unexpected:
                fails.append(
                    f"{where}: unexpected degradations {unexpected} — the "
                    "run left the §17 clean fast path (whitelist via "
                    "'allowed_degradations' in the baseline if intentional)")
        roofline_ok(where, rec)
        if base_rec is None:
            if same_scale:
                notes.append(f"{where}: not in baseline (new?)")
            return
        if same_scale and field in rec and field in base_rec:
            if rec[field] > base_rec[field]:
                fails.append(
                    f"{where}: {field} regressed "
                    f"{base_rec[field]} -> {rec[field]}")

    schema6 = doc.get("schema", 0) >= 6
    for alg, per_graph in doc.get("algorithms", {}).items():
        base_alg = baseline.get("algorithms", {}).get(alg, {})
        for name, rec in per_graph.items():
            quality("algorithm", alg, name, rec, "colors",
                    base_alg.get(name))
            if not schema6 or "error" in rec:
                continue
            where = f"algorithm {alg}/{name}"
            t = rec.get("trace")
            if alg in TRACED_ALGS and t is None:
                fails.append(f"{where}: schema-6 record of a traced "
                             "algorithm missing its 'trace' section")
            elif t is not None:
                _check_trace_section(where, t, fails)
            base_rec = base_alg.get(name)
            if t and base_rec and same_scale:
                if ("supersteps" in base_rec
                        and t.get("supersteps", 0) > base_rec["supersteps"]):
                    fails.append(
                        f"{where}: supersteps regressed "
                        f"{base_rec['supersteps']} -> {t['supersteps']}")
                if ("tail_step" in base_rec
                        and _tail_norm(t.get("tail_step"))
                        < _tail_norm(base_rec["tail_step"])):
                    fails.append(
                        f"{where}: serial tail triggers at step "
                        f"{t.get('tail_step')} (baseline "
                        f"{base_rec['tail_step']}; earlier = more "
                        "serialized work)")
    for name, rec in doc.get("bipartite", {}).items():
        quality("bipartite", "", name, rec, "groups",
                baseline.get("bipartite", {}).get(name))
    for name, rec in doc.get("dynamic", {}).items():
        base_rec = baseline.get("dynamic", {}).get(name)
        quality("dynamic", "", name, rec, "colors", base_rec)
        floor = (base_rec or {}).get("min_work_ratio", MIN_WORK_RATIO)
        if "work_ratio" in rec and rec["work_ratio"] < floor:
            fails.append(
                f"dynamic {name}: work_ratio {rec['work_ratio']} below the "
                f"frontier-proportionality floor {floor}")
        if schema6 and "error" not in rec:
            if "rounds_detail" not in rec or "jit" not in rec:
                fails.append(
                    f"dynamic {name}: schema-6 record missing its "
                    "rounds_detail/jit sections")
            else:
                cap = (base_rec or {}).get("max_jit_misses")
                misses = rec["jit"].get("misses", 0)
                if cap is not None and misses > cap:
                    fails.append(
                        f"dynamic {name}: jit misses {misses} exceed the "
                        f"baseline cap {cap} — churn rounds are retracing "
                        "instead of hitting the jit cache")
    _check_serve(doc, baseline, fails)
    return fails, notes


def _check_serve(doc: dict, baseline: dict, fails: list[str]) -> None:
    """Schema-9 serving gates (§19): latency, backpressure, jit stability."""
    serve = doc.get("serve")
    if serve is None:
        return
    base = baseline.get("serve", {})
    steady = serve.get("steady")
    if steady is None:
        fails.append("serve: document missing its 'steady' section")
    else:
        ratio_cap = base.get("max_p99_over_p50", MAX_P99_OVER_P50)
        p50 = steady.get("p50_ms", 0)
        p99 = steady.get("p99_ms", 0)
        if p50 <= 0:
            fails.append(f"serve steady: p50_ms {p50} <= 0 (no latencies?)")
        elif p99 > ratio_cap * p50:
            fails.append(
                f"serve steady: p99 {p99} ms exceeds {ratio_cap} x p50 "
                f"({p50} ms) — tail latency blowup (queueing discipline "
                "or inline maintenance regressed)")
        rej_cap = base.get("max_steady_rejection_rate",
                           MAX_STEADY_REJECTION_RATE)
        if steady.get("rejection_rate", 0) > rej_cap:
            fails.append(
                f"serve steady: rejection rate {steady['rejection_rate']} "
                f"above {rej_cap} at the calibrated steady rate — the "
                "service sheds load it should absorb")
        miss_cap = base.get("max_jit_misses_after_warmup",
                            MAX_JIT_MISSES_AFTER_WARMUP)
        misses = steady.get("jit_misses_after_warmup", 0)
        if misses > miss_cap:
            fails.append(
                f"serve steady: {misses} micro-batch jit misses after "
                f"warmup (cap {miss_cap}) — steady-state traffic left the "
                "jit cache (§19 bucketing contract)")
        submitted = steady.get("submitted", 0)
        if steady.get("completed", 0) + steady.get("rejected", 0) != submitted:
            fails.append(
                "serve steady: completed + rejected != submitted — "
                "requests were lost")
    over = serve.get("overload")
    if over is None:
        fails.append("serve: document missing its 'overload' section")
    else:
        if over.get("rejected", 0) <= 0:
            fails.append(
                "serve overload: the burst produced NO Overloaded "
                "rejections — backpressure is not engaging (unbounded "
                "queue growth)")
        limit = over.get("queue_limit", 0)
        if limit and over.get("queue_peak", 0) > limit:
            fails.append(
                f"serve overload: queue peaked at {over['queue_peak']} "
                f"past its limit {limit} — the bound is not enforced")


def make_baseline(docs: list[dict]) -> dict:
    """Distill produced documents into the checked-in baseline shape."""
    out: dict = {"schema": 9, "scale": None, "algorithms": {},
                 "bipartite": {}, "dynamic": {}}
    for doc in docs:
        out["scale"] = doc.get("scale", out["scale"])
        if "serve" in doc:
            # accept the observed warmup behaviour; the latency/rejection
            # bounds stay at the conservative module defaults
            misses = (doc["serve"].get("steady", {})
                      .get("jit_misses_after_warmup", 0))
            out["serve"] = {
                "max_p99_over_p50": MAX_P99_OVER_P50,
                "max_steady_rejection_rate": MAX_STEADY_REJECTION_RATE,
                "max_jit_misses_after_warmup": max(
                    misses, MAX_JIT_MISSES_AFTER_WARMUP),
            }
        for alg, per_graph in doc.get("algorithms", {}).items():
            slot = out["algorithms"].setdefault(alg, {})
            for name, rec in per_graph.items():
                if "colors" not in rec:
                    continue
                slot[name] = {"colors": rec["colors"]}
                t = rec.get("trace")
                if t and "supersteps" in t:
                    slot[name]["supersteps"] = t["supersteps"]
                    slot[name]["tail_step"] = t.get("tail_step", -1)
                degr = rec.get("degradations") or []
                if degr:
                    # --write-baseline is the explicit acceptance action:
                    # stages present in the accepted run become the whitelist
                    slot[name]["allowed_degradations"] = sorted(
                        {d.get("stage", "?") for d in degr})
        for name, rec in doc.get("bipartite", {}).items():
            if "groups" in rec:
                out["bipartite"][name] = {"groups": rec["groups"]}
        for name, rec in doc.get("dynamic", {}).items():
            if "colors" in rec:
                out["dynamic"][name] = {
                    "colors": rec["colors"],
                    "min_work_ratio": MIN_WORK_RATIO,
                }
                if "jit" in rec:
                    out["dynamic"][name]["max_jit_misses"] = (
                        rec["jit"].get("misses", 0))
    return out


def main(argv: list[str]) -> int:
    args = list(argv)
    write = "--write-baseline" in args
    if write:
        args.remove("--write-baseline")
    out_path = DEFAULT_BASELINE
    if "-o" in args:
        i = args.index("-o")
        out_path = args[i + 1]
        del args[i : i + 2]
    baseline_path = DEFAULT_BASELINE
    if "--baseline" in args:
        i = args.index("--baseline")
        baseline_path = args[i + 1]
        del args[i : i + 2]
    if not args:
        print(__doc__)
        return 2
    docs = []
    for path in args:
        with open(path) as f:
            docs.append((path, json.load(f)))
    if write:
        baseline = make_baseline([d for _, d in docs])
        with open(out_path, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {out_path} from {len(docs)} document(s)")
        return 0
    with open(baseline_path) as f:
        baseline = json.load(f)
    bad = False
    for path, doc in docs:
        fails, notes = check(doc, baseline)
        for msg in notes:
            print(f"NOTE  {path}: {msg}")
        for msg in fails:
            print(f"FAIL  {path}: {msg}")
        if fails:
            bad = True
        else:
            print(f"OK    {path}: no regressions vs {baseline_path}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
