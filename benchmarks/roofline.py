"""Roofline analysis: coloring bytes-moved model + dry-run HLO table.

Two halves share the hardware constants:

**Coloring model** (§15) — the SGR super-step is gather-bound: it does a
few integer compares per gathered cell, so the roofline that matters is
HBM bytes/s, not FLOPs.  ``coloring_roofline`` turns a ``ColoringResult``'s
per-degree-class work counters (``class_cells``: gather cells dispatched at
each tile width, the serial tail included as a final full-width entry) into
bytes moved per class, and — given the measured wall-clock — achieved
bytes/s vs the platform peak.  Bytes per gather cell:

  packed (``pack_degrees`` on, the default): 4B neighbor id + 4B packed
      ``color | degree << 16`` word                            =  8 B/cell
  split (packing gated off): 4B id + 4B color + 4B degree      = 12 B/cell
  pallas (gathered-tile kernel): the split tiles are materialized in HBM
      by the host-side gather AND read back by the kernel     = 24 B/cell
  csr (CSR-resident kernel, §18): the kernel gathers id + packed word
      straight from R/C into VMEM — no intermediate tile      =  8 B/cell

This replaces the previous drift where the file carried only LM-training
constants and nothing fed from the coloring engines; ``benchmarks/run.py
--backend pallas`` embeds the model's output in BENCH schema-5 records,
and schema-8 records carry the per-class ``bytes_per_cell`` so the
pallas vs pallas-csr delta is visible per degree class.

**Dry-run table** — three terms per (arch x shape x mesh) cell, in seconds
per step, from the trip-count-corrected HLO analysis
(launch/hlo_analysis.py) of the SPMD-partitioned per-device module:
  compute   = HLO_FLOPs_per_device / peak_FLOPs            (197 TF/s bf16)
  memory    = HBM_traffic_per_device / HBM_bw              (819 GB/s)
  collective= collective_bytes_per_device / ICI_link_bw    (50 GB/s/link)
``useful_flops_ratio`` = analytic model FLOPs / (HLO flops x chips): <1
means remat/padding/attention overhead.  These constants are TPU v5e and
apply ONLY to this table and to ``PEAK_BYTES_PER_S["tpu_v5e"]``.
"""
from __future__ import annotations

import json

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip (TPU v5e)
ICI_BW = 50e9              # bytes/s per link (TPU v5e)

CHIPS = {"single": 256, "pod": 512}

# bytes one gather cell moves through the rotated super-step (§12/§15/§18)
BYTES_PER_CELL_PACKED = 8    # neighbor id + packed color|deg<<16 word
BYTES_PER_CELL_SPLIT = 12    # neighbor id + color + degree, separately
# the gathered-tile Pallas path materializes the three split tiles in HBM
# (host-side gather writes them) and the kernel reads them back: 2x split
BYTES_PER_CELL_PALLAS = 2 * BYTES_PER_CELL_SPLIT
# the CSR-resident kernel (§18) reads id + packed word once, from R/C
BYTES_PER_CELL_CSR = 8

_MODE_BYTES = {
    "packed": BYTES_PER_CELL_PACKED,
    "split": BYTES_PER_CELL_SPLIT,
    "pallas": BYTES_PER_CELL_PALLAS,
    "csr": BYTES_PER_CELL_CSR,
}

# peak HBM bytes/s per platform; None = unknown (no frac_of_peak reported)
PEAK_BYTES_PER_S = {"tpu_v5e": HBM_BW, "tpu": HBM_BW, "cpu": None}

__all__ = ["roofline_terms", "coloring_roofline", "load_table",
           "format_table", "main", "BYTES_PER_CELL_PACKED",
           "BYTES_PER_CELL_SPLIT", "BYTES_PER_CELL_PALLAS",
           "BYTES_PER_CELL_CSR", "PEAK_BYTES_PER_S"]


def coloring_roofline(result, seconds: float | None = None, *,
                      peak_bytes_per_s: float | None = None,
                      packed: bool = True, mode: str | None = None) -> dict:
    """Per-degree-class bytes-moved model from ``ColoringResult`` counters.

    ``result`` needs only ``class_cells`` (and is duck-typed so benchmark
    records can replay saved counters).  ``seconds`` is the measured
    wall-clock of the run; when given, each class reports its achieved
    bytes/s contribution and the document carries the total achieved vs
    ``peak_bytes_per_s`` (``frac_of_peak``; omitted when the peak is
    unknown, e.g. CPU).  ``mode`` picks the traffic model per cell —
    ``"packed"`` / ``"split"`` (pure JAX), ``"pallas"`` (gathered-tile
    kernel: the split tiles are written to HBM and read back, 2x split) or
    ``"csr"`` (CSR-resident kernel, one id + packed-word read).  ``None``
    defers to the legacy ``packed`` flag.
    """
    if mode is None:
        mode = "packed" if packed else "split"
    if mode not in _MODE_BYTES:
        raise ValueError(
            f"unknown roofline mode {mode!r}; options: {', '.join(_MODE_BYTES)}")
    per_cell = _MODE_BYTES[mode]
    class_cells = tuple(getattr(result, "class_cells", result))
    classes = []
    for width, cells in class_cells:
        entry = {"width": int(width), "cells": int(cells),
                 "bytes_per_cell": per_cell,
                 "bytes": int(cells) * per_cell}
        classes.append(entry)
    total = sum(c["bytes"] for c in classes)
    out = {
        "mode": mode,
        "bytes_per_cell": per_cell,
        "bytes_total": total,
        "classes": classes,
    }
    if seconds is not None and seconds > 0:
        for c in classes:
            c["achieved_bytes_per_s"] = c["bytes"] / seconds
        out["seconds"] = seconds
        out["achieved_bytes_per_s"] = total / seconds
        if peak_bytes_per_s:
            out["peak_bytes_per_s"] = peak_bytes_per_s
            out["frac_of_peak"] = (total / seconds) / peak_bytes_per_s
    return out


def roofline_terms(rec: dict) -> dict:
    a = rec["analysis"]
    chips = CHIPS.get(rec.get("mesh", "single"), 256)
    compute_s = a["flops"] / PEAK_FLOPS
    memory_s = a["traffic_bytes"] / HBM_BW
    collective_s = a["collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).split("_")[0]
    step_s = max(terms.values())
    useful = rec.get("model_flops", 0.0) / max(a["flops"] * chips, 1.0)
    # achieved fraction of the bottleneck roofline if the step ran at the
    # max-term bound with perfect overlap of the other two terms
    mfu = rec.get("model_flops", 0.0) / (step_s * chips * PEAK_FLOPS) \
        if step_s > 0 else 0.0
    return {
        **terms,
        "bottleneck": bottleneck,
        "step_s_bound": step_s,
        "useful_flops_ratio": useful,
        "model_mfu_bound": mfu,
    }


def load_table(path: str) -> list[dict]:
    with open(path) as f:
        records = json.load(f)
    rows = []
    for rec in records:
        if not rec.get("ok") or rec.get("skipped"):
            continue
        if "analysis" not in rec:
            continue
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            **roofline_terms(rec),
            "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'MFU':>6s} {'temp_GB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['bottleneck']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} {r['model_mfu_bound']:6.3f} "
            f"{r['temp_gb']:8.2f}"
        )
    return "\n".join(lines)


def main(path: str = "dryrun_results.json"):
    rows = load_table(path)
    print(format_table(rows))
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
