"""Roofline analysis from dry-run JSON records (TPU v5e constants).

Three terms per (arch x shape x mesh) cell, in seconds per step:
  compute   = HLO_FLOPs_per_device / peak_FLOPs            (197 TF/s bf16)
  memory    = HBM_traffic_per_device / HBM_bw              (819 GB/s)
  collective= collective_bytes_per_device / ICI_link_bw    (50 GB/s/link)

The per-device numbers come from the trip-count-corrected HLO analysis
(launch/hlo_analysis.py) of the SPMD-partitioned per-device module, so
"/(chips x peak)" in the task formula is already applied: the partitioned
module IS the 1/chips share.  ``useful_flops_ratio`` = analytic model FLOPs
(6*N*D train, 2*N*D serve) / (HLO flops x chips): <1 means remat/padding/
attention overhead, the waste the paper's §Roofline asks us to catch.
"""
from __future__ import annotations

import json

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

CHIPS = {"single": 256, "pod": 512}

__all__ = ["roofline_terms", "load_table", "format_table", "main"]


def roofline_terms(rec: dict) -> dict:
    a = rec["analysis"]
    chips = CHIPS.get(rec.get("mesh", "single"), 256)
    compute_s = a["flops"] / PEAK_FLOPS
    memory_s = a["traffic_bytes"] / HBM_BW
    collective_s = a["collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).split("_")[0]
    step_s = max(terms.values())
    useful = rec.get("model_flops", 0.0) / max(a["flops"] * chips, 1.0)
    # achieved fraction of the bottleneck roofline if the step ran at the
    # max-term bound with perfect overlap of the other two terms
    mfu = rec.get("model_flops", 0.0) / (step_s * chips * PEAK_FLOPS) \
        if step_s > 0 else 0.0
    return {
        **terms,
        "bottleneck": bottleneck,
        "step_s_bound": step_s,
        "useful_flops_ratio": useful,
        "model_mfu_bound": mfu,
    }


def load_table(path: str) -> list[dict]:
    with open(path) as f:
        records = json.load(f)
    rows = []
    for rec in records:
        if not rec.get("ok") or rec.get("skipped"):
            continue
        if "analysis" not in rec:
            continue
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            **roofline_terms(rec),
            "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'MFU':>6s} {'temp_GB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['bottleneck']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} {r['model_mfu_bound']:6.3f} "
            f"{r['temp_gb']:8.2f}"
        )
    return "\n".join(lines)


def main(path: str = "dryrun_results.json"):
    rows = load_table(path)
    print(format_table(rows))
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
