"""Distance-2 & bipartite benchmarks: colors + throughput vs the serial D2
oracle (DESIGN.md §11), the Jacobian-compression workload.

Rows follow the ``name,us_per_call,derived`` convention of ``run.py``;
``python -m benchmarks.d2`` runs just this file.  Quality numbers (colors)
are hardware-independent; runtimes are host wall-clock, so — as everywhere
in this suite — the oracle/engine *ratios* are the meaningful quantity.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # so `python benchmarks/d2.py` finds `benchmarks.*`

from benchmarks.common import SCALE, row, timeit
from repro.d2 import (
    color_bipartite,
    color_distance2,
    compress_jacobian_pattern,
    greedy_serial_bipartite,
    greedy_serial_d2,
    validate_bipartite,
    validate_d2,
)
from repro.graphs import build_graph, jacobian_band, jacobian_tall_skinny

# squares are much denser than the originals, so the D2 matrix runs a
# representative subset at a reduced scale
D2_GRAPHS = ("rmat-er", "G3_circuit", "europe.osm", "thermal2", "cage15")
D2_SCALE = SCALE * 0.25


def bench_d2_quality_speed():
    """Colors + speedup of the D2 engine vs the serial D2 oracle."""
    rows = []
    for name in D2_GRAPHS:
        g = build_graph(name, D2_SCALE)
        ts, oracle = timeit(lambda: greedy_serial_d2(g))
        te, r = timeit(lambda: color_distance2(g, mode="fused"))
        assert validate_d2(g, r.colors), name
        rows.append(row(f"d2/{name}/colors_serial", ts, int(oracle.max())))
        rows.append(row(f"d2/{name}/colors_sgr", te, r.num_colors))
        rows.append(row(f"d2/{name}/speedup", te, round(ts / te, 4)))
        rows.append(row(f"d2/{name}/iterations", te, r.iterations))
    return rows


def bench_d2_bipartite():
    """Jacobian compression: banded (known optimum) + tall-skinny patterns."""
    rows = []
    for band in (1, 3):
        bg = jacobian_band(int(20000 * D2_SCALE) or 64, band=band)
        ts, oracle = timeit(lambda: greedy_serial_bipartite(bg))
        te, r = timeit(lambda: color_bipartite(bg, mode="fused"))
        assert validate_bipartite(bg, r.colors)
        opt = 2 * band + 1
        rows.append(row(f"d2/banded_b{band}/colors_optimal", 0.0, opt))
        rows.append(row(f"d2/banded_b{band}/colors_serial", ts, int(oracle.max())))
        rows.append(row(f"d2/banded_b{band}/colors_sgr", te, r.num_colors))
    # n_cols² >> n_rows·nnz² keeps the conflict graph unsaturated, so the
    # compression ratio (not just validity) is exercised
    n_rows = int(60000 * D2_SCALE) or 256
    for n_cols, nnz in ((512, 3), (128, 2)):
        bg = jacobian_tall_skinny(n_rows, n_cols, nnz_per_row=nnz, seed=0)
        ts, oracle = timeit(lambda: greedy_serial_bipartite(bg))
        te, cr = timeit(lambda: compress_jacobian_pattern(bg, mode="fused"))
        assert validate_bipartite(bg, cr.coloring.colors)
        rows.append(row(
            f"d2/tallskinny_{n_rows}x{n_cols}/groups_serial", ts, int(oracle.max())
        ))
        rows.append(row(
            f"d2/tallskinny_{n_rows}x{n_cols}/groups_sgr", te, cr.num_groups
        ))
        rows.append(row(
            f"d2/tallskinny_{n_rows}x{n_cols}/compression", te,
            round(n_cols / cr.num_groups, 2),
        ))
    return rows


D2_BENCHES = [bench_d2_quality_speed, bench_d2_bipartite]


if __name__ == "__main__":
    print("name,us_per_call,derived", flush=True)
    for bench in D2_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}", flush=True)
