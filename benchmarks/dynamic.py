"""Streaming churn benchmark (§14): incremental recolor vs cold re-color.

Measures the dynamic engine's claim — ``session.recolor()`` after a small
edge delta does frontier-proportional work — against the cold fused engine
rerun from scratch on the mutated graph.  Deltas are deterministic
(seeded): each round deletes ``churn`` of the undirected edges and inserts
the same number of fresh random pairs, the classic sliding-window stream.

CSV rows (per suite graph): incremental/cold wall-clock per round and the
work ratio.  ``bench_dynamic_json`` writes the machine-readable churn
records consumed by ``run.py --engine dynamic`` and the CI regression gate
(``colors``/``valid`` quality fields plus ``work_ratio``, which
``check_regression.py`` holds above the baseline floor).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALE, row

CHURN = 0.01
CHURN_GRAPHS = ("rmat-g", "G3_circuit", "europe.osm")


def _churn_once(name: str, scale: float, rounds: int = 4,
                backend: str | None = None, trace: bool = False):
    """One graph's churn record: steady-state round times + work accounting.

    Per-round wall is the MIN across rounds (the §14 pow2-shape padding
    makes round 1+ hit the jit cache, so the min is the steady-state serve
    cost and round 0 carries the one-time compile for both paths).

    ``trace=True`` (schema 6) opens the session with §16 tracing and adds
    ``rounds_detail`` — per round: frontier size, engine work, superstep
    count, tail-trigger step, and whether the recolor hit the jit cache —
    plus a ``jit`` hits/misses section from ``session.metrics()``; the
    return becomes ``(record, last_round_trace)``.
    """
    from repro.core import color_data_driven
    from repro.dynamic import churn_delta, open_session
    from repro.graphs import build_graph

    g = build_graph(name, scale)
    rng = np.random.default_rng(14)
    session = open_session(g, backend=backend, trace=trace)
    w_inc = w_cold = frontier = 0
    t_inc, t_cold = [], []
    valid = True
    detail = []
    last_trace = None
    prev_hits = 0
    for i in range(rounds):
        rem, add = churn_delta(session.graph, CHURN, rng)
        dirty = session.apply_delta(remove_edges=rem, add_edges=add)
        frontier += int(dirty.size)
        t0 = time.perf_counter()
        inc = session.recolor()
        t_inc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        cold = color_data_driven(session.graph, mode="fused",
                                 backend=backend)
        t_cold.append(time.perf_counter() - t0)
        w_inc += inc.work_items
        w_cold += cold.work_items
        valid &= session.validate()
        if trace:
            m = session.metrics()
            hit = m["engine_cache_hits"] > prev_hits
            prev_hits = m["engine_cache_hits"]
            last_trace = inc.trace
            detail.append({
                "round": i,
                "frontier": int(dirty.size),
                "work": int(inc.work_items),
                "supersteps": int(last_trace.iterations),
                "tail_step": last_trace.tail_step,
                "cache_hit": bool(hit),
            })
    rec = {
        "n": g.n,
        "m": g.m,
        "churn": CHURN,
        "rounds": rounds,
        "frontier": frontier,
        "colors": session.num_colors,
        "valid": bool(valid),
        "work_inc": int(w_inc),
        "work_cold": int(w_cold),
        "work_ratio": round(w_cold / max(w_inc, 1), 2),
        "seconds_inc": round(min(t_inc), 6),
        "seconds_cold": round(min(t_cold), 6),
        "degradations": [dict(d) for d in
                         getattr(session.result, "degradations", ())],
    }
    if not trace:
        return rec
    m = session.metrics()
    rec["rounds_detail"] = detail
    rec["jit"] = {"hits": m["engine_cache_hits"],
                  "misses": m["engine_cache_misses"]}
    return rec, last_trace


def bench_dynamic_churn():
    """CSV rows: per-round incremental vs cold wall on the churn suite."""
    rows = []
    for name in CHURN_GRAPHS:
        r = _churn_once(name, SCALE)
        rows.append(row(f"dynamic_inc_{name}", r["seconds_inc"],
                        f"work_ratio={r['work_ratio']}"))
        rows.append(row(f"dynamic_cold_{name}", r["seconds_cold"],
                        f"colors={r['colors']}"))
    return rows


def bench_dynamic_json(scale: float, backend: str | None = None):
    """The ``dynamic`` BENCH section (schema 6): churn records + traces.

    Returns ``(records, runs)``: one churn record per suite graph (with
    per-round detail and jit accounting) and the last-round recolor
    ``RunTrace`` per graph for the Chrome-trace export.
    """
    records, runs = {}, {}
    for name in CHURN_GRAPHS:
        rec, rt = _churn_once(name, scale, backend=backend, trace=True)
        records[name] = rec
        if rt is not None:
            runs[f"dynamic/{name}"] = rt
    return records, runs


DYNAMIC_BENCHES = (bench_dynamic_churn,)
