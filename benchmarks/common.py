"""Shared benchmark harness: timing + default graph scale.

Scale: REPRO_BENCH_SCALE (default 0.15) multiplies the nominal Table-1 sizes
so the full matrix runs in minutes on this single CPU core; raise it on a
bigger host.  Timing: best of REPRO_BENCH_REPEATS (default 3) after one
warmup call (jit compilation excluded, matching the paper's method of timing
computation only).
"""
from __future__ import annotations

import os
import time

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.15"))
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))


def timeit(fn, repeats: int | None = None):
    """(best_seconds, last_result) with one warmup call."""
    repeats = repeats or REPEATS
    result = fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def timeit_median(fn, repeats: int | None = None):
    """(median_seconds, compile_seconds, last_result).

    The first call is timed separately — it pays jit tracing + compilation —
    and the reported wall time is the median of ``repeats`` post-warmup
    calls, so one noisy sample cannot skew the perf trajectory the way a
    single-shot (or best-of) measurement can.  ``compile_seconds``
    approximates the one-time cost as ``first_call - median``.
    """
    repeats = max(repeats or REPEATS, 1)
    t0 = time.perf_counter()
    result = fn()
    first = time.perf_counter() - t0
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    mid = len(times) // 2
    median = times[mid] if len(times) % 2 else 0.5 * (times[mid - 1] + times[mid])
    return median, max(first - median, 0.0), result


def row(name: str, seconds: float, derived) -> tuple[str, float, str]:
    return (name, seconds * 1e6, str(derived))
