"""Shared benchmark harness: timing + default graph scale.

Scale: REPRO_BENCH_SCALE (default 0.15) multiplies the nominal Table-1 sizes
so the full matrix runs in minutes on this single CPU core; raise it on a
bigger host.  Timing: best of REPRO_BENCH_REPEATS (default 3) after one
warmup call (jit compilation excluded, matching the paper's method of timing
computation only).
"""
from __future__ import annotations

import os
import time

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.15"))
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))


def timeit(fn, repeats: int | None = None):
    """(best_seconds, last_result) with one warmup call."""
    repeats = repeats or REPEATS
    result = fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def row(name: str, seconds: float, derived) -> tuple[str, float, str]:
    return (name, seconds * 1e6, str(derived))
